//! Quickstart: generate one image on a 2-GPU heterogeneous cluster.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Shows the whole public API surface in ~40 lines: configure a
//! cluster, build the engine, inspect the spatio-temporal plan, run a
//! request, and compare against single-device Origin output.

use stadi::baselines::origin;
use stadi::config::EngineConfig;
use stadi::coordinator::{dataflow, EngineCore};
use stadi::metrics::psnr::psnr;
use stadi::model::latents::{seeded_cond, seeded_noise};

fn main() -> stadi::Result<()> {
    // Two simulated GPUs: one idle, one with 40% background occupancy
    // (the paper's load-imbalance setting).
    let mut cfg = EngineConfig::two_gpu_default("artifacts", &[0.0, 0.4]);
    // Keep the example fast: 20 steps instead of the paper's 100.
    cfg.stadi.m_base = 20;
    // The core is the shared half of the engine (planner, profiler,
    // cluster); per-request execution happens in sessions it opens.
    let core = EngineCore::new(cfg)?;

    // The plan shows what STADI decided: fewer steps and/or a smaller
    // patch for the occupied GPU.
    let session = core.session()?;
    print!("{}", session.plan().describe());

    let seed = 1234u64;
    let gen = session.execute_seeded(seed)?;
    println!(
        "generated {}x{}x{} latent; simulated cluster latency {:.3}s \
         (utilization {:.0}%)",
        gen.latent.shape[0],
        gen.latent.shape[1],
        gen.latent.shape[2],
        gen.timeline.total_s,
        gen.timeline.utilization * 100.0,
    );

    // How close is the distributed result to non-distributed Origin?
    let model = core.exec().manifest().model.clone();
    let origin_plan = origin::plan(
        core.schedule(),
        &core.config().stadi,
        model.latent_h,
        model.row_granularity,
    )?;
    let noise = seeded_noise(&model, seed);
    let cond = seeded_cond(&model, seed);
    let origin_out =
        dataflow::execute(core.exec(), &origin_plan, &noise, &cond)?;
    println!(
        "PSNR vs Origin: {:.2} dB (max|diff| {:.4})",
        psnr(&gen.latent, &origin_out.latent),
        gen.latent.max_abs_diff(&origin_out.latent),
    );
    Ok(())
}
