//! Heterogeneous-cluster walkthrough: the paper's Fig. 8 scenario in
//! miniature, on a 4-GPU cluster mixing hardware tiers and background
//! load — including one GPU slow enough to be *excluded* by Eq. 4.
//!
//! ```bash
//! make artifacts && cargo run --release --example heterogeneous_cluster
//! ```

use stadi::baselines::{patch_parallel, tensor_parallel};
use stadi::config::{DeviceConfig, EngineConfig};
use stadi::coordinator::EngineCore;
use stadi::util::benchkit::Table;

fn main() -> stadi::Result<()> {
    let mut cfg = EngineConfig::two_gpu_default("artifacts", &[0.0]);
    cfg.devices = vec![
        // A full-speed flagship...
        DeviceConfig::new("flagship", 1.0, 0.0),
        // ...a same-tier card running a background training job,
        DeviceConfig::new("busy", 1.0, 0.45),
        // ...an older card (70% relative capability),
        DeviceConfig::new("older", 0.7, 0.0),
        // ...and a card so loaded Eq. 4 should exclude it.
        DeviceConfig::new("overloaded", 1.0, 0.85),
    ];
    cfg.stadi.m_base = 40;
    let core = EngineCore::new(cfg)?;
    // Calibrate per-step costs from real PJRT timings so simulated
    // latencies are grounded (swaps the shared cluster in place).
    let cost = core.calibrate(2)?;
    println!(
        "calibrated: fixed={:.2}ms per_row={:.3}ms\n",
        cost.fixed_s * 1e3,
        cost.per_row_s * 1e3
    );

    // A session pins the plan + cluster snapshot for one request.
    let session = core.session()?;
    print!("{}", session.plan().describe());
    println!();

    // Run a real request through the plan.
    let gen = session.execute_seeded(7)?;

    // Compare scheduling policies on this cluster (simulated latency).
    let model = core.exec().manifest().model.clone();
    let cluster = core.cluster();
    let pp = patch_parallel::plan(
        core.schedule(),
        cluster.len(),
        &core.config().stadi,
        model.latent_h,
        model.row_granularity,
    )?;
    let t_pp = core.simulate_latency(&pp)?;
    let t_tp = tensor_parallel::latency(
        core.config().stadi.m_base,
        &cluster,
        &core.config().comm,
        &model,
    );

    let mut table = Table::new(&[
        "method", "latency(s)", "speedup vs PP", "utilization",
    ]);
    for (name, t) in [
        ("tensor-parallel", &t_tp),
        ("patch-parallel", &t_pp),
        ("STADI", &gen.timeline),
    ] {
        table.row(&[
            name.to_string(),
            format!("{:.3}", t.total_s),
            format!("{:.2}x", t_pp.total_s / t.total_s),
            format!("{:.1}%", t.utilization * 100.0),
        ]);
    }
    table.print();

    println!(
        "\nper-device busy/idle (STADI): {:?}",
        gen.timeline
            .busy_s
            .iter()
            .zip(&gen.timeline.idle_s)
            .map(|(b, i)| format!("{b:.2}/{i:.2}"))
            .collect::<Vec<_>>()
    );
    Ok(())
}
