//! Heterogeneous-cluster walkthrough: the paper's Fig. 8 scenario in
//! miniature, on a 4-GPU cluster mixing hardware tiers and background
//! load — including one GPU slow enough to be *excluded* by Eq. 4.
//!
//! ```bash
//! make artifacts && cargo run --release --example heterogeneous_cluster
//! ```

use stadi::baselines::{patch_parallel, tensor_parallel};
use stadi::config::{DeviceConfig, EngineConfig};
use stadi::coordinator::Engine;
use stadi::util::benchkit::Table;

fn main() -> stadi::Result<()> {
    let mut cfg = EngineConfig::two_gpu_default("artifacts", &[0.0]);
    cfg.devices = vec![
        // A full-speed flagship...
        DeviceConfig::new("flagship", 1.0, 0.0),
        // ...a same-tier card running a background training job,
        DeviceConfig::new("busy", 1.0, 0.45),
        // ...an older card (70% relative capability),
        DeviceConfig::new("older", 0.7, 0.0),
        // ...and a card so loaded Eq. 4 should exclude it.
        DeviceConfig::new("overloaded", 1.0, 0.85),
    ];
    cfg.stadi.m_base = 40;
    let mut engine = Engine::new(cfg)?;
    // Calibrate per-step costs from real PJRT timings so simulated
    // latencies are grounded.
    let cost = engine.calibrate(2)?;
    println!(
        "calibrated: fixed={:.2}ms per_row={:.3}ms\n",
        cost.fixed_s * 1e3,
        cost.per_row_s * 1e3
    );

    let plan = engine.plan()?;
    print!("{}", plan.describe());
    println!();

    // Run a real request through the plan.
    let gen = engine.generate_seeded(7)?;

    // Compare scheduling policies on this cluster (simulated latency).
    let model = engine.exec().manifest().model.clone();
    let pp = patch_parallel::plan(
        engine.schedule(),
        engine.cluster().len(),
        &engine.config().stadi,
        model.latent_h,
        model.row_granularity,
    )?;
    let t_pp = engine.simulate_latency(&pp)?;
    let t_tp = tensor_parallel::latency(
        engine.config().stadi.m_base,
        engine.cluster(),
        &engine.config().comm,
        &model,
    );

    let mut table = Table::new(&[
        "method", "latency(s)", "speedup vs PP", "utilization",
    ]);
    for (name, t) in [
        ("tensor-parallel", &t_tp),
        ("patch-parallel", &t_pp),
        ("STADI", &gen.timeline),
    ] {
        table.row(&[
            name.to_string(),
            format!("{:.3}", t.total_s),
            format!("{:.2}x", t_pp.total_s / t.total_s),
            format!("{:.1}%", t.utilization * 100.0),
        ]);
    }
    table.print();

    println!(
        "\nper-device busy/idle (STADI): {:?}",
        gen.timeline
            .busy_s
            .iter()
            .zip(&gen.timeline.idle_s)
            .map(|(b, i)| format!("{b:.2}/{i:.2}"))
            .collect::<Vec<_>>()
    );
    Ok(())
}
