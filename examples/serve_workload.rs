//! Serving workload: start the concurrent TCP front-end, drive it with
//! sequential and then concurrent client workloads, and report
//! latency/throughput — the end-to-end serving driver recorded in
//! EXPERIMENTS.md (real model, real sockets, real batched request
//! stream; python nowhere on the path).
//!
//! ```bash
//! make artifacts && cargo run --release --features xla-backend \
//!     --example serve_workload -- --gang-policy adaptive
//! ```
//!
//! `--gang-policy all|fixed:K|adaptive` turns on fleet partitioning:
//! each request leases a policy-chosen GPU gang instead of planning
//! over the whole cluster (default: no fleet, PR 1 behavior).
//! `--io events|threads` picks the connection front-end: the default
//! poll(2) event loop, or the legacy thread-per-connection path.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use stadi::config::EngineConfig;
use stadi::coordinator::EngineCore;
use stadi::fleet::parse_policy;
use stadi::serve::server::{
    drive_workload, serve, serve_fleet, Client, ServeOptions,
};
use stadi::spec::{GenerationSpec, Priority, Quality};
use stadi::util::cli::Command;
use stadi::util::json;

const N_REQUESTS: usize = 8;

fn main() -> stadi::Result<()> {
    let cmd = Command::new("serve_workload", "end-to-end serving driver")
        .flag("artifacts", "artifacts directory", Some("artifacts"))
        .flag(
            "gang-policy",
            "fleet partitioning policy: all | fixed:K | adaptive \
             (empty = whole-cluster sessions)",
            Some(""),
        )
        .flag("workers", "worker pool size", Some("2"))
        .flag(
            "io",
            "connection front-end: events (poll loop) | threads \
             (legacy thread-per-connection)",
            Some("events"),
        );
    let p = cmd.parse(std::env::args().skip(1))?;

    let mut cfg = EngineConfig::two_gpu_default(
        p.get("artifacts").unwrap(),
        &[0.0, 0.3],
    );
    cfg.stadi.m_base = 12; // keep the demo snappy
    cfg.stadi.m_warmup = 2;
    let core = EngineCore::new(cfg)?;

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    println!("serving on {addr}");

    let stop = Arc::new(AtomicBool::new(false));
    let opts = ServeOptions {
        queue_capacity: 16,
        workers: p.get_parsed("workers")?,
        max_requests: 0,
        io: stadi::config::IoMode::parse(p.get("io").unwrap())?,
        ..ServeOptions::default()
    };
    let policy_spec = p.get("gang-policy").unwrap_or("").to_string();
    if !policy_spec.is_empty() {
        println!("fleet partitioning: --gang-policy {policy_spec}");
    }
    let server = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || -> stadi::Result<u64> {
            if policy_spec.is_empty() {
                serve(core, listener, opts, Some(stop))
            } else {
                let policy = parse_policy(&policy_spec)?;
                serve_fleet(
                    core,
                    Arc::from(policy),
                    listener,
                    opts,
                    Some(stop),
                )
            }
        })
    };

    // Phase 1: one connection, sequential requests.
    let w_seq = drive_workload(&addr, 1, N_REQUESTS, 1000)?;
    println!(
        "sequential: {N_REQUESTS} reqs in {:.2}s \
         (mean latency {:.3}s, p95 {:.3}s, {:.2} req/s)",
        w_seq.wall_s,
        w_seq.mean_latency_s,
        w_seq.p95_latency_s,
        w_seq.throughput_rps(N_REQUESTS)
    );

    // Phase 2: two connections in flight at once — whole-cluster
    // sessions overlap their sampler/halo/serialization work; gang
    // policies additionally run disjoint GPU subsets concurrently.
    let w_conc = drive_workload(&addr, 2, N_REQUESTS / 2, 2000)?;
    println!(
        "2 in flight: {N_REQUESTS} reqs in {:.2}s \
         (mean latency {:.3}s, p95 {:.3}s, {:.2} req/s)",
        w_conc.wall_s,
        w_conc.mean_latency_s,
        w_conc.p95_latency_s,
        w_conc.throughput_rps(N_REQUESTS)
    );
    println!(
        "concurrency speedup: {:.2}x",
        w_seq.wall_s / w_conc.wall_s
    );

    // Phase 3: protocol v2 — request-shaped specs. A draft-quality
    // high-priority request with a deadline rides the same wire as a
    // default (v1-equivalent) request; the response echoes the
    // resolved spec and the plan shows the smaller step budget.
    println!("\nprotocol v2: per-request specs");
    let mut client = Client::connect(&addr)?;
    let shapes = [
        (
            "draft-urgent",
            GenerationSpec::new()
                .seed(31)
                .quality(Quality::Draft)
                .priority(Priority::High)
                .deadline_s(30.0),
        ),
        ("default", GenerationSpec::new().seed(32)),
    ];
    for (name, spec) in &shapes {
        let t = std::time::Instant::now();
        let line = client.request_spec(name, spec)?;
        let v = json::parse(&line)?;
        if !v.get("ok")?.as_bool()? {
            return Err(stadi::Error::Protocol(format!(
                "v2 request {name} failed: {line}"
            )));
        }
        let echoed = v.get("spec")?;
        println!(
            "  {name}: {:.3}s wall, quality={} priority={} \
             sim_latency={:.3}s",
            t.elapsed().as_secs_f64(),
            echoed.get("quality")?.as_str()?,
            echoed.get("priority")?.as_str()?,
            v.get("sim_latency_s")?.as_f64()?,
        );
    }
    drop(client);

    stop.store(true, Ordering::SeqCst);
    let handled = server.join().expect("server thread")?;
    println!("\nworkload done: server handled {handled} requests");
    Ok(())
}
