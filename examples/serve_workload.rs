//! Serving workload: start the TCP front-end, drive it with a client
//! workload, and report latency/throughput — the end-to-end serving
//! driver recorded in EXPERIMENTS.md (real model, real sockets, real
//! batched request stream; python nowhere on the path).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_workload
//! ```

use std::net::TcpListener;
use std::thread;
use std::time::Instant;

use stadi::config::EngineConfig;
use stadi::coordinator::Engine;
use stadi::metrics::latency::LatencyTracker;
use stadi::serve::server::{serve, Client};
use stadi::util::json;

const N_REQUESTS: usize = 8;

fn main() -> stadi::Result<()> {
    let mut cfg = EngineConfig::two_gpu_default("artifacts", &[0.0, 0.3]);
    cfg.stadi.m_base = 12; // keep the demo snappy
    cfg.stadi.m_warmup = 2;
    let mut engine = Engine::new(cfg)?;

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    println!("serving on {addr}");

    let server = thread::spawn(move || {
        serve(&mut engine, listener, 16, N_REQUESTS, None)
    });

    // Client side: sequential requests with per-request latency.
    let mut client = Client::connect(&addr)?;
    let mut tracker = LatencyTracker::new();
    let t0 = Instant::now();
    for i in 0..N_REQUESTS {
        let t = Instant::now();
        let line = client.request(&format!("req-{i}"), 1000 + i as u64)?;
        tracker.record(t.elapsed().as_secs_f64());
        let v = json::parse(&line)?;
        println!(
            "  {} ok={} wall={:.3}s sim_cluster={:.3}s latent_sum={:.2}",
            v.get("id")?.as_str()?,
            v.get("ok")?.as_bool()?,
            v.get("latency_s")?.as_f64()?,
            v.get("sim_latency_s")?.as_f64()?,
            v.get("latent_sum")?.as_f64()?,
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(client);
    server.join().expect("server thread")?;

    println!(
        "\nworkload done: {} | throughput {:.2} req/s",
        tracker.summary(),
        tracker.throughput(wall)
    );
    Ok(())
}
