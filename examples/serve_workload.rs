//! Serving workload: start the concurrent TCP front-end, drive it with
//! sequential and then concurrent client workloads, and report
//! latency/throughput — the end-to-end serving driver recorded in
//! EXPERIMENTS.md (real model, real sockets, real batched request
//! stream; python nowhere on the path).
//!
//! ```bash
//! make artifacts && cargo run --release --features xla-backend \
//!     --example serve_workload
//! ```

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use stadi::config::EngineConfig;
use stadi::coordinator::EngineCore;
use stadi::serve::server::{drive_workload, serve, ServeOptions};

const N_REQUESTS: usize = 8;

fn main() -> stadi::Result<()> {
    let mut cfg = EngineConfig::two_gpu_default("artifacts", &[0.0, 0.3]);
    cfg.stadi.m_base = 12; // keep the demo snappy
    cfg.stadi.m_warmup = 2;
    let core = EngineCore::new(cfg)?;

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    println!("serving on {addr}");

    let stop = Arc::new(AtomicBool::new(false));
    let server = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            serve(
                core,
                listener,
                ServeOptions {
                    queue_capacity: 16,
                    workers: 2,
                    max_requests: 0,
                    ..ServeOptions::default()
                },
                Some(stop),
            )
        })
    };

    // Phase 1: one connection, sequential requests.
    let (wall_seq, mean_seq) = drive_workload(&addr, 1, N_REQUESTS, 1000)?;
    println!(
        "sequential: {N_REQUESTS} reqs in {wall_seq:.2}s \
         (mean latency {mean_seq:.3}s, {:.2} req/s)",
        N_REQUESTS as f64 / wall_seq
    );

    // Phase 2: two connections in flight at once — the worker pool
    // overlaps their sampler/halo/serialization work around the
    // single PJRT service thread.
    let (wall_conc, mean_conc) =
        drive_workload(&addr, 2, N_REQUESTS / 2, 2000)?;
    println!(
        "2 in flight: {N_REQUESTS} reqs in {wall_conc:.2}s \
         (mean latency {mean_conc:.3}s, {:.2} req/s)",
        N_REQUESTS as f64 / wall_conc
    );
    println!(
        "concurrency speedup: {:.2}x",
        wall_seq / wall_conc
    );

    stop.store(true, Ordering::SeqCst);
    let handled = server.join().expect("server thread")?;
    println!("\nworkload done: server handled {handled} requests");
    Ok(())
}
