//! Noise schedule + DDIM timestep grids (runtime twin of
//! `python/compile/schedule.py`; golden-tested against it).
//!
//! Conventions (paper §II-A): scaled-linear betas, alpha_bar_t =
//! prod(1 - beta); the paper's alpha_t = sqrt(alpha_bar_t) and
//! sigma_t = sqrt(1 - alpha_bar_t). DDIM (eta = 0) steps are fused
//! multiply-adds with precomputed (coef_x, coef_eps) — Eq. 3.

use crate::runtime::artifacts::ScheduleInfo;

/// Precomputed schedule tables.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub train_steps: usize,
    /// alpha_bar indexed by t, length train_steps.
    pub alpha_bar: Vec<f64>,
}

/// Coefficients of one DDIM update x_next = coef_x * x + coef_eps * eps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DdimCoef {
    pub coef_x: f64,
    pub coef_eps: f64,
}

impl Schedule {
    /// Scaled-linear (SD-style): linspace in sqrt space, squared.
    pub fn scaled_linear(train_steps: usize, beta_start: f64, beta_end: f64) -> Self {
        assert!(train_steps >= 2);
        let s0 = beta_start.sqrt();
        let s1 = beta_end.sqrt();
        let mut alpha_bar = Vec::with_capacity(train_steps);
        let mut prod = 1.0f64;
        for i in 0..train_steps {
            let frac = i as f64 / (train_steps - 1) as f64;
            let beta = {
                let s = s0 + (s1 - s0) * frac;
                s * s
            };
            prod *= 1.0 - beta;
            alpha_bar.push(prod);
        }
        Schedule { train_steps, alpha_bar }
    }

    pub fn from_info(info: &ScheduleInfo) -> Self {
        Self::scaled_linear(info.train_steps, info.beta_start, info.beta_end)
    }

    /// Leading-spaced DDIM grid of `m` timesteps, strictly decreasing,
    /// ending at 0: grid[k] = floor(k*T/m) for k = m-1 .. 0.
    pub fn ddim_grid(&self, m: usize) -> Vec<usize> {
        assert!(m >= 1);
        (0..m)
            .rev()
            .map(|k| (k * self.train_steps) / m)
            .collect()
    }

    /// Slow-device grid per STADI temporal adaptation (paper §III-C):
    /// shared warmup prefix, then every 2nd point of the remainder
    /// (always including the final point).
    pub fn stadi_slow_grid(fast: &[usize], warmup: usize) -> Vec<usize> {
        let rest = &fast[warmup..];
        assert!(
            rest.len() % 2 == 0,
            "M_base - M_warmup must be even (got {})",
            rest.len()
        );
        let mut g: Vec<usize> = fast[..warmup].to_vec();
        g.extend(rest.iter().skip(1).step_by(2));
        g
    }

    /// Coefficients of one DDIM step t_from -> t_to; t_to = None means
    /// the final step to the clean sample (alpha_bar = 1).
    pub fn ddim_coefficients(&self, t_from: usize, t_to: Option<usize>) -> DdimCoef {
        let ab_t = self.alpha_bar[t_from];
        let ab_s = match t_to {
            None => 1.0,
            Some(s) => self.alpha_bar[s],
        };
        let coef_x = (ab_s / ab_t).sqrt();
        let coef_eps = (1.0 - ab_s).sqrt() - coef_x * (1.0 - ab_t).sqrt();
        DdimCoef { coef_x, coef_eps }
    }

    /// Coefficient sequence for a decreasing grid, final step -> clean.
    pub fn grid_coefficients(&self, grid: &[usize]) -> Vec<DdimCoef> {
        (0..grid.len())
            .map(|i| {
                let to = grid.get(i + 1).copied();
                self.ddim_coefficients(grid[i], to)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> Schedule {
        Schedule::scaled_linear(1000, 0.00085, 0.012)
    }

    #[test]
    fn alpha_bar_monotone_decreasing() {
        let s = sched();
        assert_eq!(s.alpha_bar.len(), 1000);
        for w in s.alpha_bar.windows(2) {
            assert!(w[1] < w[0]);
        }
        assert!(s.alpha_bar[0] < 1.0 && s.alpha_bar[999] > 0.0);
    }

    #[test]
    fn grid_shape_and_bounds() {
        let s = sched();
        let g = s.ddim_grid(100);
        assert_eq!(g.len(), 100);
        assert_eq!(g[0], 990);
        assert_eq!(g[99], 0);
        for w in g.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    #[test]
    fn slow_grid_subset_and_aligned() {
        let s = sched();
        let fast = s.ddim_grid(100);
        let slow = Schedule::stadi_slow_grid(&fast, 4);
        assert_eq!(slow.len(), 52); // 4 + 96/2 — Eq. 4's ½M+½W
        assert_eq!(&slow[..4], &fast[..4]);
        assert_eq!(*slow.last().unwrap(), 0);
        for t in &slow {
            assert!(fast.contains(t));
        }
    }

    #[test]
    fn identity_coefficient() {
        let s = sched();
        let c = s.ddim_coefficients(500, Some(500));
        assert!((c.coef_x - 1.0).abs() < 1e-12);
        assert!(c.coef_eps.abs() < 1e-12);
    }

    #[test]
    fn coefficients_telescope() {
        // Product of coef_x over a grid = 1/sqrt(alpha_bar[grid[0]]).
        let s = sched();
        let g = s.ddim_grid(10);
        let prod: f64 = s
            .grid_coefficients(&g)
            .iter()
            .map(|c| c.coef_x)
            .product();
        let want = 1.0 / s.alpha_bar[g[0]].sqrt();
        assert!((prod - want).abs() / want < 1e-9);
    }

    #[test]
    fn matches_python_golden_if_built() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/golden/schedule.json");
        if !dir.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let g = crate::util::json::from_file(&dir).unwrap();
        let s = Schedule::scaled_linear(
            g.get("train_steps").unwrap().as_usize().unwrap(),
            g.get("beta_start").unwrap().as_f64().unwrap(),
            g.get("beta_end").unwrap().as_f64().unwrap(),
        );
        // alpha_bar samples
        for (k, v) in g.get("alpha_bar_samples").unwrap().as_obj().unwrap().iter() {
            let t: usize = k.parse().unwrap();
            let want = v.as_f64().unwrap();
            let got = s.alpha_bar[t];
            assert!(
                (got - want).abs() < 1e-12 * want.abs().max(1.0),
                "alpha_bar[{t}]: {got} vs {want}"
            );
        }
        // grids
        let want_g100 = g.get("grid_m100").unwrap().usizes().unwrap();
        assert_eq!(s.ddim_grid(100), want_g100);
        let want_g50 = g.get("grid_m50").unwrap().usizes().unwrap();
        assert_eq!(s.ddim_grid(50), want_g50);
        let want_slow = g.get("grid_slow_m100_w4").unwrap().usizes().unwrap();
        assert_eq!(Schedule::stadi_slow_grid(&s.ddim_grid(100), 4), want_slow);
        // first coefficients
        let coeffs = s.grid_coefficients(&s.ddim_grid(100));
        let want8 = g.get("coeffs_m100_first8").unwrap().as_arr().unwrap();
        for (i, pair) in want8.iter().enumerate() {
            let p = pair.f64s().unwrap();
            assert!((coeffs[i].coef_x - p[0]).abs() < 1e-12);
            assert!((coeffs[i].coef_eps - p[1]).abs() < 1e-12);
        }
        let want_last = g.get("coeffs_m100_last2").unwrap().as_arr().unwrap();
        for (i, pair) in want_last.iter().enumerate() {
            let p = pair.f64s().unwrap();
            let c = coeffs[coeffs.len() - 2 + i];
            assert!((c.coef_x - p[0]).abs() < 1e-9);
            assert!((c.coef_eps - p[1]).abs() < 1e-9);
        }
    }
}
