//! Diffusion model runtime pieces: noise schedule, DDIM sampler and
//! latent partitioning (rust twins of `python/compile/schedule.py` and
//! the request-side helpers).

pub mod latents;
pub mod sampler;
pub mod schedule;

pub use schedule::{DdimCoef, Schedule};
