//! Latent partitioning: contiguous row ranges per device, seeded
//! initial noise, and request conditioning vectors.

use crate::runtime::artifacts::ModelInfo;
use crate::runtime::tensor::Tensor;
use crate::util::rng::NormalGen;

/// A device's spatial assignment: latent rows [row0, row0 + rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowRange {
    pub row0: usize,
    pub rows: usize,
}

impl RowRange {
    pub fn end(&self) -> usize {
        self.row0 + self.rows
    }
}

/// Turn per-device patch sizes (rows) into contiguous ranges covering
/// the latent top-to-bottom in device order.
pub fn partition_rows(sizes: &[usize]) -> Vec<RowRange> {
    let mut out = Vec::with_capacity(sizes.len());
    let mut row0 = 0;
    for &rows in sizes {
        out.push(RowRange { row0, rows });
        row0 += rows;
    }
    out
}

/// Token range corresponding to a row range.
pub fn token_range(model: &ModelInfo, r: RowRange) -> (usize, usize) {
    let t0 = model.tokens_for_rows(r.row0);
    let t1 = t0 + model.tokens_for_rows(r.rows);
    (t0, t1)
}

/// Seeded N(0,1) initial latent for a request (the paper's "initial
/// noise x_{t_0}"). Draw order matches `compile/pcg.py` consumers.
pub fn seeded_noise(model: &ModelInfo, seed: u64) -> Tensor {
    let mut g = NormalGen::new(seed);
    let shape = model.latent_shape();
    let n = shape.iter().product();
    Tensor::new(shape, g.vec_f32(n)).unwrap()
}

/// Seeded conditioning vector (prompt-embedding stand-in, DESIGN.md §3).
/// Uses a distinct stream from the noise so requests with equal seeds
/// still decouple the two draws.
pub fn seeded_cond(model: &ModelInfo, seed: u64) -> Vec<f32> {
    let mut g = NormalGen::new(seed ^ 0x9e3779b97f4a7c15);
    g.vec_f32(model.dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelInfo {
        ModelInfo {
            latent_h: 32, latent_w: 32, latent_c: 4, patch: 2, dim: 96,
            heads: 4, layers: 3, temb_dim: 64, row_granularity: 4,
            tokens_full: 256, param_count: 1, params_seed: 0,
        }
    }

    #[test]
    fn partition_covers_contiguously() {
        let parts = partition_rows(&[24, 8]);
        assert_eq!(parts[0], RowRange { row0: 0, rows: 24 });
        assert_eq!(parts[1], RowRange { row0: 24, rows: 8 });
        assert_eq!(parts[1].end(), 32);
    }

    #[test]
    fn token_ranges_tile_the_tokens() {
        let m = model();
        let parts = partition_rows(&[12, 20]);
        let (a0, a1) = token_range(&m, parts[0]);
        let (b0, b1) = token_range(&m, parts[1]);
        assert_eq!((a0, a1), (0, 96));
        assert_eq!((b0, b1), (96, 256));
    }

    #[test]
    fn noise_is_seed_deterministic() {
        let m = model();
        let a = seeded_noise(&m, 5);
        let b = seeded_noise(&m, 5);
        let c = seeded_noise(&m, 6);
        assert_eq!(a, b);
        assert!(a.max_abs_diff(&c) > 0.1);
        assert_eq!(a.shape, vec![32, 32, 4]);
    }

    #[test]
    fn cond_differs_from_noise_stream() {
        let m = model();
        let cond = seeded_cond(&m, 5);
        let noise = seeded_noise(&m, 5);
        assert_eq!(cond.len(), 96);
        assert!((cond[0] - noise.data[0]).abs() > 1e-6);
    }
}
