//! Rust-native DDIM / DPM-Solver-1 update (paper Eq. 3), the L3 side
//! of the sampling loop. Cross-validated against both the python
//! oracle (golden trajectory) and the AOT'd Pallas `ddim_update`
//! artifact (integration tests).

use crate::model::schedule::DdimCoef;
use crate::runtime::tensor::Tensor;

/// In-place DDIM update over any tensor: x = coef_x * x + coef_eps * eps.
pub fn ddim_update_inplace(x: &mut Tensor, eps: &Tensor, c: DdimCoef) {
    debug_assert_eq!(x.shape, eps.shape);
    let cx = c.coef_x as f32;
    let ce = c.coef_eps as f32;
    for (xi, ei) in x.data.iter_mut().zip(&eps.data) {
        *xi = cx * *xi + ce * *ei;
    }
}

/// Out-of-place variant.
pub fn ddim_update(x: &Tensor, eps: &Tensor, c: DdimCoef) -> Tensor {
    let mut out = x.clone();
    ddim_update_inplace(&mut out, eps, c);
    out
}

/// Partial update over rows [r0, r0+h) of a [H, W, C] tensor — the
/// per-device case where each GPU only advances its own patch.
pub fn ddim_update_rows(
    x: &mut Tensor,
    eps_patch: &Tensor,
    r0: usize,
    c: DdimCoef,
) {
    assert_eq!(x.shape.len(), 3);
    let stride = x.shape[1] * x.shape[2];
    let h = eps_patch.shape[0];
    assert_eq!(eps_patch.shape[1..], x.shape[1..]);
    assert!(r0 + h <= x.shape[0]);
    let cx = c.coef_x as f32;
    let ce = c.coef_eps as f32;
    let xs = &mut x.data[r0 * stride..(r0 + h) * stride];
    for (xi, ei) in xs.iter_mut().zip(&eps_patch.data) {
        *xi = cx * *xi + ce * *ei;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::NormalGen;

    fn coef(cx: f64, ce: f64) -> DdimCoef {
        DdimCoef { coef_x: cx, coef_eps: ce }
    }

    #[test]
    fn identity_update() {
        let mut g = NormalGen::new(1);
        let x = Tensor::new(vec![4, 4, 2], g.vec_f32(32)).unwrap();
        let eps = Tensor::new(vec![4, 4, 2], g.vec_f32(32)).unwrap();
        let out = ddim_update(&x, &eps, coef(1.0, 0.0));
        assert_eq!(out, x);
    }

    #[test]
    fn fma_semantics() {
        let x = Tensor::new(vec![1, 1, 2], vec![2.0, 4.0]).unwrap();
        let eps = Tensor::new(vec![1, 1, 2], vec![1.0, -1.0]).unwrap();
        let out = ddim_update(&x, &eps, coef(0.5, 2.0));
        assert_eq!(out.data, vec![3.0, 0.0]);
    }

    #[test]
    fn rows_update_touches_only_patch() {
        let mut g = NormalGen::new(2);
        let mut x = Tensor::new(vec![8, 2, 2], g.vec_f32(32)).unwrap();
        let before = x.clone();
        let eps = Tensor::new(vec![2, 2, 2], g.vec_f32(8)).unwrap();
        ddim_update_rows(&mut x, &eps, 4, coef(0.9, 0.1));
        // Rows outside [4, 6) untouched.
        assert_eq!(x.slice_rows(0, 4), before.slice_rows(0, 4));
        assert_eq!(x.slice_rows(6, 2), before.slice_rows(6, 2));
        // Rows inside updated.
        let want0 = 0.9 * before.data[4 * 4] + 0.1 * eps.data[0];
        assert!((x.data[4 * 4] - want0).abs() < 1e-6);
    }

    #[test]
    fn full_equals_composed_row_updates() {
        // Updating all patches row-wise equals the full update —
        // the locality property spatial adaptation relies on.
        let mut g = NormalGen::new(3);
        let x0 = Tensor::new(vec![8, 4, 2], g.vec_f32(64)).unwrap();
        let eps = Tensor::new(vec![8, 4, 2], g.vec_f32(64)).unwrap();
        let c = coef(0.8, -0.3);
        let full = ddim_update(&x0, &eps, c);
        let mut patched = x0.clone();
        ddim_update_rows(&mut patched, &eps.slice_rows(0, 3), 0, c);
        ddim_update_rows(&mut patched, &eps.slice_rows(3, 5), 3, c);
        assert_eq!(full, patched);
    }
}
