//! DistriFusion-style patch parallelism (paper §II-B, the primary
//! baseline): uniform patches, uniform step counts, per-step
//! synchronization, asynchronous stale-activation reuse. Exactly
//! STADI with both adaptations disabled — which is how the paper
//! frames it (Table III "None").

use crate::config::StadiParams;
use crate::error::Result;
use crate::model::schedule::Schedule;
use crate::sched::plan::Plan;

/// Uniform patch-parallel plan over `n` devices. DistriFusion assumes
/// homogeneous devices, so speeds are forced to 1.0 (no exclusion, no
/// adaptation) regardless of actual cluster state — that blindness is
/// precisely the straggler effect of Figs. 2-3.
pub fn plan(
    schedule: &Schedule,
    n: usize,
    params: &StadiParams,
    total_rows: usize,
    granularity: usize,
) -> Result<Plan> {
    let p = StadiParams { temporal: false, spatial: false, ..params.clone() };
    let speeds = vec![1.0; n];
    let names: Vec<String> = (0..n).map(|i| format!("pp{i}")).collect();
    Plan::build(schedule, &speeds, &names, &p, total_rows, granularity)
}

/// Patch-parallel plan with an explicit row split (Fig. 9's patch-size
/// sweep: uniform steps, custom ratio).
pub fn plan_with_sizes(
    schedule: &Schedule,
    sizes: &[usize],
    params: &StadiParams,
) -> Result<Plan> {
    let p = StadiParams { temporal: false, spatial: false, ..params.clone() };
    let speeds = vec![1.0; sizes.len()];
    let names: Vec<String> =
        (0..sizes.len()).map(|i| format!("pp{i}")).collect();
    Plan::build_with_sizes(schedule, &speeds, &names, &p, sizes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_two_device_plan() {
        let s = Schedule::scaled_linear(1000, 0.00085, 0.012);
        let p = plan(&s, 2, &StadiParams::default(), 32, 4).unwrap();
        assert_eq!(p.devices[0].rows.rows, 16);
        assert_eq!(p.devices[1].rows.rows, 16);
        assert_eq!(p.devices[0].steps.len(), 100);
        assert_eq!(p.devices[1].steps.len(), 100);
        assert_eq!(p.sync_points.len(), 100);
    }

    #[test]
    fn custom_ratio_plan() {
        let s = Schedule::scaled_linear(1000, 0.00085, 0.012);
        let p =
            plan_with_sizes(&s, &[24, 8], &StadiParams::default()).unwrap();
        assert_eq!(p.devices[0].rows.rows, 24);
        assert_eq!(p.devices[1].rows.rows, 8);
        assert_eq!(p.total_rows(), 32);
    }
}
