//! Origin baseline: non-distributed inference on one device
//! (paper Table II "Origin"). The reference images for PSNR "w/ Orig."
//! come from here.

use crate::config::StadiParams;
use crate::error::Result;
use crate::model::schedule::Schedule;
use crate::sched::plan::Plan;

/// Single-device plan running all M_base steps on the full latent.
pub fn plan(
    schedule: &Schedule,
    params: &StadiParams,
    total_rows: usize,
    granularity: usize,
) -> Result<Plan> {
    let p = StadiParams {
        temporal: false,
        spatial: false,
        ..params.clone()
    };
    Plan::build(
        schedule,
        &[1.0],
        &["origin".to_string()],
        &p,
        total_rows,
        granularity,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn origin_owns_everything() {
        let s = Schedule::scaled_linear(1000, 0.00085, 0.012);
        let p = plan(&s, &StadiParams::default(), 32, 4).unwrap();
        assert_eq!(p.devices.len(), 1);
        assert_eq!(p.devices[0].rows.rows, 32);
        assert_eq!(p.devices[0].steps.len(), 100);
        // Every step syncs trivially (single participant).
        assert!(p.devices[0].steps.iter().all(|st| st.sync));
    }
}
