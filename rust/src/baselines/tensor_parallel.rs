//! Tensor-parallelism baseline (paper §V: "synchronous all-reduce at
//! each layer of computation").
//!
//! Numerically TP is exact — weight-split matmuls compose to the same
//! result — so its images are the Origin images; what differs is the
//! latency profile: per-layer synchronous all-reduces of full-image
//! activations every step, paced by the slowest device. The latency
//! model lives in `coordinator::timeline::simulate_tensor_parallel`;
//! this module pairs it with the Origin numerics for the quality
//! tables.

use crate::comm::all_reduce_cost;
use crate::config::CommConfig;
use crate::coordinator::timeline::{simulate_tensor_parallel, Timeline};
use crate::device::SimGpu;
use crate::runtime::artifacts::ModelInfo;

/// Latency of M steps of tensor-parallel inference.
pub fn latency(
    m_steps: usize,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
) -> Timeline {
    simulate_tensor_parallel(m_steps, cluster, comm, model)
}

/// Communication bytes per step (diagnostics / EXPERIMENTS.md): each
/// of the 2L all-reduces moves ~2·(n-1)/n of the activation per rank.
pub fn bytes_per_step(model: &ModelInfo, n: usize) -> u64 {
    let act = (model.tokens_full * model.dim * 4) as u64;
    (2 * model.layers) as u64 * act * (2 * (n.max(1) - 1)) as u64 / n.max(1) as u64
}

/// Cost of one activation all-reduce (exposed for benches).
pub fn reduce_cost(comm: &CommConfig, model: &ModelInfo, n: usize) -> f64 {
    all_reduce_cost(comm, model.tokens_full * model.dim * 4, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::device::{build_cluster, CostModel};

    fn model() -> ModelInfo {
        ModelInfo {
            latent_h: 32, latent_w: 32, latent_c: 4, patch: 2, dim: 96,
            heads: 4, layers: 3, temb_dim: 64, row_granularity: 4,
            tokens_full: 256, param_count: 1, params_seed: 0,
        }
    }

    #[test]
    fn tp_slower_than_pp_under_heavy_comm() {
        // With the default PCIe-ish cost model and per-layer blocking
        // reduces, TP pays more comm than patch parallelism — the
        // paper's Fig. 8 ordering.
        let devs = vec![
            DeviceConfig::new("a", 1.0, 0.0),
            DeviceConfig::new("b", 1.0, 0.0),
        ];
        let cl = build_cluster(
            &devs,
            CostModel { fixed_s: 0.004, per_row_s: 0.0012 },
        );
        let comm = CommConfig::default();
        let tl = latency(100, &cl, &comm, &model());
        assert!(tl.total_s > 0.0);
        assert!(tl.comm_s > 0.0);
        assert!(bytes_per_step(&model(), 2) > 0);
    }

    #[test]
    fn tp_single_device_has_no_comm() {
        let devs = vec![DeviceConfig::new("a", 1.0, 0.0)];
        let cl = build_cluster(
            &devs,
            CostModel { fixed_s: 0.004, per_row_s: 0.0012 },
        );
        let tl = latency(10, &cl, &CommConfig::default(), &model());
        assert_eq!(tl.comm_s, 0.0);
    }
}
