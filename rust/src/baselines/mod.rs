//! Baselines from the paper's evaluation (§V): non-distributed Origin,
//! DistriFusion-style patch parallelism, and tensor parallelism.

pub mod origin;
pub mod patch_parallel;
pub mod tensor_parallel;
