//! `stadi` CLI: leader entrypoint.
//!
//! Subcommands:
//!   generate       — run one request, print plan + latency + summary
//!   plan           — print the (M_i, P_i) plan for a cluster state
//!   profile        — calibrate the per-step cost model, optionally save
//!   serve          — TCP JSON-lines serving front-end
//!   compare        — STADI vs patch/tensor parallelism on one setting
//!   stub-artifacts — write a synthetic multi-resolution artifact set
//!                    that executes offline on the deterministic stub
//!                    backend (no PJRT, no python)

use std::net::TcpListener;
use std::process::ExitCode;

use stadi::baselines::{patch_parallel, tensor_parallel};
use stadi::config::{EngineConfig, ExecMode};
use stadi::coordinator::EngineCore;
use stadi::error::Result;
use stadi::serve::server::ServeOptions;
use stadi::util::cli::Command;
use stadi::util::json;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().collect();
    let sub = args.get(1).map(String::as_str).unwrap_or("help");
    let rest = args.iter().skip(2).cloned();
    let out = match sub {
        "generate" => cmd_generate(rest),
        "plan" => cmd_plan(rest),
        "profile" => cmd_profile(rest),
        "serve" => cmd_serve(rest),
        "compare" => cmd_compare(rest),
        "stub-artifacts" => cmd_stub_artifacts(rest),
        _ => {
            println!(
                "stadi — Spatio-Temporal Adaptive Diffusion Inference\n\n\
                 usage: stadi <generate|plan|profile|serve|compare|\
                 stub-artifacts> [flags]\n\
                 run `stadi <subcommand> --help` for flags"
            );
            Ok(())
        }
    };
    match out {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}

fn base_flags(cmd: Command) -> Command {
    cmd.flag("artifacts", "artifacts directory", Some("artifacts"))
        .flag("config", "JSON cluster config file (overrides --occ)", None)
        .flag("occ", "per-device occupancies, comma-separated", Some("0.0,0.0"))
        .flag("steps", "M_base", Some("100"))
        .flag("warmup", "M_warmup", Some("4"))
        .flag("a", "temporal threshold a", Some("0.75"))
        .flag("b", "exclusion threshold b", Some("0.25"))
        .switch("no-temporal", "disable temporal adaptation (+TA off)")
        .switch("no-spatial", "disable spatial adaptation (+SA off)")
        .switch("cost-aware", "EXTENSION: affine-cost patch mending")
        .switch("threaded", "real worker threads instead of dataflow")
        .flag(
            "replan",
            "EXTENSION: mid-flight re-planning cadence in sync points \
             (0 = force frozen plans; empty = config default)",
            Some(""),
        )
        .flag(
            "replan-threshold",
            "relative speed drift that triggers a re-plan",
            Some("0.05"),
        )
        .flag(
            "halo-mode",
            "halo exchange at sync points: sync | displaced | \
             displaced:N (N = staleness budget in sync intervals; \
             empty = config default)",
            Some(""),
        )
}

fn build_config(
    p: &stadi::util::cli::Parsed,
) -> Result<EngineConfig> {
    let mut cfg = if let Some(path) = p.get("config") {
        EngineConfig::from_json_file(std::path::Path::new(path))?
    } else {
        let occ: Vec<f64> = p.get_list("occ")?;
        EngineConfig::two_gpu_default(p.get("artifacts").unwrap(), &occ)
    };
    cfg.stadi.m_base = p.get_parsed("steps")?;
    cfg.stadi.m_warmup = p.get_parsed("warmup")?;
    cfg.stadi.a = p.get_parsed("a")?;
    cfg.stadi.b = p.get_parsed("b")?;
    cfg.stadi.temporal = !p.get_bool("no-temporal");
    cfg.stadi.spatial = !p.get_bool("no-spatial");
    cfg.stadi.cost_aware = p.get_bool("cost-aware");
    if p.get_bool("threaded") {
        cfg.mode = ExecMode::Threaded;
    }
    // Empty = leave whatever the JSON config says; an explicit 0
    // forces the frozen path even when the config opted in.
    if let Some(spec) = p.get("replan").filter(|s| !s.trim().is_empty()) {
        let every: usize = spec.trim().parse().map_err(|_| {
            stadi::error::Error::Config(format!(
                "--replan {spec:?} is not a sync-point count"
            ))
        })?;
        if every == 0 {
            cfg.replan.enabled = false;
        } else {
            cfg.replan.enabled = true;
            cfg.replan.every_k_syncs = every;
            cfg.replan.drift_threshold = p.get_parsed("replan-threshold")?;
        }
    }
    // Empty = leave whatever the JSON config says.
    if let Some(spec) = p.get("halo-mode").filter(|s| !s.trim().is_empty()) {
        cfg.halo = stadi::config::HaloMode::parse(spec.trim())?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_generate(args: impl Iterator<Item = String>) -> Result<()> {
    let cmd = base_flags(Command::new("generate", "run one request"))
        .flag("seed", "request seed", Some("1234"))
        .flag(
            "quality",
            "request quality tier: draft | standard | high \
             (scales --steps)",
            Some("standard"),
        )
        .switch("calibrate", "calibrate the cost model first");
    let p = cmd.parse(args)?;
    let cfg = build_config(&p)?;
    let core = EngineCore::new(cfg)?;
    if p.get_bool("calibrate") {
        let c = core.calibrate(3)?;
        println!(
            "calibrated cost model: fixed={:.4}ms per_row={:.4}ms",
            c.fixed_s * 1e3,
            c.per_row_s * 1e3
        );
    }
    let spec = stadi::spec::GenerationSpec::new()
        .seed(p.get_parsed("seed")?)
        .quality(stadi::spec::Quality::parse(p.get("quality").unwrap())?);
    let t0 = std::time::Instant::now();
    let g = core.generate(&spec)?;
    let wall = t0.elapsed().as_secs_f64();
    print!("{}", g.plan.describe());
    println!(
        "wall={:.3}s sim_cluster_latency={:.3}s utilization={:.1}% \
         syncs={} x_bytes={} kv_bytes={}",
        wall,
        g.timeline.total_s,
        g.timeline.utilization * 100.0,
        g.stats.syncs,
        g.stats.x_bytes,
        g.stats.kv_bytes
    );
    println!(
        "latent: sum={:.4} first4={:?}",
        g.latent.sum(),
        &g.latent.data[..4]
    );
    Ok(())
}

fn cmd_plan(args: impl Iterator<Item = String>) -> Result<()> {
    let cmd = base_flags(Command::new("plan", "print the schedule plan"));
    let p = cmd.parse(args)?;
    let cfg = build_config(&p)?;
    let core = EngineCore::new(cfg)?;
    let plan = core.plan()?;
    print!("{}", plan.describe());
    let tl = core.simulate_latency(&plan)?;
    println!(
        "simulated latency: {:.3}s (utilization {:.1}%)",
        tl.total_s,
        tl.utilization * 100.0
    );
    Ok(())
}

fn cmd_profile(args: impl Iterator<Item = String>) -> Result<()> {
    let cmd = base_flags(Command::new(
        "profile",
        "calibrate per-step cost from real PJRT timings",
    ))
    .flag("reps", "timed repetitions per height", Some("5"))
    .flag("save", "write calibration JSON to this path", None);
    let p = cmd.parse(args)?;
    let cfg = build_config(&p)?;
    let core = EngineCore::new(cfg)?;
    let cost = core.calibrate(p.get_parsed("reps")?)?;
    println!(
        "cost model: fixed={:.4}ms per_row={:.4}ms",
        cost.fixed_s * 1e3,
        cost.per_row_s * 1e3
    );
    if let Some(path) = p.get("save") {
        std::fs::write(path, json::to_string_pretty(&cost.to_json()))?;
        println!("saved to {path}");
    }
    Ok(())
}

fn cmd_serve(args: impl Iterator<Item = String>) -> Result<()> {
    let cmd = base_flags(Command::new("serve", "TCP JSON-lines server"))
        .flag("addr", "listen address", Some("127.0.0.1:7878"))
        .flag("queue", "router queue capacity", Some("64"))
        .flag("workers", "concurrent in-flight requests", Some("2"))
        .flag("max-requests", "stop after N requests (0 = run forever)", Some("0"))
        .flag(
            "connections",
            "simultaneously-open client connection cap (the event \
             loop's table size; excess connections wait in the OS \
             accept backlog)",
            Some("256"),
        )
        .flag(
            "io",
            "connection front-end: events (single poll-loop thread) | \
             threads (one reader+writer thread pair per connection; \
             kept byte-identical for one release)",
            Some("events"),
        )
        .flag(
            "gang-policy",
            "fleet partitioning: all | fixed:K | adaptive | deadline | \
             batched:K (empty = whole-cluster sessions)",
            Some(""),
        )
        .flag(
            "batch-window",
            "cross-request batching admission window in ms; setting it \
             enables batching (empty = config default, off unless the \
             JSON config enables it)",
            Some(""),
        )
        .flag(
            "batch-max",
            "largest fused session; setting it enables batching (empty \
             = config default)",
            Some(""),
        )
        .flag(
            "nodes",
            "federate N identical coordinator nodes behind one \
             admission surface (empty = config default; 1 = no tier)",
            Some(""),
        )
        .flag(
            "shard-policy",
            "federation routing: least-loaded | hash (empty = config \
             default)",
            Some(""),
        )
        .flag(
            "migrate",
            "true|false: barrier-checkpoint migration between \
             federation nodes (empty = config default)",
            Some(""),
        )
        .flag(
            "degrade",
            "graceful degradation under overload: off | on | \
             on:T1,T2,... (ascending pressure thresholds; empty = \
             config default)",
            Some(""),
        )
        .flag(
            "degrade-floor",
            "lowest quality tier the demotion ladder may serve: \
             draft | standard | high (empty = config default)",
            Some(""),
        );
    let p = cmd.parse(args)?;
    let mut cfg = build_config(&p)?;
    if let Some(s) = p.get("nodes").filter(|s| !s.trim().is_empty()) {
        cfg.federation.nodes = s.trim().parse().map_err(|_| {
            stadi::error::Error::Config(format!(
                "--nodes {s:?} is not a node count"
            ))
        })?;
    }
    if let Some(s) = p.get("shard-policy").filter(|s| !s.trim().is_empty())
    {
        cfg.federation.shard_policy = s.trim().to_string();
    }
    if let Some(s) = p.get("migrate").filter(|s| !s.trim().is_empty()) {
        cfg.federation.migrate = s.trim().parse().map_err(|_| {
            stadi::error::Error::Config(format!(
                "--migrate {s:?} is not true|false"
            ))
        })?;
    }
    if let Some(s) = p.get("degrade").filter(|s| !s.trim().is_empty()) {
        let s = s.trim();
        if s == "off" {
            cfg.degrade.enabled = false;
        } else if s == "on" {
            cfg.degrade.enabled = true;
        } else if let Some(list) = s.strip_prefix("on:") {
            cfg.degrade.pressure_thresholds = list
                .split(',')
                .map(|t| {
                    t.trim().parse::<f64>().map_err(|_| {
                        stadi::error::Error::Config(format!(
                            "--degrade threshold {t:?} is not a number"
                        ))
                    })
                })
                .collect::<Result<Vec<f64>>>()?;
            cfg.degrade.enabled = true;
        } else {
            return Err(stadi::error::Error::Config(format!(
                "--degrade {s:?} is not off | on | on:T1,T2,..."
            )));
        }
    }
    if let Some(s) = p.get("degrade-floor").filter(|s| !s.trim().is_empty())
    {
        cfg.degrade.floor = stadi::spec::Quality::parse(s.trim())?;
    }
    cfg.validate()?;
    let listener = TcpListener::bind(p.get("addr").unwrap())?;
    let mut opts = ServeOptions {
        queue_capacity: p.get_parsed("queue")?,
        workers: p.get_parsed("workers")?,
        max_requests: p.get_parsed("max-requests")?,
        max_connections: p.get_parsed("connections")?,
        io: stadi::config::IoMode::parse(p.get("io").unwrap())?,
        ..ServeOptions::default()
    };
    // The engine config's `batch` block is the baseline; either CLI
    // flag overrides its field *and* switches batching on (passing a
    // batching knob means you want batching).
    opts.batch = cfg.batch.clone();
    if let Some(s) = p.get("batch-window").filter(|s| !s.trim().is_empty()) {
        opts.batch.window_ms = s.trim().parse().map_err(|_| {
            stadi::error::Error::Config(format!(
                "--batch-window {s:?} is not a millisecond count"
            ))
        })?;
        opts.batch.enabled = true;
    }
    if let Some(s) = p.get("batch-max").filter(|s| !s.trim().is_empty()) {
        opts.batch.max_batch = s.trim().parse().map_err(|_| {
            stadi::error::Error::Config(format!(
                "--batch-max {s:?} is not a session size"
            ))
        })?;
        opts.batch.enabled = true;
    }
    if opts.batch.enabled && opts.batch.max_batch < 2 {
        return Err(stadi::error::Error::Config(
            "batching needs --batch-max >= 2".into(),
        ));
    }
    // The engine config's `degrade` block (possibly overridden above)
    // is what the serve path arms.
    opts.degrade = cfg.degrade.clone();
    if opts.degrade.enabled && cfg.federation.nodes > 1 {
        return Err(stadi::error::Error::Config(
            "--degrade shapes one node's admission queue; it cannot \
             be combined with a federated tier (--nodes > 1)"
                .into(),
        ));
    }
    if cfg.federation.nodes > 1 {
        if p.get("gang-policy").filter(|s| !s.is_empty()).is_some() {
            return Err(stadi::error::Error::Config(
                "--gang-policy partitions one node's fleet; it cannot \
                 be combined with a federated tier (--nodes > 1)"
                    .into(),
            ));
        }
        let tier = stadi::federation::FrontTier::homogeneous(&cfg)?;
        stadi::serve::server::serve_federated(
            std::sync::Arc::new(tier),
            listener,
            opts,
            None,
        )?;
        return Ok(());
    }
    let core = EngineCore::new(cfg)?;
    match p.get("gang-policy").filter(|s| !s.is_empty()) {
        None => {
            stadi::serve::server::serve(core, listener, opts, None)?;
        }
        Some(spec) => {
            let policy = stadi::fleet::parse_policy(spec)?;
            stadi::serve::server::serve_fleet(
                core,
                std::sync::Arc::from(policy),
                listener,
                opts,
                None,
            )?;
        }
    }
    Ok(())
}

fn cmd_stub_artifacts(args: impl Iterator<Item = String>) -> Result<()> {
    let cmd = Command::new(
        "stub-artifacts",
        "write a synthetic multi-resolution artifact set (offline \
         deterministic backend; every other subcommand then works \
         with --artifacts pointed here)",
    )
    .flag("out", "output directory", Some("artifacts-stub"))
    .flag(
        "resolutions",
        "extra latent resolutions as HxW pairs, comma-separated \
         (empty = native only)",
        Some("16x32,48x32"),
    )
    .flag(
        "drift",
        "deterministic occupancy drift schedule embedded in the \
         manifest, per-device `;`-separated OCC@STEP ramps (e.g. \
         \"0@0;0@0,0.6@4\"; empty = none)",
        Some(""),
    )
    .flag(
        "kv-gain",
        "KV-context coupling gain in [0,1] embedded in the manifest \
         (makes displaced-halo staleness numerically measurable; \
         empty = none, the exact legacy arithmetic)",
        Some(""),
    );
    let p = cmd.parse(args)?;
    let mut extra = Vec::new();
    let spec = p.get("resolutions").unwrap_or("");
    for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let (h, w) = part
            .trim()
            .split_once('x')
            .ok_or_else(|| {
                stadi::error::Error::Config(format!(
                    "bad resolution {part:?} (expected HxW, e.g. 16x32)"
                ))
            })?;
        let parse = |s: &str| {
            s.parse::<usize>().map_err(|_| {
                stadi::error::Error::Config(format!(
                    "bad resolution {part:?} (expected HxW, e.g. 16x32)"
                ))
            })
        };
        extra.push((parse(h)?, parse(w)?));
    }
    let out = p.get("out").unwrap();
    let drift = match p.get("drift").filter(|s| !s.trim().is_empty()) {
        Some(spec) => {
            Some(stadi::device::OccupancySchedule::parse(spec)?)
        }
        None => None,
    };
    let kv_gain = match p.get("kv-gain").filter(|s| !s.trim().is_empty()) {
        Some(spec) => Some(spec.trim().parse::<f64>().map_err(|_| {
            stadi::error::Error::Config(format!(
                "--kv-gain {spec:?} is not a number"
            ))
        })?),
        None => None,
    };
    stadi::runtime::stubgen::write_stub_artifacts_full(
        out,
        &extra,
        drift.as_ref(),
        kv_gain,
    )?;
    println!(
        "wrote stub artifacts to {out} ({} extra resolution{}): try\n  \
         stadi generate --artifacts {out} --steps 8 --warmup 2\n  \
         stadi serve --artifacts {out} --steps 8 --warmup 2",
        extra.len(),
        if extra.len() == 1 { "" } else { "s" },
    );
    Ok(())
}

fn cmd_compare(args: impl Iterator<Item = String>) -> Result<()> {
    let cmd = base_flags(Command::new(
        "compare",
        "STADI vs patch/tensor parallelism (simulated latency)",
    ));
    let p = cmd.parse(args)?;
    let cfg = build_config(&p)?;
    let core = EngineCore::new(cfg)?;
    core.calibrate(3)?;
    let model = core.exec().manifest().model.clone();
    let cluster = core.cluster();

    let stadi_plan = core.plan()?;
    let t_stadi = core.simulate_latency(&stadi_plan)?;

    let pp_plan = patch_parallel::plan(
        core.schedule(),
        cluster.len(),
        &core.config().stadi,
        model.latent_h,
        model.row_granularity,
    )?;
    let t_pp = core.simulate_latency(&pp_plan)?;
    let t_tp = tensor_parallel::latency(
        core.config().stadi.m_base,
        &cluster,
        &core.config().comm,
        &model,
    );

    println!("method            latency     vs PP    utilization");
    let row = |name: &str, t: &stadi::coordinator::timeline::Timeline| {
        println!(
            "{name:<16}  {:>8.3}s   {:>5.2}x   {:>6.1}%",
            t.total_s,
            t_pp.total_s / t.total_s,
            t.utilization * 100.0
        );
    };
    row("tensor-parallel", &t_tp);
    row("patch-parallel", &t_pp);
    row("STADI", &t_stadi);
    Ok(())
}
