//! Small dense linear algebra (substrate for the FID-proxy metric).
//!
//! Row-major `Mat` with just enough operations for the Fréchet
//! distance: covariance, symmetric eigendecomposition (cyclic Jacobi),
//! and the symmetric-product matrix square root
//! `tr((Σ₁ Σ₂)^{1/2})` computed as `tr((Σ₁^{1/2} Σ₂ Σ₁^{1/2})^{1/2})`.

/// Row-major dense matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        out
    }

    pub fn scale(&self, s: f64) -> Mat {
        let mut out = self.clone();
        for a in out.data.iter_mut() {
            *a *= s;
        }
        out
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Force exact symmetry (average with transpose).
    pub fn symmetrize(&self) -> Mat {
        self.add(&self.transpose()).scale(0.5)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Column means of a sample matrix [n, d].
pub fn col_means(samples: &Mat) -> Vec<f64> {
    let n = samples.rows.max(1) as f64;
    let mut mu = vec![0.0; samples.cols];
    for i in 0..samples.rows {
        for j in 0..samples.cols {
            mu[j] += samples[(i, j)];
        }
    }
    for m in mu.iter_mut() {
        *m /= n;
    }
    mu
}

/// Sample covariance (unbiased, /(n-1)) of [n, d] samples.
pub fn covariance(samples: &Mat) -> Mat {
    let n = samples.rows;
    let d = samples.cols;
    let mu = col_means(samples);
    let mut cov = Mat::zeros(d, d);
    if n < 2 {
        return cov;
    }
    for i in 0..n {
        for a in 0..d {
            let xa = samples[(i, a)] - mu[a];
            for b in a..d {
                cov[(a, b)] += xa * (samples[(i, b)] - mu[b]);
            }
        }
    }
    let denom = (n - 1) as f64;
    for a in 0..d {
        for b in a..d {
            let v = cov[(a, b)] / denom;
            cov[(a, b)] = v;
            cov[(b, a)] = v;
        }
    }
    cov
}

/// Symmetric eigendecomposition via cyclic Jacobi rotations.
/// Returns (eigenvalues, eigenvectors as columns of V) with A = V Λ Vᵀ.
pub fn sym_eigen(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.symmetrize();
    let mut v = Mat::eye(n);
    for _sweep in 0..100 {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation J(p,q,θ): M = Jᵀ M J, V = V J.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let vals = (0..n).map(|i| m[(i, i)]).collect();
    (vals, v)
}

/// Matrix square root of a symmetric PSD matrix via eigendecomposition.
/// Negative eigenvalues (numerical noise) are clamped to zero.
pub fn sqrtm_psd(a: &Mat) -> Mat {
    let (vals, v) = sym_eigen(a);
    let n = a.rows;
    let mut lam = Mat::zeros(n, n);
    for i in 0..n {
        lam[(i, i)] = vals[i].max(0.0).sqrt();
    }
    v.matmul(&lam).matmul(&v.transpose())
}

/// tr((Σ₁ Σ₂)^{1/2}) for symmetric PSD Σ₁, Σ₂, computed stably as
/// tr((S Σ₂ S)^{1/2}) with S = Σ₁^{1/2}.
pub fn trace_sqrt_product(sigma1: &Mat, sigma2: &Mat) -> f64 {
    let s = sqrtm_psd(sigma1);
    let inner = s.matmul(sigma2).matmul(&s).symmetrize();
    let (vals, _) = sym_eigen(&inner);
    vals.iter().map(|&l| l.max(0.0).sqrt()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::NormalGen;

    fn random_psd(n: usize, seed: u64) -> Mat {
        let mut g = NormalGen::new(seed);
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = g.next();
            }
        }
        b.matmul(&b.transpose()).scale(1.0 / n as f64)
    }

    #[test]
    fn matmul_identity() {
        let a = random_psd(5, 1);
        let i = Mat::eye(5);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-12);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn eigen_reconstructs() {
        for seed in 0..5 {
            let a = random_psd(8, seed);
            let (vals, v) = sym_eigen(&a);
            let mut lam = Mat::zeros(8, 8);
            for i in 0..8 {
                lam[(i, i)] = vals[i];
            }
            let rec = v.matmul(&lam).matmul(&v.transpose());
            assert!(
                rec.max_abs_diff(&a) < 1e-8,
                "seed {seed}: {}",
                rec.max_abs_diff(&a)
            );
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_psd(6, 9);
        let (_, v) = sym_eigen(&a);
        let vtv = v.transpose().matmul(&v);
        assert!(vtv.max_abs_diff(&Mat::eye(6)) < 1e-9);
    }

    #[test]
    fn sqrtm_squares_back() {
        for seed in 0..5 {
            let a = random_psd(7, 100 + seed);
            let s = sqrtm_psd(&a);
            assert!(s.matmul(&s).max_abs_diff(&a) < 1e-8);
        }
    }

    #[test]
    fn trace_sqrt_product_of_identical_is_trace() {
        // tr((ΣΣ)^{1/2}) = tr(Σ) for PSD Σ.
        let a = random_psd(6, 42);
        let t = trace_sqrt_product(&a, &a);
        assert!((t - a.trace()).abs() < 1e-8, "{t} vs {}", a.trace());
    }

    #[test]
    fn covariance_of_known_samples() {
        // Two perfectly correlated columns.
        let s = Mat::from_rows(&[
            vec![1.0, 2.0],
            vec![2.0, 4.0],
            vec![3.0, 6.0],
        ]);
        let c = covariance(&s);
        assert!((c[(0, 0)] - 1.0).abs() < 1e-12);
        assert!((c[(0, 1)] - 2.0).abs() < 1e-12);
        assert!((c[(1, 1)] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn col_means_correct() {
        let s = Mat::from_rows(&[vec![1.0, 10.0], vec![3.0, 20.0]]);
        assert_eq!(col_means(&s), vec![2.0, 15.0]);
    }
}
