//! Per-request generation parameters: the typed [`GenerationSpec`].
//!
//! The paper's planner adapts step counts and patch sizes to the
//! *cluster*; a serving deployment also has to adapt to the *request*
//! — different image sizes, step budgets, quality tiers and SLOs
//! (DistriFusion shows patch-parallel cost scales with resolution and
//! steps; mixed-request scheduling is where serving throughput is
//! won). `GenerationSpec` is the seam that carries those parameters
//! from the wire, through the router's priority queue, into
//! `EngineCore::plan_for` / `session_for` and the gang policies.
//!
//! Every field except `seed` is optional-with-a-default, and the
//! default spec reproduces the engine's global configuration exactly:
//! a v1 `{"id","seed"}` wire request maps to
//! `GenerationSpec::new().seed(s)` and plans — and renders — exactly
//! like the pre-spec engine did (covered by the backcompat golden
//! test).
//!
//! Resolution note: latent rows = `height / VAE_FACTOR`. Planning and
//! latency prediction accept any granularity-aligned row count, but
//! *execution* needs compiled artifacts for the requested latent size
//! — any resolution in the engine's
//! [`ArtifactRegistry`](crate::runtime::ArtifactRegistry) executes
//! end-to-end, and unregistered sizes are rejected at admission with a
//! typed [`Error::Spec`](crate::error::Error) (wire code `bad_spec`)
//! instead of producing a wrong-shaped image.

use crate::error::{Error, Result};
use crate::util::json::{Object, Value};

/// VAE downsampling factor: pixels per latent row/column.
pub const VAE_FACTOR: usize = 8;

/// Hard validation bounds (anti-abuse; generous beyond any real use).
pub const MAX_STEPS: usize = 4096;
pub const MAX_SIDE_PX: usize = 8192;

/// Seeds travel as JSON numbers (f64 on the wire), so only integers
/// strictly below 2^53 are unambiguous; 2^53 itself is rejected too,
/// because 2^53 + 1 rounds *onto* it in f64 — accepting it would
/// silently serve a different seed than the client sent.
pub const MAX_SEED: u64 = (1 << 53) - 1;

/// Deadline upper bound (a week): keeps `Instant + deadline`
/// arithmetic safely inside `Duration` range and rejects nonsense
/// SLOs instead of scheduling them.
pub const MAX_DEADLINE_S: f64 = 604_800.0;

/// Request quality tier: scales the step budget when `steps` is not
/// set explicitly (an explicit `steps` always wins).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Quality {
    /// Half the configured step budget.
    Draft,
    /// The configured step budget unchanged.
    #[default]
    Standard,
    /// 1.5x the configured step budget.
    High,
}

impl Quality {
    pub fn factor(self) -> f64 {
        match self {
            Quality::Draft => 0.5,
            Quality::Standard => 1.0,
            Quality::High => 1.5,
        }
    }

    /// The halo staleness budget this tier tolerates (sync intervals a
    /// peeked neighbor halo may lag). High-quality requests always run
    /// the fully synchronous exchange; draft requests accept the most
    /// displacement. The engine's configured
    /// [`HaloMode`](crate::config::HaloMode) can only be *tightened*
    /// by the tier: effective budget = `min(config, tier)`.
    pub fn staleness_budget(self) -> usize {
        match self {
            Quality::Draft => 2,
            Quality::Standard => 1,
            Quality::High => 0,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Quality::Draft => "draft",
            Quality::Standard => "standard",
            Quality::High => "high",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "draft" => Ok(Quality::Draft),
            "standard" => Ok(Quality::Standard),
            "high" => Ok(Quality::High),
            _ => Err(Error::Spec(format!(
                "unknown quality {s:?} (expected draft | standard | high)"
            ))),
        }
    }
}

/// Request priority tier. The router serves higher tiers first
/// (earliest-deadline within a tier, FIFO among equals).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    /// Numeric rank: higher = served first.
    pub fn rank(self) -> u8 {
        match self {
            Priority::Low => 0,
            Priority::Normal => 1,
            Priority::High => 2,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            _ => Err(Error::Spec(format!(
                "unknown priority {s:?} (expected low | normal | high)"
            ))),
        }
    }
}

/// Typed per-request generation parameters (builder API).
///
/// ```
/// use stadi::spec::{GenerationSpec, Priority, Quality};
/// let spec = GenerationSpec::new()
///     .seed(42)
///     .steps(50)
///     .size(256, 256)
///     .quality(Quality::Standard)
///     .priority(Priority::High)
///     .deadline_s(2.5);
/// spec.validate().unwrap();
/// ```
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GenerationSpec {
    /// Seeds the initial noise and the conditioning vector (the
    /// prompt-embedding stand-in, DESIGN.md §3).
    pub seed: u64,
    /// Explicit step budget (M_base for this request). `None` = the
    /// engine's configured M_base scaled by `quality`.
    pub steps: Option<usize>,
    /// Output height in pixels; `None` = the model's native height.
    pub height_px: Option<usize>,
    /// Output width in pixels; `None` = the model's native width.
    pub width_px: Option<usize>,
    pub quality: Quality,
    pub priority: Priority,
    /// Soft SLO: seconds from admission after which the request is
    /// shed rather than served (wire code `deadline`).
    pub deadline_s: Option<f64>,
}

impl GenerationSpec {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = Some(steps);
        self
    }

    /// Output size in pixels (height, width).
    pub fn size(mut self, height_px: usize, width_px: usize) -> Self {
        self.height_px = Some(height_px);
        self.width_px = Some(width_px);
        self
    }

    pub fn quality(mut self, q: Quality) -> Self {
        self.quality = q;
        self
    }

    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    pub fn deadline_s(mut self, s: f64) -> Self {
        self.deadline_s = Some(s);
        self
    }

    /// Validate field ranges (engine-independent; cross-checks against
    /// model geometry happen in `EngineCore::plan_for`).
    pub fn validate(&self) -> Result<()> {
        if self.seed > MAX_SEED {
            return Err(Error::Spec(format!(
                "seed {} not exactly representable as a JSON number \
                 (max {MAX_SEED})",
                self.seed
            )));
        }
        if let Some(s) = self.steps {
            if s < 2 || s > MAX_STEPS {
                return Err(Error::Spec(format!(
                    "steps {s} outside [2, {MAX_STEPS}]"
                )));
            }
        }
        for (name, px) in
            [("height", self.height_px), ("width", self.width_px)]
        {
            if let Some(px) = px {
                if px == 0 || px > MAX_SIDE_PX {
                    return Err(Error::Spec(format!(
                        "{name} {px}px outside [{VAE_FACTOR}, \
                         {MAX_SIDE_PX}]"
                    )));
                }
                if px % VAE_FACTOR != 0 {
                    return Err(Error::Spec(format!(
                        "{name} {px}px not a multiple of the VAE \
                         factor {VAE_FACTOR}"
                    )));
                }
            }
        }
        if let Some(d) = self.deadline_s {
            if !d.is_finite() || d <= 0.0 || d > MAX_DEADLINE_S {
                return Err(Error::Spec(format!(
                    "deadline_s {d} must be finite, > 0 and <= \
                     {MAX_DEADLINE_S}"
                )));
            }
        }
        Ok(())
    }

    /// The step budget this request plans with: an explicit `steps`
    /// wins; otherwise the configured base scaled by the quality tier
    /// (floored at 2 — parity against M_warmup is normalized by
    /// [`crate::sched::temporal::normalize_warmup`]).
    pub fn effective_steps(&self, base: usize) -> usize {
        match self.steps {
            Some(s) => s,
            None => {
                ((base as f64 * self.quality.factor()).round() as usize)
                    .max(2)
            }
        }
    }

    /// Latent rows this request plans over (`height / VAE_FACTOR`;
    /// native when unset).
    pub fn latent_rows(&self, native_rows: usize) -> usize {
        match self.height_px {
            Some(h) => h / VAE_FACTOR,
            None => native_rows,
        }
    }

    /// Latent columns this request renders (`width / VAE_FACTOR`;
    /// native when unset).
    pub fn latent_cols(&self, native_cols: usize) -> usize {
        match self.width_px {
            Some(w) => w / VAE_FACTOR,
            None => native_cols,
        }
    }

    /// True when the spec requests the model's native resolution (the
    /// only resolution the AOT'd artifacts can *execute*).
    pub fn is_native_size(&self, native_h: usize, native_w: usize) -> bool {
        self.height_px.unwrap_or(native_h * VAE_FACTOR)
            == native_h * VAE_FACTOR
            && self.width_px.unwrap_or(native_w * VAE_FACTOR)
                == native_w * VAE_FACTOR
    }

    /// Wire representation (the `"spec"` object of a v2 request line).
    /// Unset optional fields are omitted, so parse(to_json(s)) == s.
    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("seed", Value::Num(self.seed as f64));
        if let Some(s) = self.steps {
            o.insert("steps", Value::Num(s as f64));
        }
        if let Some(h) = self.height_px {
            o.insert("height", Value::Num(h as f64));
        }
        if let Some(w) = self.width_px {
            o.insert("width", Value::Num(w as f64));
        }
        o.insert("quality", Value::Str(self.quality.as_str().into()));
        o.insert("priority", Value::Str(self.priority.as_str().into()));
        if let Some(d) = self.deadline_s {
            o.insert("deadline_s", Value::Num(d));
        }
        Value::Obj(o)
    }

    /// Parse the `"spec"` object of a v2 request. Unknown keys are
    /// ignored (forward compatibility); known keys are validated
    /// strictly and the assembled spec is range-checked.
    pub fn from_json(v: &Value) -> Result<Self> {
        v.as_obj().map_err(|_| {
            Error::Spec("spec must be a JSON object".into())
        })?;
        let mut spec = GenerationSpec::new();
        if let Some(x) = v.get_opt("seed") {
            spec.seed = parse_seed(x)?;
        }
        if let Some(x) = v.get_opt("steps") {
            spec.steps = Some(x.as_usize().map_err(spec_err("steps"))?);
        }
        if let Some(x) = v.get_opt("height") {
            spec.height_px =
                Some(x.as_usize().map_err(spec_err("height"))?);
        }
        if let Some(x) = v.get_opt("width") {
            spec.width_px = Some(x.as_usize().map_err(spec_err("width"))?);
        }
        if let Some(x) = v.get_opt("quality") {
            spec.quality =
                Quality::parse(x.as_str().map_err(spec_err("quality"))?)?;
        }
        if let Some(x) = v.get_opt("priority") {
            spec.priority =
                Priority::parse(x.as_str().map_err(spec_err("priority"))?)?;
        }
        if let Some(x) = v.get_opt("deadline_s") {
            spec.deadline_s =
                Some(x.as_f64().map_err(spec_err("deadline_s"))?);
        }
        spec.validate()?;
        Ok(spec)
    }
}

/// Seeds arrive as JSON numbers; a negative one used to be silently
/// cast through `as u64` into a huge positive seed — now a typed
/// rejection (wire code `bad_spec`). The upper bound is [`MAX_SEED`]
/// (f64-exact integers only).
pub fn parse_seed(v: &Value) -> Result<u64> {
    let s = v.as_i64().map_err(spec_err("seed"))?;
    let seed = u64::try_from(s).map_err(|_| {
        Error::Spec(format!("seed {s} must be non-negative"))
    })?;
    if seed > MAX_SEED {
        return Err(Error::Spec(format!(
            "seed {seed} not exactly representable as a JSON number \
             (max {MAX_SEED})"
        )));
    }
    Ok(seed)
}

fn spec_err(field: &'static str) -> impl Fn(Error) -> Error {
    move |e| Error::Spec(format!("bad {field}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn default_spec_is_neutral() {
        let s = GenerationSpec::new();
        s.validate().unwrap();
        assert_eq!(s.effective_steps(100), 100);
        assert_eq!(s.latent_rows(32), 32);
        assert!(s.is_native_size(32, 32));
        assert_eq!(s.priority, Priority::Normal);
        assert_eq!(s.quality, Quality::Standard);
        assert_eq!(s.deadline_s, None);
    }

    #[test]
    fn builder_sets_every_field() {
        let s = GenerationSpec::new()
            .seed(7)
            .steps(50)
            .size(128, 256)
            .quality(Quality::Draft)
            .priority(Priority::High)
            .deadline_s(1.5);
        s.validate().unwrap();
        assert_eq!(s.seed, 7);
        assert_eq!(s.effective_steps(100), 50, "explicit steps win");
        assert_eq!(s.latent_rows(32), 16);
        assert!(!s.is_native_size(32, 32));
        assert_eq!(s.deadline_s, Some(1.5));
    }

    #[test]
    fn quality_scales_steps_when_unset() {
        let base = 100;
        assert_eq!(
            GenerationSpec::new()
                .quality(Quality::Draft)
                .effective_steps(base),
            50
        );
        assert_eq!(
            GenerationSpec::new()
                .quality(Quality::High)
                .effective_steps(base),
            150
        );
        // Explicit steps override the tier.
        assert_eq!(
            GenerationSpec::new()
                .steps(30)
                .quality(Quality::High)
                .effective_steps(base),
            30
        );
        // Tiny bases floor at 2 steps.
        assert_eq!(
            GenerationSpec::new()
                .quality(Quality::Draft)
                .effective_steps(2),
            2
        );
    }

    #[test]
    fn validation_rejects_bad_fields() {
        assert!(GenerationSpec::new().steps(1).validate().is_err());
        assert!(GenerationSpec::new()
            .steps(MAX_STEPS + 1)
            .validate()
            .is_err());
        assert!(GenerationSpec::new().size(100, 256).validate().is_err());
        assert!(GenerationSpec::new().size(0, 256).validate().is_err());
        assert!(GenerationSpec::new()
            .size(256, MAX_SIDE_PX + 8)
            .validate()
            .is_err());
        assert!(GenerationSpec::new().deadline_s(0.0).validate().is_err());
        assert!(GenerationSpec::new()
            .deadline_s(-1.0)
            .validate()
            .is_err());
        assert!(GenerationSpec::new()
            .deadline_s(f64::NAN)
            .validate()
            .is_err());
        assert!(GenerationSpec::new()
            .deadline_s(MAX_DEADLINE_S * 2.0)
            .validate()
            .is_err());
        // Seeds beyond f64-exact range are rejected, not rounded.
        assert!(GenerationSpec::new().seed(MAX_SEED).validate().is_ok());
        assert!(GenerationSpec::new()
            .seed(MAX_SEED + 1)
            .validate()
            .is_err());
    }

    #[test]
    fn json_roundtrip_preserves_unset_fields() {
        for spec in [
            GenerationSpec::new().seed(5),
            GenerationSpec::new()
                .seed(9)
                .steps(64)
                .size(128, 128)
                .quality(Quality::High)
                .priority(Priority::Low)
                .deadline_s(0.25),
        ] {
            let line = json::to_string(&spec.to_json());
            let back =
                GenerationSpec::from_json(&json::parse(&line).unwrap())
                    .unwrap();
            assert_eq!(back, spec, "{line}");
        }
    }

    #[test]
    fn from_json_rejects_negative_seed_and_bad_enums() {
        let bad = |s: &str| {
            let v = json::parse(s).unwrap();
            let e = GenerationSpec::from_json(&v).unwrap_err();
            assert!(
                matches!(e, Error::Spec(_)),
                "expected Error::Spec for {s}, got {e:?}"
            );
        };
        bad("{\"seed\": -1}");
        bad("{\"quality\": \"ultra\"}");
        bad("{\"priority\": \"urgent\"}");
        bad("{\"steps\": 1}");
        bad("{\"deadline_s\": -0.5}");
        bad("{\"height\": 100}");
        // Unknown keys are ignored, not rejected.
        let v = json::parse("{\"seed\": 3, \"future_knob\": true}").unwrap();
        assert_eq!(
            GenerationSpec::from_json(&v).unwrap(),
            GenerationSpec::new().seed(3)
        );
    }

    #[test]
    fn staleness_budget_tightens_with_quality() {
        assert_eq!(Quality::Draft.staleness_budget(), 2);
        assert_eq!(Quality::Standard.staleness_budget(), 1);
        assert_eq!(Quality::High.staleness_budget(), 0);
        assert!(
            Quality::High.staleness_budget()
                < Quality::Standard.staleness_budget()
        );
    }

    #[test]
    fn priority_ordering_and_ranks() {
        assert!(Priority::High > Priority::Normal);
        assert!(Priority::Normal > Priority::Low);
        assert_eq!(Priority::High.rank(), 2);
        assert_eq!(Priority::parse("high").unwrap(), Priority::High);
        assert_eq!(Quality::parse("draft").unwrap(), Quality::Draft);
    }
}
