//! # STADI — Spatio-Temporal Adaptive Diffusion Inference
//!
//! Rust + JAX + Pallas reproduction of *"STADI: Fine-Grained Step-Patch
//! Diffusion Parallelism for Heterogeneous GPUs"* (CS.DC 2025).
//!
//! Three layers (see DESIGN.md):
//! * **L1** — Pallas kernels (attention / LN / MLP / DDIM update) in
//!   `python/compile/kernels/`, lowered once at build time.
//! * **L2** — the mini-DiT denoiser in `python/compile/model.py`,
//!   AOT-compiled to HLO text per patch height.
//! * **L3** — this crate: the paper's contribution. Temporal step
//!   adaptation (Eq. 4), spatial patch-size mending (Eq. 5), the
//!   Algorithm-1 worker loop, communication manager, heterogeneous
//!   device simulation, serving front-end, baselines, metrics and the
//!   benches that regenerate every table/figure of the evaluation.
//!
//! Quickstart (after `make artifacts`, built with `--features
//! xla-backend`):
//! ```no_run
//! use stadi::config::EngineConfig;
//! use stadi::coordinator::EngineCore;
//!
//! let cfg = EngineConfig::two_gpu_default("artifacts", &[0.0, 0.4]);
//! let core = EngineCore::new(cfg).unwrap();
//! // One-shot: plan + execute. For serving, open one `Session` per
//! // in-flight request — sessions share the core and run concurrently.
//! let out = core.generate_seeded(1234).unwrap();
//! println!("latent sum = {}", out.latent.data.iter().sum::<f32>());
//! ```

pub mod baselines;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod des;
pub mod device;
pub mod error;
pub mod expt;
pub mod federation;
pub mod fleet;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod spec;
pub mod util;

pub use error::{Error, Result};
