//! Temporal adaptation: the Eq. 4 step allocator (paper §III-C).
//!
//! Given normalized effective speeds v_i (v_max = 1 after the
//! profiler's normalization) and thresholds 0 < b < a < 1:
//!
//!   M_i = M_base                     if a·v_max < v_i ≤ v_max
//!   M_i = ½·M_base + ½·M_warmup      if b·v_max < v_i ≤ a·v_max
//!   excluded                         if v_i ≤ b·v_max
//!
//! The ½ quantization is the paper's least-common-multiple-minimizing
//! choice: with step counts in ratio 2:1 past the warmup, every slow
//! step lands on a fast timestep, so sync points stay aligned and
//! communication intervals never stretch (§III-C "minimizes the lowest
//! common multiple of inference step sizes").

use crate::config::StadiParams;
use crate::error::{Error, Result};

/// Step class assigned to a device by Eq. 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepClass {
    /// Runs all M_base steps.
    Full,
    /// Runs M_warmup + (M_base - M_warmup)/2 steps.
    Half,
    /// v_i ≤ b·v_max: dropped from the cluster for this request.
    Excluded,
}

/// Result of temporal adaptation for one device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepAssignment {
    pub class: StepClass,
    /// Total local steps M_i (0 when excluded).
    pub steps: usize,
}

/// Number of steps in the Half class: ½·M_base + ½·M_warmup. With
/// M_base - M_warmup even this is exact integer math.
pub fn half_steps(p: &StadiParams) -> usize {
    p.m_warmup + (p.m_base - p.m_warmup) / 2
}

/// Largest warmup ≤ `preferred` that is valid for an `m_base`-step
/// grid: warmup < m_base and m_base - warmup even (the 2:1 LCM
/// quantization needs an even remainder to halve). This is how a
/// per-request step budget (`GenerationSpec::steps`) reuses the
/// engine's configured warmup without tripping the config invariants
/// — e.g. warmup 4 against a 7-step request normalizes to 3.
pub fn normalize_warmup(m_base: usize, preferred: usize) -> usize {
    assert!(m_base >= 2, "step grids need at least 2 steps");
    let mut w = preferred.min(m_base - 1);
    if (m_base - w) % 2 != 0 {
        // Parity fix: step down when possible (shrinking the shared
        // prefix is always safe), otherwise up to 1 (m_base odd, w 0).
        if w > 0 {
            w -= 1;
        } else {
            w = 1;
        }
    }
    w
}

/// Re-quantize a step *suffix* at a mid-request sync barrier: the
/// Half-class continuation takes every other point of the remaining
/// fast grid, keeping both endpoints — the barrier timestep (shared
/// state all devices just synchronized on) and the final pre-clean
/// timestep (so the last transition to the clean sample stays
/// aligned). This needs an odd-length suffix, which is exactly what
/// common sync barriers of a plan with Half-class devices yield
/// (M_base - M_warmup even ⇒ every shared post-state sits an even
/// number of fast steps before the final grid point); an all-Full
/// plan's barriers alternate parity, and callers defer one sync when
/// a demotion lands on the wrong one.
pub fn requantize_suffix(fast_suffix: &[usize]) -> Result<Vec<usize>> {
    if fast_suffix.is_empty() {
        return Err(Error::Sched("empty fast suffix".into()));
    }
    if fast_suffix.len() % 2 == 0 {
        return Err(Error::Sched(format!(
            "Half-class continuation needs an odd fast suffix (got {} \
             remaining steps)",
            fast_suffix.len()
        )));
    }
    Ok(fast_suffix.iter().copied().step_by(2).collect())
}

/// Apply Eq. 4 to every device. `speeds` need not be normalized; the
/// max in the slice is v_max. When `p.temporal` is false (ablation
/// "None"/"+SA"), every non-excluded device gets M_base.
pub fn assign_steps(speeds: &[f64], p: &StadiParams) -> Result<Vec<StepAssignment>> {
    if speeds.is_empty() {
        return Err(Error::Sched("no devices".into()));
    }
    let v_max = speeds.iter().cloned().fold(0.0, f64::max);
    if v_max <= 0.0 {
        return Err(Error::Sched("all devices have zero speed".into()));
    }
    let out: Vec<StepAssignment> = speeds
        .iter()
        .map(|&v| {
            if v <= p.b * v_max {
                StepAssignment { class: StepClass::Excluded, steps: 0 }
            } else if v <= p.a * v_max && p.temporal {
                StepAssignment { class: StepClass::Half, steps: half_steps(p) }
            } else {
                StepAssignment { class: StepClass::Full, steps: p.m_base }
            }
        })
        .collect();
    if out.iter().all(|a| a.class == StepClass::Excluded) {
        return Err(Error::Sched(
            "temporal adaptation excluded every device".into(),
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{ensure, forall};

    fn params() -> StadiParams {
        StadiParams::default() // m_base 100, warmup 4, a .75, b .25
    }

    #[test]
    fn fast_devices_keep_base_steps() {
        let a = assign_steps(&[1.0, 0.8], &params()).unwrap();
        assert_eq!(a[0], StepAssignment { class: StepClass::Full, steps: 100 });
        assert_eq!(a[1].class, StepClass::Full); // 0.8 > 0.75
    }

    #[test]
    fn middle_band_gets_half() {
        let a = assign_steps(&[1.0, 0.6], &params()).unwrap();
        assert_eq!(a[1].class, StepClass::Half);
        assert_eq!(a[1].steps, 52); // ½·100 + ½·4
    }

    #[test]
    fn slow_devices_excluded() {
        let a = assign_steps(&[1.0, 0.2], &params()).unwrap();
        assert_eq!(a[1].class, StepClass::Excluded);
        assert_eq!(a[1].steps, 0);
    }

    #[test]
    fn boundaries_are_paper_exact() {
        // v = a·v_max belongs to the Half band (strict a·v_max < v for
        // Full); v = b·v_max is excluded (strict b·v_max < v for Half).
        let p = params();
        let a = assign_steps(&[1.0, 0.75], &p).unwrap();
        assert_eq!(a[1].class, StepClass::Half);
        let a = assign_steps(&[1.0, 0.25], &p).unwrap();
        assert_eq!(a[1].class, StepClass::Excluded);
    }

    #[test]
    fn temporal_disabled_keeps_uniform_steps() {
        let mut p = params();
        p.temporal = false;
        let a = assign_steps(&[1.0, 0.5], &p).unwrap();
        assert_eq!(a[1].class, StepClass::Full);
        assert_eq!(a[1].steps, 100);
        // Exclusion still applies (GPU usage threshold b, §V).
        let a = assign_steps(&[1.0, 0.1], &p).unwrap();
        assert_eq!(a[1].class, StepClass::Excluded);
    }

    #[test]
    fn normalize_warmup_respects_grid_invariants() {
        // Even remainder preserved as-is.
        assert_eq!(normalize_warmup(100, 4), 4);
        // Warmup clamped below m_base (then parity-fixed: 4-3 is odd).
        assert_eq!(normalize_warmup(4, 4), 2);
        // Parity fixes: prefer stepping down...
        assert_eq!(normalize_warmup(7, 4), 3);
        assert_eq!(normalize_warmup(2, 4), 0);
        // ...step up only from 0 on an odd grid.
        assert_eq!(normalize_warmup(5, 0), 1);
        // Exhaustive invariant check over the small lattice.
        for m in 2..64usize {
            for pref in 0..10usize {
                let w = normalize_warmup(m, pref);
                assert!(w < m, "w={w} m={m}");
                assert_eq!((m - w) % 2, 0, "parity w={w} m={m}");
                assert!(
                    w <= pref + 1,
                    "normalization moved warmup too far: {pref} -> {w}"
                );
            }
        }
    }

    #[test]
    fn requantize_suffix_keeps_both_endpoints() {
        // Odd suffix: every other point, first and last included.
        let f = [90usize, 80, 70, 60, 50];
        assert_eq!(requantize_suffix(&f).unwrap(), vec![90, 70, 50]);
        // Length 1 (only the final step) is trivially itself.
        assert_eq!(requantize_suffix(&[10]).unwrap(), vec![10]);
        // Even suffixes cannot host a Half-class continuation.
        assert!(requantize_suffix(&[90, 80]).is_err());
        assert!(requantize_suffix(&[]).is_err());
    }

    #[test]
    fn requantize_matches_stadi_slow_grid_at_the_warmup_barrier() {
        use crate::model::schedule::Schedule;
        // The suffix re-quantization at a post-warmup barrier must
        // reproduce the static slow grid's continuation exactly (the
        // zero-drift invariant, grid half of it).
        let s = Schedule::scaled_linear(1000, 0.00085, 0.012);
        let fast = s.ddim_grid(100);
        let slow = Schedule::stadi_slow_grid(&fast, 4);
        // After m_warmup - 1 = 3 shared syncs both classes sit at
        // fast[3]; the slow continuation is slow[3..].
        let suffix = requantize_suffix(&fast[3..]).unwrap();
        assert_eq!(suffix, slow[3..].to_vec());
        // After the first post-warmup sync (post-state fast[5]) the
        // continuation is slow[5-th slow point..] = every other fast
        // point from index 5.
        let suffix = requantize_suffix(&fast[5..]).unwrap();
        assert_eq!(suffix, slow[4..].to_vec());
    }

    #[test]
    fn all_excluded_is_error() {
        // Single zero-speed device: error out rather than hang.
        assert!(assign_steps(&[0.0], &params()).is_err());
        assert!(assign_steps(&[], &params()).is_err());
    }

    #[test]
    fn property_half_class_grids_align_past_warmup() {
        use crate::model::schedule::Schedule;
        // For random valid (M_base, M_warmup) and speed vectors: every
        // Half-class device's timestep grid shares the warmup prefix
        // with the Full-class grid and lands only on Full-class
        // timesteps afterwards — the §III-C alignment that keeps sync
        // points from stretching — and grid lengths equal the Eq. 4
        // step counts.
        let schedule = Schedule::scaled_linear(1000, 0.00085, 0.012);
        forall(
            29,
            200,
            |rng| {
                let m_warmup = 1 + rng.below(6) as usize;
                let m_base = m_warmup + 2 * (1 + rng.below(24) as usize);
                let n = 2 + rng.below(5) as usize;
                let speeds: Vec<f64> =
                    (0..n).map(|_| 0.05 + 0.95 * rng.next_f64()).collect();
                ((m_base, m_warmup), speeds)
            },
            |((m_base, m_warmup), speeds)| {
                // Shrink candidates may break the M invariants the
                // config layer normally enforces; skip those.
                if *m_warmup == 0
                    || m_warmup >= m_base
                    || (m_base - m_warmup) % 2 != 0
                {
                    return Ok(());
                }
                let p = StadiParams {
                    m_base: *m_base,
                    m_warmup: *m_warmup,
                    ..StadiParams::default()
                };
                let Ok(assign) = assign_steps(speeds, &p) else {
                    return Ok(());
                };
                let fast = schedule.ddim_grid(*m_base);
                let slow = Schedule::stadi_slow_grid(&fast, *m_warmup);
                ensure(
                    slow[..*m_warmup] == fast[..*m_warmup],
                    "warmup prefix diverges",
                )?;
                for t in &slow[*m_warmup..] {
                    ensure(
                        fast.contains(t),
                        format!("slow timestep {t} not on the fast grid"),
                    )?;
                }
                for a in assign {
                    match a.class {
                        StepClass::Full => ensure(
                            a.steps == fast.len(),
                            "Full step count != fast grid length",
                        )?,
                        StepClass::Half => ensure(
                            a.steps == slow.len(),
                            "Half step count != slow grid length",
                        )?,
                        StepClass::Excluded => {
                            ensure(a.steps == 0, "excluded ran steps")?
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_sync_alignment_and_monotonicity() {
        // For arbitrary speed vectors: (1) the fastest device is never
        // excluded; (2) step counts are monotone in speed; (3) Half
        // count satisfies the LCM alignment M_full - W = 2·(M_half - W).
        let p = params();
        forall(
            17,
            300,
            |rng| {
                let n = 1 + rng.below(6) as usize;
                (0..n).map(|_| rng.next_f64()).collect::<Vec<f64>>()
            },
            |speeds| {
                let Ok(assign) = assign_steps(speeds, &p) else {
                    return Ok(()); // all-excluded handled elsewhere
                };
                let vmax = speeds.iter().cloned().fold(0.0, f64::max);
                let fastest = speeds.iter().position(|&v| v == vmax).unwrap();
                ensure(
                    assign[fastest].class == StepClass::Full,
                    "fastest device not Full",
                )?;
                for i in 0..speeds.len() {
                    for j in 0..speeds.len() {
                        if speeds[i] >= speeds[j] {
                            ensure(
                                assign[i].steps >= assign[j].steps,
                                format!(
                                    "monotonicity: v{i}={} v{j}={} but \
                                     M{i}={} < M{j}={}",
                                    speeds[i], speeds[j],
                                    assign[i].steps, assign[j].steps
                                ),
                            )?;
                        }
                    }
                }
                for a in assign {
                    if a.class == StepClass::Half {
                        ensure(
                            p.m_base - p.m_warmup == 2 * (a.steps - p.m_warmup),
                            "LCM alignment broken",
                        )?;
                    }
                }
                Ok(())
            },
        );
    }
}
