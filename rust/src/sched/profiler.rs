//! Effective-speed estimation (paper §III-B, §V).
//!
//! `v_i = c_i * (1 - rho_i)` from static config, refined online from
//! "historical inference time profiles" (paper §V): per-device EWMAs of
//! measured seconds-per-row, normalized so the fastest device is 1.0.

use crate::config::DeviceConfig;
use crate::util::stats::Ewma;

/// Online estimator of per-device effective speeds.
#[derive(Debug)]
pub struct Profiler {
    /// Static priors from config.
    priors: Vec<f64>,
    /// Measured seconds-per-row EWMAs (None until first sample).
    measured: Vec<Ewma>,
    names: Vec<String>,
}

impl Profiler {
    pub fn new(devices: &[DeviceConfig]) -> Self {
        Profiler {
            priors: devices.iter().map(|d| d.effective_speed()).collect(),
            measured: devices.iter().map(|_| Ewma::new(0.3)).collect(),
            names: devices.iter().map(|d| d.name.clone()).collect(),
        }
    }

    pub fn num_devices(&self) -> usize {
        self.priors.len()
    }

    pub fn name(&self, i: usize) -> &str {
        &self.names[i]
    }

    /// Record a measured step: `rows` processed in `seconds`.
    pub fn record_step(&mut self, device: usize, rows: usize, seconds: f64) {
        if rows == 0 || seconds <= 0.0 {
            return;
        }
        self.measured[device].update(seconds / rows as f64);
    }

    /// Current effective speeds, normalized to max = 1.0.
    ///
    /// Devices with measured history use 1/(s-per-row) relative to the
    /// fastest measured device; unmeasured devices fall back to their
    /// static prior. (Before any measurement this returns exactly the
    /// priors — the paper's offline-benchmark + occupancy-API path.)
    pub fn effective_speeds(&self) -> Vec<f64> {
        let spr: Vec<Option<f64>> =
            self.measured.iter().map(|e| e.get()).collect();
        let any_measured = spr.iter().any(Option::is_some);
        let mut v: Vec<f64> = if any_measured {
            // Fastest measured device anchors the scale.
            let best = spr
                .iter()
                .flatten()
                .cloned()
                .fold(f64::INFINITY, f64::min);
            spr.iter()
                .enumerate()
                .map(|(i, s)| match s {
                    Some(s) => best / s,
                    None => self.priors[i],
                })
                .collect()
        } else {
            self.priors.clone()
        };
        let max = v.iter().cloned().fold(0.0, f64::max);
        if max > 0.0 {
            for x in v.iter_mut() {
                *x /= max;
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn devs(occ: &[f64]) -> Vec<DeviceConfig> {
        occ.iter()
            .enumerate()
            .map(|(i, &o)| DeviceConfig::new(format!("g{i}"), 1.0, o))
            .collect()
    }

    #[test]
    fn priors_before_measurement() {
        let p = Profiler::new(&devs(&[0.0, 0.4]));
        let v = p.effective_speeds();
        assert!((v[0] - 1.0).abs() < 1e-12);
        assert!((v[1] - 0.6).abs() < 1e-12);
    }

    #[test]
    fn measurements_override_priors() {
        let mut p = Profiler::new(&devs(&[0.0, 0.0]));
        // Device 1 measured 2x slower despite equal priors.
        for _ in 0..10 {
            p.record_step(0, 16, 0.10);
            p.record_step(1, 16, 0.20);
        }
        let v = p.effective_speeds();
        assert!((v[0] - 1.0).abs() < 1e-9);
        assert!((v[1] - 0.5).abs() < 0.05, "v1 = {}", v[1]);
    }

    #[test]
    fn normalization_to_unit_max() {
        let mut p = Profiler::new(&devs(&[0.2, 0.2]));
        p.record_step(0, 8, 0.4);
        p.record_step(1, 8, 0.8);
        let v = p.effective_speeds();
        assert!((v.iter().cloned().fold(0.0, f64::max) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ignores_degenerate_samples() {
        let mut p = Profiler::new(&devs(&[0.0, 0.3]));
        p.record_step(0, 0, 1.0);
        p.record_step(1, 8, 0.0);
        assert_eq!(p.effective_speeds(), vec![1.0, 0.7]);
    }
}
