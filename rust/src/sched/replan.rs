//! Mid-flight re-planning: sync-point elastic re-splits (EXTENSION).
//!
//! The paper freezes the Eq. 4/5 plan before inference and only
//! applies the step allocator "after warmup phases". Real background
//! jobs land *while work is in flight*, so the plan's speed snapshot
//! goes stale mid-denoise. Sync barriers make this fixable: at a sync
//! point every included device holds the fully-fresh latent and KV
//! stack (the all-gather just ran), so ownership of rows can move
//! without any numerical consequence — the continuation depends only
//! on *which* grid steps run over *which* rows, not on who ran the
//! history.
//!
//! [`replan_at_sync`] therefore re-runs the static planner at live
//! speeds and adopts its answer for the remaining steps:
//!
//! * Eq. 4 re-classifies devices (a drifted device can demote
//!   Full→Half or drop out entirely; originally-excluded devices are
//!   never re-admitted — their buffers are stale);
//! * the remaining fast grid is the plan's own suffix from the
//!   barrier; Half-class devices continue on the
//!   [`requantize_suffix`](crate::sched::temporal::requantize_suffix)
//!   grid (every other point, both endpoints kept);
//! * Eq. 5 re-splits rows using the *full-request* step weights, so
//!   unchanged speeds reproduce the current split byte-for-byte — the
//!   zero-drift invariant the integration goldens pin.
//!
//! The [`RePlan`] delta carries row-migration accounting: which rows
//! changed owner and what a KV-sharded engine would pay to move them
//! (this repo's executors exchange full buffers at syncs, so the
//! migration itself is numerically free; the timeline model charges
//! the conservative transfer anyway so the DES comparison cannot
//! flatter re-planning).

use crate::config::StadiParams;
use crate::device::CostModel;
use crate::error::{Error, Result};
use crate::model::latents::RowRange;
use crate::model::schedule::Schedule;
use crate::runtime::artifacts::ModelInfo;
use crate::sched::plan::{Plan, StepSpec};
use crate::sched::spatial::resplit_sizes;
use crate::sched::temporal::{assign_steps, requantize_suffix, StepClass};

/// One device's row range before and after a re-plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowMove {
    pub device: usize,
    pub old: RowRange,
    pub new: RowRange,
}

impl RowMove {
    /// Rows this device gained (rows it must have fresh state for that
    /// it did not own before the barrier).
    pub fn gained_rows(&self) -> usize {
        self.new.rows - overlap(self.old, self.new)
    }
}

fn overlap(a: RowRange, b: RowRange) -> usize {
    let lo = a.row0.max(b.row0);
    let hi = a.end().min(b.end());
    hi.saturating_sub(lo)
}

/// The delta produced by one re-plan decision.
#[derive(Debug, Clone)]
pub struct RePlan {
    /// The continuation plan over the remaining fast-grid suffix.
    pub plan: Plan,
    /// The live speeds the re-plan was built from (local plan order).
    pub speeds: Vec<f64>,
    /// Devices whose row range changed.
    pub moves: Vec<RowMove>,
    /// Rows whose owning device changed.
    pub migrated_rows: usize,
    /// Did any device change step class (Full/Half/Excluded)?
    pub classes_changed: bool,
}

impl RePlan {
    /// True when the re-plan reproduces the current structure exactly
    /// (no migration, no class change) — the zero-drift case. Callers
    /// keep executing the current plan; by construction the suffix
    /// programs are identical anyway.
    pub fn is_structural_noop(&self) -> bool {
        self.migrated_rows == 0 && !self.classes_changed
    }

    /// Conservative migration transfer: every gained row's x slice and
    /// KV block, as a KV-sharded engine would have to move them.
    /// (Full-buffer engines like this repo's executors pay nothing;
    /// charging the bytes anyway keeps the adaptive-vs-frozen
    /// comparison honest.)
    pub fn migration_bytes(&self, model: &ModelInfo) -> u64 {
        let mut bytes = 0u64;
        for mv in &self.moves {
            let gained = mv.gained_rows();
            if gained == 0 {
                continue;
            }
            let x = gained * model.latent_w * model.latent_c * 4;
            let kv = model.layers
                * model.tokens_for_rows(gained)
                * 2
                * model.dim
                * 4;
            bytes += (x + kv) as u64;
        }
        bytes
    }
}

/// Live per-device speeds from one segment's measurements: invert the
/// calibrated cost model (`mean = step_time(rows, v)` ⇒ `v`), keep the
/// current plan's estimate for devices without fresh samples,
/// normalize to max 1 (the scale Eq. 4/5 consume). Local device order
/// throughout. `costs` is each local device's cost model (the
/// cluster's, in the same order as the plan). Shared by the session's
/// adaptive loop and the DES strategy comparison, so the simulated
/// numbers describe exactly what the engine does.
pub fn live_speeds(
    plan: &Plan,
    costs: &[CostModel],
    steps_before: &[usize],
    steps_after: &[usize],
    sec_delta: &[f64],
) -> Vec<f64> {
    let mut v = vec![0.0f64; plan.devices.len()];
    for d in plan.included_devices() {
        let i = d.device;
        let steps = steps_after[i] - steps_before[i];
        if steps == 0 || sec_delta[i] <= 0.0 {
            v[i] = d.speed;
            continue;
        }
        let mean = sec_delta[i] / steps as f64;
        v[i] = costs[i].step_time(d.rows.rows, 1.0) / mean;
    }
    let max = v.iter().cloned().fold(0.0, f64::max);
    if max > 0.0 {
        for x in v.iter_mut() {
            *x /= max;
        }
    }
    v
}

/// Max relative change of any included device's live speed vs the
/// speed the current plan was built from, against the threshold
/// (strict, so a literal zero-drift measurement never re-plans).
///
/// The plan's stored speeds can carry a different scale than the
/// max-1-normalized live estimates: a lease-restricted gang keeps the
/// *global* profiler normalization (`EngineCore::subset_parts` slices
/// without re-normalizing), so a [0.8, 0.8] gang is the same shape as
/// live [1.0, 1.0]. Both sides are therefore normalized to their own
/// included-max before comparing — only *relative* shape changes count
/// as drift (Eq. 4/5 are scale-invariant, so shape is all a re-plan
/// could act on anyway).
pub fn drift_detected(plan: &Plan, live: &[f64], threshold: f64) -> bool {
    let plan_max = plan
        .included_devices()
        .map(|d| d.speed)
        .fold(0.0, f64::max);
    if plan_max <= 0.0 {
        return false;
    }
    plan.included_devices().any(|d| {
        let old = (d.speed / plan_max).max(1e-9);
        (live[d.device] - d.speed / plan_max).abs() / old > threshold
    })
}

/// A device's program cursor after `synced` completed sync points: the
/// index of its next step.
pub fn cursor_after_syncs(steps: &[StepSpec], synced: usize) -> Result<usize> {
    if synced == 0 {
        return Ok(0);
    }
    let mut seen = 0usize;
    for (k, s) in steps.iter().enumerate() {
        if s.sync {
            seen += 1;
            if seen == synced {
                return Ok(k + 1);
            }
        }
    }
    Err(Error::Sched(format!(
        "program has only {seen} sync steps, asked for {synced}"
    )))
}

/// The remaining fast-grid suffix of `prev` after `synced` completed
/// sync points: the Full-class reference device's own `t_from` tail
/// from its cursor on. This is the payload a
/// [`MigrationEnvelope`](crate::federation::MigrationEnvelope) ships —
/// together with the barrier's fresh buffers it fully determines the
/// continuation. Returns `Ok(None)` when the barrier carries no
/// replannable work: nothing executed yet (`synced == 0`), or at most
/// the final step remains.
pub fn fast_suffix_of(
    prev: &Plan,
    synced: usize,
) -> Result<Option<Vec<usize>>> {
    if synced == 0 || synced >= prev.sync_points.len() {
        return Ok(None);
    }
    let fast_dev = prev
        .devices
        .iter()
        .find(|d| d.class == StepClass::Full)
        .ok_or_else(|| Error::Sched("plan has no Full-class device".into()))?;
    let j = cursor_after_syncs(&fast_dev.steps, synced)?;
    let fast_suffix: Vec<usize> =
        fast_dev.steps[j..].iter().map(|s| s.t_from).collect();
    if fast_suffix.len() < 2 {
        return Ok(None); // only the final step remains
    }
    Ok(Some(fast_suffix))
}

/// Plan a fast-grid suffix onto an **arbitrary** cluster — the
/// cross-node migration / device re-admission planner.
///
/// Unlike [`replan_at_sync`], which continues on the same devices and
/// therefore pins originally-excluded devices to speed 0 (their
/// buffers are stale), every device here is assumed to start from
/// *transferred fully-fresh buffers* (the `MigrationEnvelope`
/// state-transfer seam), so Eq. 4/5 run free over the live speeds:
/// any device count, recovered devices included. The caller owns
/// charging the state-transfer bytes on the timeline.
///
/// Returns `Ok(None)` on parity deferral: a Half-class continuation
/// needs an odd suffix (both endpoints on the slow grid) — hand off at
/// the next barrier instead.
#[allow(clippy::too_many_arguments)]
pub fn plan_suffix_on(
    schedule: &Schedule,
    fast_suffix: &[usize],
    params: &StadiParams,
    speeds: &[f64],
    names: &[String],
    cost: Option<&CostModel>,
    total_rows: usize,
    granularity: usize,
) -> Result<Option<Plan>> {
    let assign = assign_steps(speeds, params)?;
    let any_half = assign.iter().any(|a| a.class == StepClass::Half);
    if any_half && fast_suffix.len() % 2 == 0 {
        return Ok(None);
    }
    let sizes = resplit_sizes(
        speeds,
        &assign,
        params.spatial,
        cost,
        total_rows,
        granularity,
    )?;
    Plan::build_on_grid(
        schedule,
        fast_suffix,
        speeds,
        names,
        params,
        &assign,
        &sizes,
    )
    .map(Some)
}

/// Re-quantize the remaining steps of `prev` at a sync barrier —
/// the *pressure* lever (graceful degradation), as opposed to
/// [`replan_at_sync`]'s *drift* lever.
///
/// The continuation keeps the current speeds, classes and row split
/// intent but runs on the [`requantize_suffix`] grid: every other
/// point of the remaining fast suffix, both endpoints kept, so the
/// remaining work roughly halves while the final transition to the
/// clean sample stays aligned. The coarse grid becomes the
/// continuation's *fast* grid (Eq. 4 re-classifies over it, excluded
/// devices stay pinned out — their buffers are stale).
///
/// Returns `Ok(None)` when nothing can be cheapened at this barrier:
/// nothing executed yet / at most the final step remains, the suffix
/// has even parity (defer one sync point, exactly like a drift
/// demotion), the coarse grid would be a single step, or a Half-class
/// continuation lands on an even coarse suffix. Callers should only
/// trigger this past the warmup barrier — early denoising steps set
/// global structure and tolerate no thinning (the same rule the
/// displaced-halo fallback enforces).
pub fn requantize_plan_at_sync(
    schedule: &Schedule,
    prev: &Plan,
    synced: usize,
    cost: Option<&CostModel>,
    granularity: usize,
) -> Result<Option<Plan>> {
    let fast_suffix = match fast_suffix_of(prev, synced)? {
        Some(fs) => fs,
        None => return Ok(None),
    };
    if fast_suffix.len() % 2 == 0 {
        return Ok(None); // parity deferral: retry at the next barrier
    }
    let coarse = requantize_suffix(&fast_suffix)?;
    if coarse.len() < 2 {
        return Ok(None); // only the final transition remains
    }
    // No re-admission (same rule as replan_at_sync): an excluded
    // device's buffers are stale, so its speed is pinned to 0.
    let speeds: Vec<f64> = prev
        .devices
        .iter()
        .map(|d| if d.included() { d.speed } else { 0.0 })
        .collect();
    let names: Vec<String> =
        prev.devices.iter().map(|d| d.name.clone()).collect();
    plan_suffix_on(
        schedule,
        &coarse,
        &prev.params,
        &speeds,
        &names,
        cost,
        prev.total_rows(),
        granularity,
    )
}

/// Re-plan the remaining steps of `prev` at a sync barrier.
///
/// `synced` is the number of `prev` sync points completed (the barrier
/// everyone just arrived at); `live_speeds` the freshly measured
/// per-device speeds in the plan's (local) device order. Pass `cost`
/// iff the plan was built cost-aware. Returns `Ok(None)` when no
/// re-plan is possible at this barrier: nothing executed yet, the
/// request is finished (or only the final step remains), or a new
/// Half-class demotion lands on an even-parity suffix — callers defer
/// one sync point and retry.
pub fn replan_at_sync(
    schedule: &Schedule,
    prev: &Plan,
    synced: usize,
    live_speeds: &[f64],
    cost: Option<&CostModel>,
    granularity: usize,
) -> Result<Option<RePlan>> {
    let n = prev.devices.len();
    if live_speeds.len() != n {
        return Err(Error::Sched(format!(
            "live speeds for {} devices, plan has {n}",
            live_speeds.len()
        )));
    }
    // The remaining fast grid is the Full-class reference device's own
    // suffix — valid for original plans and for suffix plans alike
    // (the fastest device is always Full).
    let fast_suffix = match fast_suffix_of(prev, synced)? {
        Some(fs) => fs,
        None => return Ok(None),
    };
    // (Only the final sync point is the clean-sample None —
    // check_alignment guarantees it — and the suffix bound above
    // already excludes it, so sync_points[synced - 1] is a timestep.)
    debug_assert!(prev.sync_points[synced - 1].is_some());

    // No re-admission: a device excluded from `prev` has stale
    // buffers, so its live speed is pinned to 0 (Eq. 4 keeps it out
    // and Eq. 5 gives it no rows).
    let mut speeds = live_speeds.to_vec();
    for (i, d) in prev.devices.iter().enumerate() {
        if !d.included() {
            speeds[i] = 0.0;
        }
    }

    let assign = assign_steps(&speeds, &prev.params)?;
    let any_half = assign.iter().any(|a| a.class == StepClass::Half);
    if any_half && fast_suffix.len() % 2 == 0 {
        // A Half-class continuation needs an odd suffix (both
        // endpoints on the slow grid). Plans that already carry Half
        // devices only sync at odd-suffix barriers; an all-Full plan
        // syncs every step, so the very next barrier has the right
        // parity — defer to it.
        return Ok(None);
    }

    let total_rows = prev.total_rows();
    let sizes = resplit_sizes(
        &speeds,
        &assign,
        prev.params.spatial,
        cost,
        total_rows,
        granularity,
    )?;
    let names: Vec<String> =
        prev.devices.iter().map(|d| d.name.clone()).collect();
    let plan = Plan::build_on_grid(
        schedule,
        &fast_suffix,
        &speeds,
        &names,
        &prev.params,
        &assign,
        &sizes,
    )?;

    // Row-migration accounting: who owns which rows before vs after.
    let mut old_owner = vec![usize::MAX; total_rows];
    let mut new_owner = vec![usize::MAX; total_rows];
    for d in &prev.devices {
        for r in d.rows.row0..d.rows.end() {
            old_owner[r] = d.device;
        }
    }
    for d in &plan.devices {
        for r in d.rows.row0..d.rows.end() {
            new_owner[r] = d.device;
        }
    }
    let migrated_rows = old_owner
        .iter()
        .zip(&new_owner)
        .filter(|(a, b)| a != b)
        .count();
    let moves: Vec<RowMove> = prev
        .devices
        .iter()
        .zip(&plan.devices)
        .filter(|(o, p)| o.rows != p.rows)
        .map(|(o, p)| RowMove { device: o.device, old: o.rows, new: p.rows })
        .collect();
    let classes_changed = prev
        .devices
        .iter()
        .zip(&plan.devices)
        .any(|(o, p)| o.class != p.class);

    Ok(Some(RePlan {
        plan,
        speeds,
        moves,
        migrated_rows,
        classes_changed,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StadiParams;
    use crate::util::proptest::{ensure, forall};

    fn sched() -> Schedule {
        Schedule::scaled_linear(1000, 0.00085, 0.012)
    }

    fn build(speeds: &[f64], p: &StadiParams, rows: usize) -> Plan {
        let names: Vec<String> =
            (0..speeds.len()).map(|i| format!("g{i}")).collect();
        Plan::build(&sched(), speeds, &names, p, rows, 4).unwrap()
    }

    /// A device's remaining step program after `synced` sync points.
    fn suffix_of(plan: &Plan, device: usize, synced: usize) -> Vec<StepSpec> {
        let d = &plan.devices[device];
        if !d.included() {
            return Vec::new();
        }
        let j = cursor_after_syncs(&d.steps, synced).unwrap();
        d.steps[j..].to_vec()
    }

    /// Step programs match up to the local re-indexing a fresh suffix
    /// plan applies (index restarts at 0; everything the executors and
    /// the timeline consume — timesteps, coefficients, sync flags,
    /// warmup flags — must be identical).
    fn programs_equal(a: &[StepSpec], b: &[StepSpec]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.t_from == y.t_from
                    && x.t_to == y.t_to
                    && x.coef == y.coef
                    && x.sync == y.sync
                    && x.is_warmup == y.is_warmup
            })
    }

    #[test]
    fn zero_drift_replan_is_a_structural_noop_with_identical_programs() {
        let p = StadiParams::default(); // 100 steps, warmup 4
        let speeds = [1.0, 0.5];
        let plan = build(&speeds, &p, 32);
        // At the warmup barrier (m_warmup syncs) and at later
        // barriers, unchanged speeds must reproduce the remaining
        // programs exactly.
        for synced in [4usize, 6, 10] {
            let rp = replan_at_sync(&sched(), &plan, synced, &speeds, None, 4)
                .unwrap()
                .expect("replan possible at a mid-request barrier");
            assert!(rp.is_structural_noop(), "drift-free replan migrated");
            assert_eq!(rp.migrated_rows, 0);
            assert!(rp.moves.is_empty());
            for d in 0..2 {
                assert!(
                    programs_equal(
                        &suffix_of(&plan, d, synced),
                        &rp.plan.devices[d].steps
                    ),
                    "device {d} suffix program diverges at sync {synced}"
                );
                assert_eq!(plan.devices[d].rows, rp.plan.devices[d].rows);
            }
            // The continuation's sync schedule is the tail of the
            // original schedule.
            assert_eq!(
                rp.plan.sync_points.as_slice(),
                &plan.sync_points[synced..]
            );
        }
    }

    #[test]
    fn drift_demotes_and_migrates_rows() {
        let p = StadiParams::default();
        let plan = build(&[1.0, 1.0], &p, 32); // equal speeds: 16/16
        assert_eq!(plan.devices[1].rows.rows, 16);
        // Device 1 slows to 0.4 mid-request: demote to Half, shrink
        // its patch. All-Full plans sync every step, so barrier parity
        // matters: m_base 100 - synced must leave an odd suffix.
        let synced = 5;
        let rp = replan_at_sync(
            &sched(),
            &plan,
            synced,
            &[1.0, 0.4],
            None,
            4,
        )
        .unwrap()
        .expect("odd-suffix barrier must replan");
        assert!(rp.classes_changed);
        assert_eq!(rp.plan.devices[1].class, StepClass::Half);
        assert!(rp.plan.devices[1].rows.rows < 16);
        assert!(rp.migrated_rows > 0);
        assert_eq!(rp.moves.len(), 2);
        assert!(rp.migration_bytes(&test_model()) > 0);
        // Coverage: the re-split still tiles the latent exactly.
        assert_eq!(rp.plan.total_rows(), 32);
        // Even-parity barrier defers instead.
        let deferred =
            replan_at_sync(&sched(), &plan, 4, &[1.0, 0.4], None, 4)
                .unwrap();
        assert!(deferred.is_none(), "even suffix must defer demotion");
    }

    fn test_model() -> ModelInfo {
        ModelInfo {
            latent_h: 32,
            latent_w: 32,
            latent_c: 4,
            patch: 2,
            dim: 96,
            heads: 4,
            layers: 3,
            temb_dim: 64,
            row_granularity: 4,
            tokens_full: 256,
            param_count: 1,
            params_seed: 0,
        }
    }

    #[test]
    fn requantize_halves_suffix_and_keeps_endpoints() {
        let p = StadiParams { m_base: 20, m_warmup: 2, ..Default::default() };
        let speeds = [1.0, 1.0]; // all-Full: a sync point every step
        let plan = build(&speeds, &p, 32);
        // Odd-suffix barrier: 20 - 5 = 15 remaining fast steps.
        let synced = 5;
        let fast = fast_suffix_of(&plan, synced).unwrap().unwrap();
        assert_eq!(fast.len(), 15);
        let rq = requantize_plan_at_sync(&sched(), &plan, synced, None, 4)
            .unwrap()
            .expect("odd barrier must requantize");
        // The coarse grid is every other fast point, endpoints kept.
        let coarse: Vec<usize> = rq.devices[0]
            .steps
            .iter()
            .map(|st| st.t_from)
            .collect();
        assert_eq!(coarse.len(), 8);
        assert_eq!(coarse.first(), fast.first());
        assert_eq!(coarse.last(), fast.last());
        assert!(coarse.iter().all(|t| fast.contains(t)));
        // Even-parity barrier defers.
        assert!(requantize_plan_at_sync(&sched(), &plan, 4, None, 4)
            .unwrap()
            .is_none());
        // Terminal barriers refuse.
        let last = plan.sync_points.len();
        assert!(requantize_plan_at_sync(&sched(), &plan, 0, None, 4)
            .unwrap()
            .is_none());
        assert!(requantize_plan_at_sync(&sched(), &plan, last, None, 4)
            .unwrap()
            .is_none());
        // Excluded devices stay pinned out of the cheap continuation.
        let het = build(&[1.0, 0.1], &p, 32);
        assert!(!het.devices[1].included());
        let rq = requantize_plan_at_sync(&sched(), &het, 5, None, 4)
            .unwrap()
            .unwrap();
        assert_eq!(rq.devices[1].class, StepClass::Excluded);
    }

    #[test]
    fn drift_detection_is_scale_invariant_for_gang_plans() {
        // A lease-restricted gang keeps the global profiler scale: a
        // plan built at [0.5, 0.5] is the same *shape* as live
        // measurements normalized to [1.0, 1.0] — no drift, no
        // spurious planner pass at every barrier.
        let p = StadiParams::default();
        let plan = build(&[0.5, 0.5], &p, 32);
        assert!(!drift_detected(&plan, &[1.0, 1.0], 0.1));
        // A genuine relative change is still caught...
        assert!(drift_detected(&plan, &[1.0, 0.4], 0.1));
        // ...and max-1 plans compare as before.
        let plan = build(&[1.0, 0.6], &p, 32);
        assert!(!drift_detected(&plan, &[1.0, 0.6], 0.1));
        assert!(drift_detected(&plan, &[1.0, 0.3], 0.1));
    }

    #[test]
    fn excluded_devices_are_never_readmitted() {
        let p = StadiParams::default();
        let plan = build(&[1.0, 0.1], &p, 32); // device 1 excluded
        assert!(!plan.devices[1].included());
        // Device 1 "recovers" — but its buffers are stale, so the
        // re-plan must keep it out regardless of its live speed.
        let rp = replan_at_sync(&sched(), &plan, 6, &[1.0, 1.0], None, 4)
            .unwrap()
            .unwrap();
        assert_eq!(rp.plan.devices[1].class, StepClass::Excluded);
        assert_eq!(rp.plan.devices[1].rows.rows, 0);
        assert!(rp.is_structural_noop());
    }

    #[test]
    fn terminal_barriers_return_none() {
        let p = StadiParams { m_base: 8, m_warmup: 2, ..Default::default() };
        let plan = build(&[1.0, 0.5], &p, 32);
        let speeds = [1.0, 0.5];
        let last = plan.sync_points.len();
        assert!(replan_at_sync(&sched(), &plan, 0, &speeds, None, 4)
            .unwrap()
            .is_none());
        assert!(replan_at_sync(&sched(), &plan, last, &speeds, None, 4)
            .unwrap()
            .is_none());
        // One-before-last: only the final shared step remains.
        assert!(replan_at_sync(&sched(), &plan, last - 1, &speeds, None, 4)
            .unwrap()
            .is_none());
    }

    /// Satellite: the re-quantization/re-split property. For random
    /// valid (M_base, M_warmup), random speeds and granularities, at
    /// every feasible re-plan barrier and random live speeds: the
    /// re-quantized remaining steps stay on the fast-device grid with
    /// the sync schedules of all included devices aligned, and the
    /// re-split covers the latent rows exactly once at granularity
    /// alignment.
    #[test]
    fn property_replan_grids_align_and_resplit_tiles_exactly() {
        let s = sched();
        forall(
            71,
            150,
            |rng| {
                let m_warmup = 1 + rng.below(4) as usize;
                let m_base = m_warmup + 2 * (2 + rng.below(12) as usize);
                let gran = 1usize << (rng.below(3) as usize); // 1|2|4
                let granules = 2 + rng.below(14) as usize;
                let n = 2 + rng.below(3) as usize;
                let speeds: Vec<f64> =
                    (0..n).map(|_| 0.05 + 0.95 * rng.next_f64()).collect();
                let live: Vec<f64> =
                    (0..n).map(|_| 0.05 + 0.95 * rng.next_f64()).collect();
                let synced = 1 + rng.below(12) as usize;
                (
                    ((m_base, m_warmup), (gran, granules)),
                    ((speeds, live), synced),
                )
            },
            |case| {
                let (
                    ((m_base, m_warmup), (gran, granules)),
                    ((speeds, live), synced),
                ) = case;
                let (m_base, m_warmup, gran, granules, synced) =
                    (*m_base, *m_warmup, *gran, *granules, *synced);
                // Shrink candidates may violate the config invariants
                // the engine enforces upstream; skip those.
                if m_warmup == 0
                    || m_warmup >= m_base
                    || (m_base - m_warmup) % 2 != 0
                    || gran == 0
                    || granules == 0
                    || speeds.is_empty()
                    || live.len() != speeds.len()
                    || speeds.iter().chain(live.iter()).any(|&v| v <= 0.0)
                {
                    return Ok(());
                }
                let p = StadiParams {
                    m_base,
                    m_warmup,
                    ..StadiParams::default()
                };
                let rows = gran * granules;
                let names: Vec<String> =
                    (0..speeds.len()).map(|i| format!("g{i}")).collect();
                let Ok(plan) = Plan::build(&s, speeds, &names, &p, rows, gran)
                else {
                    return Ok(()); // infeasible shape: skip
                };
                let synced = synced % plan.sync_points.len();
                let rp = match replan_at_sync(
                    &s, &plan, synced, live, None, gran,
                ) {
                    Ok(Some(rp)) => rp,
                    Ok(None) => return Ok(()), // deferred/terminal
                    Err(e) => {
                        // Live speeds can push the split past what the
                        // granule budget allows — a typed refusal, not
                        // a broken plan.
                        return ensure(
                            e.to_string().contains("granule"),
                            format!("unexpected replan error: {e}"),
                        );
                    }
                };
                let fast_steps: Vec<usize> = plan
                    .devices
                    .iter()
                    .find(|d| d.class == StepClass::Full)
                    .unwrap()
                    .steps
                    .iter()
                    .map(|st| st.t_from)
                    .collect();
                // (1) every device's remaining grid lives on the fast
                // suffix, and sync schedules align.
                for d in rp.plan.included_devices() {
                    for st in &d.steps {
                        ensure(
                            fast_steps.contains(&st.t_from),
                            format!(
                                "timestep {} not on the fast grid",
                                st.t_from
                            ),
                        )?;
                    }
                    ensure(
                        d.sync_states() == rp.plan.sync_points,
                        "sync misalignment after replan",
                    )?;
                }
                // (2) the re-split tiles the rows exactly once.
                let mut covered = vec![0usize; rows];
                for d in &rp.plan.devices {
                    ensure(
                        d.rows.rows % gran == 0,
                        "granularity violated",
                    )?;
                    for r in d.rows.row0..d.rows.end() {
                        covered[r] += 1;
                    }
                }
                ensure(
                    covered.iter().all(|&c| c == 1),
                    "rows not covered exactly once",
                )?;
                // (3) migration accounting is self-consistent.
                let gained: usize = rp
                    .moves
                    .iter()
                    .map(|m| m.gained_rows())
                    .sum();
                ensure(
                    gained == rp.migrated_rows,
                    format!(
                        "gained {gained} != migrated {}",
                        rp.migrated_rows
                    ),
                )?;
                // (4) zero drift (live == plan speeds) is a noop.
                if let Ok(Some(noop)) = replan_at_sync(
                    &s,
                    &plan,
                    synced,
                    &plan
                        .devices
                        .iter()
                        .map(|d| d.speed)
                        .collect::<Vec<f64>>(),
                    None,
                    gran,
                ) {
                    ensure(
                        noop.is_structural_noop(),
                        "same-speed replan migrated rows",
                    )?;
                }
                Ok(())
            },
        );
    }
}
