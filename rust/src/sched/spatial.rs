//! Spatial adaptation: patch size mending, Eq. 5 (paper §III-D).
//!
//! Allocates P_i ∝ v_i / M_i (effective processing rate) subject to
//! Σ P_i = P_total, then rounds to the hardware/operator granularity
//! (paper §III-D "P_total must also satisfy hardware/operator
//! constraints"; here: latent rows in multiples of
//! `row_granularity`, matching the AOT'd patch-height variants) with a
//! largest-remainder scheme that preserves the total and keeps every
//! included device at least one granule.

use crate::error::{Error, Result};
use crate::sched::temporal::{StepAssignment, StepClass};

/// Ideal (unrounded) Eq. 5 shares P_i = (v_i/M_i) / Σ(v_j/M_j) · total.
pub fn ideal_shares(
    speeds: &[f64],
    assign: &[StepAssignment],
    total: f64,
) -> Vec<f64> {
    let rates: Vec<f64> = speeds
        .iter()
        .zip(assign)
        .map(|(&v, a)| match a.class {
            StepClass::Excluded => 0.0,
            _ => v / a.steps as f64,
        })
        .collect();
    let sum: f64 = rates.iter().sum();
    rates
        .iter()
        .map(|r| if sum > 0.0 { r / sum * total } else { 0.0 })
        .collect()
}

/// Round Eq. 5 shares to row counts: multiples of `granularity`,
/// summing to `total_rows`, ≥ granularity for every included device.
/// Uses largest-remainder apportionment on granules.
pub fn mend_patch_sizes(
    speeds: &[f64],
    assign: &[StepAssignment],
    total_rows: usize,
    granularity: usize,
) -> Result<Vec<usize>> {
    assert_eq!(speeds.len(), assign.len());
    if total_rows % granularity != 0 {
        return Err(Error::Sched(format!(
            "total rows {total_rows} not a multiple of granularity \
             {granularity}"
        )));
    }
    let granules_total = total_rows / granularity;
    let included: Vec<usize> = assign
        .iter()
        .enumerate()
        .filter(|(_, a)| a.class != StepClass::Excluded)
        .map(|(i, _)| i)
        .collect();
    if included.is_empty() {
        return Err(Error::Sched("no included devices".into()));
    }
    if included.len() > granules_total {
        return Err(Error::Sched(format!(
            "{} devices but only {granules_total} granules",
            included.len()
        )));
    }

    let ideal = ideal_shares(speeds, assign, granules_total as f64);

    // Floor to granules with a 1-granule floor for included devices.
    let mut granules: Vec<usize> = vec![0; speeds.len()];
    let mut remainders: Vec<(f64, usize)> = Vec::new();
    let mut used = 0usize;
    for &i in &included {
        let g = (ideal[i].floor() as usize).max(1);
        granules[i] = g;
        used += g;
        remainders.push((ideal[i] - ideal[i].floor(), i));
    }
    // Distribute leftovers by largest remainder; take back from the
    // smallest-remainder donors if the floors overshot (possible when
    // the 1-granule floor kicked in).
    if used < granules_total {
        remainders.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
        let mut k = 0;
        while used < granules_total {
            let (_, i) = remainders[k % remainders.len()];
            granules[i] += 1;
            used += 1;
            k += 1;
        }
    } else if used > granules_total {
        // Donors: largest current allocation first (take from the
        // biggest to keep everyone ≥ 1 granule).
        while used > granules_total {
            let &max_i = included
                .iter()
                .max_by_key(|&&i| granules[i])
                .unwrap();
            if granules[max_i] <= 1 {
                return Err(Error::Sched("cannot satisfy granule floors".into()));
            }
            granules[max_i] -= 1;
            used -= 1;
        }
    }

    Ok(granules.iter().map(|&g| g * granularity).collect())
}

/// EXTENSION (beyond the paper): cost-aware patch mending.
///
/// Eq. 5 assumes per-step latency is *linear* in patch rows, which the
/// paper itself notes breaks under large load gaps ("the single-step
/// delay no longer maintains a linear relationship with the patch
/// size due to some fixed overhead", Fig. 9 discussion). This
/// allocator minimizes the actual bottleneck under the calibrated
/// affine cost model instead:
///
///   minimize  max_i  (fixed + per_row · P_i) · (M_i/M_sync) / v_i
///   s.t.      Σ P_i = total, P_i ≥ g, P_i ≡ 0 (mod g)
///
/// where M_i/M_sync is the steps the device runs per sync interval
/// (2 for Full devices when Half devices exist, else 1). Solved
/// exactly by greedy granule descent: repeatedly move one granule from
/// the current bottleneck's complement... equivalently, start from the
/// floor assignment and hand each remaining granule to the device
/// whose interval time is currently *smallest* after the hypothetical
/// add — a classic makespan-balancing argument; with a single shared
/// affine cost the greedy is optimal on this lattice.
pub fn cost_aware_sizes(
    speeds: &[f64],
    assign: &[StepAssignment],
    cost: &crate::device::CostModel,
    total_rows: usize,
    granularity: usize,
) -> Result<Vec<usize>> {
    assert_eq!(speeds.len(), assign.len());
    if total_rows % granularity != 0 {
        return Err(Error::Sched(format!(
            "total rows {total_rows} not a multiple of granularity \
             {granularity}"
        )));
    }
    let included: Vec<usize> = assign
        .iter()
        .enumerate()
        .filter(|(_, a)| a.class != StepClass::Excluded)
        .map(|(i, _)| i)
        .collect();
    if included.is_empty() {
        return Err(Error::Sched("no included devices".into()));
    }
    let granules_total = total_rows / granularity;
    if included.len() > granules_total {
        return Err(Error::Sched(format!(
            "{} devices but only {granules_total} granules",
            included.len()
        )));
    }
    // Steps per sync interval: Full devices run 2 steps between syncs
    // when any Half device exists (Alg. 1's alternation), 1 otherwise.
    let any_half = assign.iter().any(|a| a.class == StepClass::Half);
    let steps_per_sync = |i: usize| -> f64 {
        match assign[i].class {
            StepClass::Full if any_half => 2.0,
            _ => 1.0,
        }
    };
    let interval_time = |i: usize, granules: usize| -> f64 {
        let rows = granules * granularity;
        cost.step_time(rows, speeds[i]) * steps_per_sync(i)
    };

    // Floor of one granule each, then greedily place the rest on the
    // device that stays cheapest after receiving it.
    let mut granules = vec![0usize; speeds.len()];
    for &i in &included {
        granules[i] = 1;
    }
    let mut remaining = granules_total - included.len();
    while remaining > 0 {
        let &best = included
            .iter()
            .min_by(|&&a, &&b| {
                interval_time(a, granules[a] + 1)
                    .partial_cmp(&interval_time(b, granules[b] + 1))
                    .unwrap()
            })
            .unwrap();
        granules[best] += 1;
        remaining -= 1;
    }
    Ok(granules.iter().map(|&g| g * granularity).collect())
}

/// Comm-aware variant of [`cost_aware_sizes`] for the displaced-halo
/// planner. Under [`HaloMode::Sync`] each candidate placement is
/// additionally charged the blocking per-interval x all-gather its
/// allocation would cost — under `PadAllGather` that penalizes growing
/// the *largest* patch (the pad target), flattening splits on slow
/// interconnects. Under a positive staleness budget the exchange is
/// off the critical path, the term vanishes, and the greedy reduces
/// byte-identically to [`cost_aware_sizes`] (same candidate rule, zero
/// added score) — the planner face of "displaced comm is cheaper".
///
/// `bytes_per_row` is the x payload of one latent row at the planned
/// width (`latent_cols * latent_c * 4`).
///
/// [`HaloMode::Sync`]: crate::config::HaloMode::Sync
#[allow(clippy::too_many_arguments)]
pub fn cost_aware_sizes_with_comm(
    speeds: &[f64],
    assign: &[StepAssignment],
    cost: &crate::device::CostModel,
    comm: &crate::config::CommConfig,
    halo: crate::config::HaloMode,
    bytes_per_row: usize,
    total_rows: usize,
    granularity: usize,
) -> Result<Vec<usize>> {
    assert_eq!(speeds.len(), assign.len());
    if total_rows % granularity != 0 {
        return Err(Error::Sched(format!(
            "total rows {total_rows} not a multiple of granularity \
             {granularity}"
        )));
    }
    let included: Vec<usize> = assign
        .iter()
        .enumerate()
        .filter(|(_, a)| a.class != StepClass::Excluded)
        .map(|(i, _)| i)
        .collect();
    if included.is_empty() {
        return Err(Error::Sched("no included devices".into()));
    }
    let granules_total = total_rows / granularity;
    if included.len() > granules_total {
        return Err(Error::Sched(format!(
            "{} devices but only {granules_total} granules",
            included.len()
        )));
    }
    let any_half = assign.iter().any(|a| a.class == StepClass::Half);
    let steps_per_sync = |i: usize| -> f64 {
        match assign[i].class {
            StepClass::Full if any_half => 2.0,
            _ => 1.0,
        }
    };
    let interval_time = |i: usize, granules: usize| -> f64 {
        let rows = granules * granularity;
        cost.step_time(rows, speeds[i]) * steps_per_sync(i)
    };
    // The blocking x gather a candidate allocation would pay per sync
    // interval; identically zero when the displaced path masks it.
    let blocking = halo.max_staleness() == 0;
    let x_gather = |granules: &[usize]| -> f64 {
        if !blocking {
            return 0.0;
        }
        let sizes: Vec<usize> = included
            .iter()
            .map(|&i| granules[i] * granularity * bytes_per_row)
            .collect();
        crate::comm::all_gather_cost(comm, &sizes)
    };

    let mut granules = vec![0usize; speeds.len()];
    for &i in &included {
        granules[i] = 1;
    }
    let mut remaining = granules_total - included.len();
    while remaining > 0 {
        let &best = included
            .iter()
            .min_by(|&&a, &&b| {
                let mut score = |i: usize| {
                    granules[i] += 1;
                    let s = interval_time(i, granules[i])
                        + x_gather(&granules);
                    granules[i] -= 1;
                    s
                };
                let (sa, sb) = (score(a), score(b));
                sa.partial_cmp(&sb).unwrap()
            })
            .unwrap();
        granules[best] += 1;
        remaining -= 1;
    }
    Ok(granules.iter().map(|&g| g * granularity).collect())
}

/// Eq. 5 elastic re-split at a mid-request sync barrier. The weights
/// deliberately use the *full-request* step counts carried by
/// `assign` (M_base / half-class totals — the same weights the static
/// planner uses) rather than the remaining-step counts: re-planning
/// is "adopt the split the static planner would build at today's
/// speeds", so unchanged speeds reproduce the current split exactly —
/// the zero-drift no-op invariant the re-planner is pinned to.
/// `cost` engages the cost-aware allocator (pass it iff the plan was
/// built cost-aware, so a re-plan never switches allocator families
/// mid-request).
pub fn resplit_sizes(
    speeds: &[f64],
    assign: &[StepAssignment],
    spatial: bool,
    cost: Option<&crate::device::CostModel>,
    total_rows: usize,
    granularity: usize,
) -> Result<Vec<usize>> {
    if !spatial {
        return uniform_patch_sizes(assign, total_rows, granularity);
    }
    match cost {
        Some(c) => {
            cost_aware_sizes(speeds, assign, c, total_rows, granularity)
        }
        None => mend_patch_sizes(speeds, assign, total_rows, granularity),
    }
}

/// Largest gang a latent of `total_rows` can feed: every included
/// device needs at least one granule. Request-shaped planning uses
/// this to bound gang size for small images (a 16-row draft spec on a
/// granularity of 4 can spread over at most 4 GPUs) before the patch
/// menders reject the split.
pub fn max_gang(total_rows: usize, granularity: usize) -> usize {
    if granularity == 0 {
        return 0;
    }
    total_rows / granularity
}

/// Uniform split (spatial adaptation disabled — ablation "None"/"+TA",
/// and the DistriFusion baseline). Remainder granules go to the first
/// devices, matching DistriFusion's equal-patch assumption as closely
/// as the granularity allows.
pub fn uniform_patch_sizes(
    assign: &[StepAssignment],
    total_rows: usize,
    granularity: usize,
) -> Result<Vec<usize>> {
    let speeds: Vec<f64> = assign
        .iter()
        .map(|a| if a.class == StepClass::Excluded { 0.0 } else { 1.0 })
        .collect();
    // Equal speeds + equal steps => equal shares through the same
    // rounding path.
    let eq: Vec<StepAssignment> = assign
        .iter()
        .map(|a| StepAssignment {
            class: a.class,
            steps: if a.class == StepClass::Excluded { 0 } else { 1 },
        })
        .collect();
    mend_patch_sizes(&speeds, &eq, total_rows, granularity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StadiParams;
    use crate::sched::temporal::assign_steps;
    use crate::util::proptest::{ensure, forall};

    fn full(steps: usize) -> StepAssignment {
        StepAssignment { class: StepClass::Full, steps }
    }

    #[test]
    fn equal_speeds_split_evenly() {
        let sizes =
            mend_patch_sizes(&[1.0, 1.0], &[full(100), full(100)], 32, 4)
                .unwrap();
        assert_eq!(sizes, vec![16, 16]);
    }

    #[test]
    fn faster_device_gets_larger_patch() {
        let sizes =
            mend_patch_sizes(&[1.0, 0.5], &[full(100), full(100)], 32, 4)
                .unwrap();
        assert_eq!(sizes.iter().sum::<usize>(), 32);
        assert!(sizes[0] > sizes[1]);
        // Ideal: 21.33 / 10.67 -> 20/12 or 24/8 after rounding.
        assert_eq!(sizes[0] % 4, 0);
    }

    #[test]
    fn step_reduction_shifts_rows_to_slow_device() {
        // Paper Table II's 24:8 case: slow device at half steps has
        // rate v/M doubled relative to naive v, earning more rows than
        // its raw speed alone would.
        let p = StadiParams::default();
        let speeds = [1.0, 0.4];
        let assign = assign_steps(&speeds, &p).unwrap();
        assert_eq!(assign[1].class, StepClass::Half);
        let stadi =
            mend_patch_sizes(&speeds, &assign, 32, 4).unwrap();
        let no_ta = mend_patch_sizes(
            &speeds,
            &[full(100), full(100)],
            32,
            4,
        )
        .unwrap();
        assert!(stadi[1] > no_ta[1], "{stadi:?} vs {no_ta:?}");
    }

    #[test]
    fn excluded_devices_get_zero_rows() {
        let assign = [
            full(100),
            StepAssignment { class: StepClass::Excluded, steps: 0 },
        ];
        let sizes = mend_patch_sizes(&[1.0, 0.1], &assign, 32, 4).unwrap();
        assert_eq!(sizes, vec![32, 0]);
    }

    #[test]
    fn uniform_split_ignores_speeds() {
        let assign = [full(100), full(100)];
        assert_eq!(uniform_patch_sizes(&assign, 32, 4).unwrap(), vec![16, 16]);
        // Non-power-of-two device counts leave a remainder granule.
        let assign3 = [full(100), full(100), full(100)];
        let sizes = uniform_patch_sizes(&assign3, 32, 4).unwrap();
        assert_eq!(sizes.iter().sum::<usize>(), 32);
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(max - min <= 4, "{sizes:?}");
    }

    #[test]
    fn rejects_impossible_granularity() {
        assert!(mend_patch_sizes(&[1.0], &[full(10)], 30, 4).is_err());
        let nine: Vec<f64> = vec![1.0; 9];
        let assign: Vec<_> = (0..9).map(|_| full(10)).collect();
        assert!(mend_patch_sizes(&nine, &assign, 32, 4).is_err());
    }

    #[test]
    fn max_gang_matches_mender_feasibility() {
        assert_eq!(max_gang(32, 4), 8);
        assert_eq!(max_gang(16, 4), 4);
        assert_eq!(max_gang(3, 4), 0);
        assert_eq!(max_gang(32, 0), 0);
        // Exactly max_gang devices is feasible; one more is not.
        let k = max_gang(16, 4);
        let speeds = vec![1.0; k];
        let assign: Vec<_> = (0..k).map(|_| full(10)).collect();
        assert!(mend_patch_sizes(&speeds, &assign, 16, 4).is_ok());
        let speeds = vec![1.0; k + 1];
        let assign: Vec<_> = (0..=k).map(|_| full(10)).collect();
        assert!(mend_patch_sizes(&speeds, &assign, 16, 4).is_err());
    }

    #[test]
    fn cost_aware_accounts_for_fixed_overhead() {
        use crate::device::CostModel;
        // Heavy imbalance: Eq. 5 (linear) gives the slow device more
        // rows than the affine-cost optimum; the cost-aware allocator
        // must shrink the slow device's patch.
        let cost = CostModel { fixed_s: 0.0034, per_row_s: 0.00024 };
        let speeds = [1.0, 0.4];
        let assign = [full(100), full(100)];
        let eq5 = mend_patch_sizes(&speeds, &assign, 32, 2).unwrap();
        let ca = cost_aware_sizes(&speeds, &assign, &cost, 32, 2).unwrap();
        assert_eq!(ca.iter().sum::<usize>(), 32);
        assert!(ca[1] < eq5[1], "cost-aware {ca:?} vs eq5 {eq5:?}");
        // And it actually reduces the bottleneck interval time.
        let t = |sizes: &[usize]| {
            (0..2)
                .map(|i| cost.step_time(sizes[i], speeds[i]))
                .fold(0.0, f64::max)
        };
        assert!(t(&ca) <= t(&eq5) + 1e-12);
    }

    #[test]
    fn cost_aware_equals_eq5_when_fixed_cost_vanishes() {
        use crate::device::CostModel;
        // With no fixed term the linear assumption is exact, so both
        // allocators agree (up to rounding ties).
        let cost = CostModel { fixed_s: 0.0, per_row_s: 0.001 };
        let speeds = [1.0, 0.5];
        let assign = [full(100), full(100)];
        let eq5 = mend_patch_sizes(&speeds, &assign, 32, 2).unwrap();
        let ca = cost_aware_sizes(&speeds, &assign, &cost, 32, 2).unwrap();
        assert!(
            (eq5[0] as i64 - ca[0] as i64).abs() <= 2,
            "{eq5:?} vs {ca:?}"
        );
    }

    #[test]
    fn cost_aware_respects_interval_steps_of_half_devices() {
        use crate::device::CostModel;
        use crate::config::StadiParams;
        // A Half device runs 1 step per interval vs the fast device's
        // 2 — the allocator must weigh that (a fast device's granule
        // costs double per interval).
        let cost = CostModel { fixed_s: 0.002, per_row_s: 0.0003 };
        let p = StadiParams::default();
        let speeds = [1.0, 0.5];
        let assign = assign_steps(&speeds, &p).unwrap();
        assert_eq!(assign[1].class, StepClass::Half);
        let ca = cost_aware_sizes(&speeds, &assign, &cost, 32, 2).unwrap();
        assert_eq!(ca.iter().sum::<usize>(), 32);
        // Fast device pays 2 steps per interval; slow pays 1 at half
        // speed — the slow device can afford a sizeable share.
        assert!(ca[1] >= 8, "{ca:?}");
    }

    #[test]
    fn comm_aware_flattens_sync_splits_but_not_displaced() {
        use crate::config::{CommConfig, HaloMode, UnevenStrategy};
        use crate::device::CostModel;
        let cost = CostModel { fixed_s: 0.002, per_row_s: 0.0005 };
        let speeds = [1.0, 0.4];
        let assign = [full(100), full(100)];
        let legacy =
            cost_aware_sizes(&speeds, &assign, &cost, 32, 2).unwrap();
        assert_eq!(legacy, vec![24, 8]);

        // Slow interconnect: under Pad, growing the largest patch
        // raises every interval's blocking gather — the sync-effective
        // split flattens toward the slow device.
        let slow = CommConfig {
            latency_s: 0.0,
            bandwidth_bytes_per_s: 1e5,
            uneven_strategy: UnevenStrategy::PadAllGather,
        };
        let sync = cost_aware_sizes_with_comm(
            &speeds,
            &assign,
            &cost,
            &slow,
            HaloMode::Sync,
            512,
            32,
            2,
        )
        .unwrap();
        assert_eq!(sync.iter().sum::<usize>(), 32);
        assert!(sync[0] < legacy[0], "sync {sync:?} vs legacy {legacy:?}");

        // Displaced hides the exchange: the comm term is identically
        // zero and the allocator reduces byte-identically to the
        // legacy cost-aware split even on the slow interconnect.
        let disp = cost_aware_sizes_with_comm(
            &speeds,
            &assign,
            &cost,
            &slow,
            HaloMode::Displaced { max_staleness: 1 },
            512,
            32,
            2,
        )
        .unwrap();
        assert_eq!(disp, legacy);

        // Near-free interconnect: the comm term is negligible and the
        // sync-effective split agrees with legacy too.
        let fast = CommConfig {
            latency_s: 0.0,
            bandwidth_bytes_per_s: 1e12,
            uneven_strategy: UnevenStrategy::PadAllGather,
        };
        let free = cost_aware_sizes_with_comm(
            &speeds,
            &assign,
            &cost,
            &fast,
            HaloMode::Sync,
            512,
            32,
            2,
        )
        .unwrap();
        assert_eq!(free, legacy);
    }

    /// Satellite: the Eq. 5 split at *non-native* sizes. For random
    /// registered-style resolutions (any granularity-aligned row
    /// count), random speeds and random granularities, the mend must
    /// conserve total rows, respect the granularity, and never hand a
    /// zero-row patch to an included (nonzero-speed, non-excluded)
    /// device — nor a nonzero patch to an excluded one.
    #[test]
    fn property_non_native_row_splits_conserve_rows() {
        let p = StadiParams::default();
        forall(
            67,
            300,
            |rng| {
                let gran_pick = rng.below(4) as usize; // 1 | 2 | 4 | 8
                let granules = 1 + rng.below(24) as usize;
                let n = 1 + rng.below(6) as usize;
                let speeds: Vec<f64> = (0..n)
                    .map(|_| 0.05 + 0.95 * rng.next_f64())
                    .collect();
                (gran_pick, (granules, speeds))
            },
            |&(gran_pick, (granules, ref speeds))| {
                let granularity = 1usize << gran_pick;
                let rows = granules * granularity;
                let Ok(assign) = assign_steps(speeds, &p) else {
                    return Ok(()); // infeasible speed vectors skip
                };
                let included: Vec<usize> = assign
                    .iter()
                    .enumerate()
                    .filter(|(_, a)| a.class != StepClass::Excluded)
                    .map(|(i, _)| i)
                    .collect();
                if included.len() > granules {
                    // More devices than granules: the mend must refuse
                    // rather than invent sub-granule patches.
                    ensure(
                        mend_patch_sizes(
                            speeds, &assign, rows, granularity,
                        )
                        .is_err(),
                        "oversubscribed latent accepted",
                    )?;
                    return Ok(());
                }
                let sizes =
                    mend_patch_sizes(speeds, &assign, rows, granularity)
                        .map_err(|e| e.to_string())?;
                ensure(
                    sizes.iter().sum::<usize>() == rows,
                    format!("rows not conserved: {sizes:?} != {rows}"),
                )?;
                for (i, &s) in sizes.iter().enumerate() {
                    ensure(
                        s % granularity == 0,
                        format!("granularity violated: {s}"),
                    )?;
                    let excluded =
                        assign[i].class == StepClass::Excluded;
                    if excluded {
                        ensure(s == 0, "excluded device got rows")?;
                    } else {
                        ensure(
                            s >= granularity,
                            format!(
                                "included device {i} (speed \
                                 {}) got a zero-row patch",
                                speeds[i]
                            ),
                        )?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_sum_granularity_floor_proportionality() {
        let p = StadiParams::default();
        forall(
            23,
            300,
            |rng| {
                let n = 1 + rng.below(7) as usize;
                (0..n)
                    .map(|_| 0.05 + 0.95 * rng.next_f64())
                    .collect::<Vec<f64>>()
            },
            |speeds| {
                let Ok(assign) = assign_steps(speeds, &p) else {
                    return Ok(());
                };
                let included =
                    assign.iter().filter(|a| a.steps > 0).count();
                if included > 8 {
                    return Ok(()); // more devices than granules
                }
                let sizes = mend_patch_sizes(speeds, &assign, 32, 4)
                    .map_err(|e| e.to_string())?;
                ensure(
                    sizes.iter().sum::<usize>() == 32,
                    format!("sum {:?} != 32", sizes),
                )?;
                for (i, &s) in sizes.iter().enumerate() {
                    ensure(s % 4 == 0, "granularity violated")?;
                    let excluded = assign[i].class == StepClass::Excluded;
                    ensure(
                        (s == 0) == excluded,
                        "zero rows iff excluded",
                    )?;
                }
                // Rounded sizes stay near the ideal shares: within one
                // granule normally; within two when the 1-granule floor
                // forces redistribution (tiny ideal shares).
                let ideal = ideal_shares(speeds, &assign, 32.0);
                let floor_active = ideal
                    .iter()
                    .zip(&assign)
                    .any(|(&id, a)| a.steps > 0 && id < 4.0);
                let tol = if floor_active { 8.0 } else { 4.0 };
                for (i, &s) in sizes.iter().enumerate() {
                    if assign[i].class != StepClass::Excluded {
                        ensure(
                            (s as f64 - ideal[i]).abs() <= tol + 1e-9,
                            format!(
                                "size {s} too far from ideal {}",
                                ideal[i]
                            ),
                        )?;
                    }
                }
                Ok(())
            },
        );
    }
}
