//! The paper's scheduling contribution: computation-aware step
//! allocation (temporal, Eq. 4), elastic patch-size mending (spatial,
//! Eq. 5), effective-speed profiling, the joint Algorithm-1 plan, and
//! the mid-flight re-planner (`replan`) that re-runs Eq. 4/5 over a
//! request's remaining steps at sync barriers.

pub mod plan;
pub mod profiler;
pub mod replan;
pub mod spatial;
pub mod temporal;

pub use plan::{DevicePlan, Plan, PlanCache, PlanCacheStats, PlanKey, StepSpec};
pub use profiler::Profiler;
pub use replan::{replan_at_sync, RePlan, RowMove};
pub use temporal::{normalize_warmup, StepAssignment, StepClass};
