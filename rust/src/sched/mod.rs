//! The paper's scheduling contribution: computation-aware step
//! allocation (temporal, Eq. 4), elastic patch-size mending (spatial,
//! Eq. 5), effective-speed profiling, and the joint Algorithm-1 plan.

pub mod plan;
pub mod profiler;
pub mod spatial;
pub mod temporal;

pub use plan::{DevicePlan, Plan, PlanCache, PlanCacheStats, PlanKey, StepSpec};
pub use profiler::Profiler;
pub use temporal::{normalize_warmup, StepAssignment, StepClass};
