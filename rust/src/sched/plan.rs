//! Joint spatio-temporal plan: what Algorithm 1 executes.
//!
//! `Plan::build` composes the profiler's effective speeds, Eq. 4
//! temporal assignment, and Eq. 5 patch mending into per-device step
//! programs with an aligned synchronization schedule:
//!
//! * every device's step list carries (t_from -> t_to) and precomputed
//!   DDIM coefficients from its own grid;
//! * a step is a **sync step** when its post-state timestep is shared
//!   by *all* included devices (the intersection of grids). The shared
//!   warmup prefix syncs every step (Alg. 1 lines 9-12); afterwards
//!   slow devices sync every step and fast devices every other step
//!   (lines 13-24) — exactly what the intersection rule yields for the
//!   2:1 LCM-minimizing quantization. (Grid convention: the warmup
//!   phase is the first M_warmup grid points; the M_warmup-th
//!   *transition* is the slow device's first doubled step, which keeps
//!   M_half = ½M_base + ½M_warmup exact and the final timesteps
//!   aligned.)
//! * the final step (to the clean sample) always syncs, producing the
//!   gathered output image.

use std::collections::BTreeSet;

use crate::config::StadiParams;
use crate::error::{Error, Result};
use crate::model::latents::{partition_rows, RowRange};
use crate::model::schedule::{DdimCoef, Schedule};
use crate::sched::spatial::{mend_patch_sizes, uniform_patch_sizes};
use crate::sched::temporal::{assign_steps, StepClass};

/// One local denoising step of a device's program.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepSpec {
    /// Local step index (0-based).
    pub index: usize,
    /// Timestep consumed by the model (eps_theta(x, t_from)).
    pub t_from: usize,
    /// Post-state timestep; None = clean sample (final step).
    pub t_to: Option<usize>,
    /// DDIM coefficients for this transition.
    pub coef: DdimCoef,
    /// Inside the shared warmup phase?
    pub is_warmup: bool,
    /// Publish fresh buffers + participate in the x all-gather after
    /// this step.
    pub sync: bool,
}

/// Per-device program.
#[derive(Debug, Clone)]
pub struct DevicePlan {
    pub device: usize,
    pub name: String,
    pub speed: f64,
    pub class: StepClass,
    pub rows: RowRange,
    pub steps: Vec<StepSpec>,
}

impl DevicePlan {
    pub fn included(&self) -> bool {
        self.class != StepClass::Excluded
    }

    /// Post-state timesteps of this device's sync steps, in order.
    pub fn sync_states(&self) -> Vec<Option<usize>> {
        self.steps.iter().filter(|s| s.sync).map(|s| s.t_to).collect()
    }
}

/// The joint plan for one request.
#[derive(Debug, Clone)]
pub struct Plan {
    pub devices: Vec<DevicePlan>,
    /// Shared ordered sync schedule (post-state timesteps; final None).
    pub sync_points: Vec<Option<usize>>,
    pub params: StadiParams,
}

impl Plan {
    /// Build the plan from normalized effective speeds.
    pub fn build(
        schedule: &Schedule,
        speeds: &[f64],
        names: &[String],
        params: &StadiParams,
        total_rows: usize,
        granularity: usize,
    ) -> Result<Plan> {
        if speeds.len() != names.len() {
            return Err(Error::Sched("speeds/names length mismatch".into()));
        }
        let assign = assign_steps(speeds, params)?;
        let sizes = if params.spatial {
            mend_patch_sizes(speeds, &assign, total_rows, granularity)?
        } else {
            uniform_patch_sizes(&assign, total_rows, granularity)?
        };
        Self::assemble_base(schedule, speeds, names, params, &assign, &sizes)
    }

    /// Build with the EXTENSION cost-aware allocator (affine step-cost
    /// model) in place of Eq. 5. See `spatial::cost_aware_sizes`.
    pub fn build_cost_aware(
        schedule: &Schedule,
        speeds: &[f64],
        names: &[String],
        params: &StadiParams,
        cost: &crate::device::CostModel,
        total_rows: usize,
        granularity: usize,
    ) -> Result<Plan> {
        let assign = assign_steps(speeds, params)?;
        let sizes = crate::sched::spatial::cost_aware_sizes(
            speeds, &assign, cost, total_rows, granularity,
        )?;
        Self::assemble_base(schedule, speeds, names, params, &assign, &sizes)
    }

    /// Build with the cost-aware allocator priced under the engine's
    /// comm config and halo mode (see
    /// [`crate::sched::spatial::cost_aware_sizes_with_comm`]):
    /// sync-effective plans account for the blocking per-interval x
    /// gather, displaced plans drop it — the latter is byte-identical
    /// to [`Plan::build_cost_aware`]. `bytes_per_row` is the x payload
    /// of one latent row at the planned width.
    #[allow(clippy::too_many_arguments)]
    pub fn build_cost_aware_with_comm(
        schedule: &Schedule,
        speeds: &[f64],
        names: &[String],
        params: &StadiParams,
        cost: &crate::device::CostModel,
        comm: &crate::config::CommConfig,
        halo: crate::config::HaloMode,
        bytes_per_row: usize,
        total_rows: usize,
        granularity: usize,
    ) -> Result<Plan> {
        let assign = assign_steps(speeds, params)?;
        let sizes = crate::sched::spatial::cost_aware_sizes_with_comm(
            speeds,
            &assign,
            cost,
            comm,
            halo,
            bytes_per_row,
            total_rows,
            granularity,
        )?;
        Self::assemble_base(schedule, speeds, names, params, &assign, &sizes)
    }

    /// Build with explicit patch sizes (Fig. 9's patch-ratio sweep and
    /// custom baselines). Temporal assignment still follows Eq. 4 /
    /// the `params.temporal` toggle; excluded devices must have size 0.
    pub fn build_with_sizes(
        schedule: &Schedule,
        speeds: &[f64],
        names: &[String],
        params: &StadiParams,
        sizes: &[usize],
    ) -> Result<Plan> {
        let assign = assign_steps(speeds, params)?;
        for (a, &s) in assign.iter().zip(sizes) {
            if (a.class == StepClass::Excluded) != (s == 0) {
                return Err(Error::Sched(
                    "size must be 0 exactly for excluded devices".into(),
                ));
            }
        }
        Self::assemble_base(schedule, speeds, names, params, &assign, sizes)
    }

    /// Continue a request mid-flight: assemble device programs over an
    /// explicit *fast-grid suffix* (the remaining timesteps from a
    /// sync barrier) instead of a fresh `ddim_grid`. Half-class
    /// devices run the
    /// [`crate::sched::temporal::requantize_suffix`] grid (every other
    /// point, both endpoints kept); no step is a warmup step (re-plans
    /// happen at or after the warmup barrier). `assign` carries the
    /// Eq. 4 classes at live speeds, `sizes` the Eq. 5 re-split;
    /// excluded devices must have size 0. Used by
    /// [`crate::sched::replan`].
    pub fn build_on_grid(
        schedule: &Schedule,
        fast_grid: &[usize],
        speeds: &[f64],
        names: &[String],
        params: &StadiParams,
        assign: &[crate::sched::temporal::StepAssignment],
        sizes: &[usize],
    ) -> Result<Plan> {
        if fast_grid.is_empty() {
            return Err(Error::Sched("empty fast suffix".into()));
        }
        if assign.len() != speeds.len() || sizes.len() != speeds.len() {
            return Err(Error::Sched(
                "assign/sizes/speeds length mismatch".into(),
            ));
        }
        for (a, &s) in assign.iter().zip(sizes) {
            if (a.class == StepClass::Excluded) != (s == 0) {
                return Err(Error::Sched(
                    "size must be 0 exactly for excluded devices".into(),
                ));
            }
        }
        let any_half =
            assign.iter().any(|a| a.class == StepClass::Half);
        let slow_suffix = if any_half {
            Some(crate::sched::temporal::requantize_suffix(fast_grid)?)
        } else {
            None
        };
        Self::assemble(
            schedule,
            speeds,
            names,
            params,
            assign,
            sizes,
            fast_grid,
            slow_suffix.as_deref(),
            0,
        )
    }

    /// Assemble from the params-derived grids (the static entry
    /// points).
    fn assemble_base(
        schedule: &Schedule,
        speeds: &[f64],
        names: &[String],
        params: &StadiParams,
        assign: &[crate::sched::temporal::StepAssignment],
        sizes: &[usize],
    ) -> Result<Plan> {
        let fast_grid = schedule.ddim_grid(params.m_base);
        let slow_grid =
            Schedule::stadi_slow_grid(&fast_grid, params.m_warmup);
        Self::assemble(
            schedule,
            speeds,
            names,
            params,
            assign,
            sizes,
            &fast_grid,
            Some(&slow_grid),
            params.m_warmup,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        schedule: &Schedule,
        speeds: &[f64],
        names: &[String],
        params: &StadiParams,
        assign: &[crate::sched::temporal::StepAssignment],
        sizes: &[usize],
        fast_grid: &[usize],
        slow_grid: Option<&[usize]>,
        warmup_len: usize,
    ) -> Result<Plan> {
        let ranges = partition_rows(sizes);

        // Post-state sets per included device, for the sync intersection.
        let grids: Vec<Option<&[usize]>> = assign
            .iter()
            .map(|a| match a.class {
                StepClass::Full => Some(fast_grid),
                StepClass::Half => slow_grid,
                StepClass::Excluded => None,
            })
            .collect();
        if assign.iter().any(|a| a.class == StepClass::Half)
            && slow_grid.is_none()
        {
            return Err(Error::Sched(
                "Half-class device without a slow grid".into(),
            ));
        }
        let mut common: Option<BTreeSet<usize>> = None;
        for g in grids.iter().flatten() {
            // Post-states of a grid are all points except the first.
            let states: BTreeSet<usize> = g[1..].iter().cloned().collect();
            common = Some(match common {
                None => states,
                Some(c) => c.intersection(&states).cloned().collect(),
            });
        }
        let common = common
            .ok_or_else(|| Error::Sched("no included devices".into()))?;

        let mut devices = Vec::with_capacity(speeds.len());
        for (i, a) in assign.iter().enumerate() {
            let grid: &[usize] = match a.class {
                StepClass::Full => fast_grid,
                StepClass::Half => slow_grid.unwrap(),
                StepClass::Excluded => &[],
            };
            let coefs = schedule.grid_coefficients(grid);
            let steps: Vec<StepSpec> = grid
                .iter()
                .enumerate()
                .map(|(k, &t_from)| {
                    let t_to = grid.get(k + 1).copied();
                    StepSpec {
                        index: k,
                        t_from,
                        t_to,
                        coef: coefs[k],
                        is_warmup: k < warmup_len,
                        // Final step (None) always syncs; otherwise the
                        // post-state must be common to all devices.
                        sync: match t_to {
                            None => true,
                            Some(t) => common.contains(&t),
                        },
                    }
                })
                .collect();
            devices.push(DevicePlan {
                device: i,
                name: names[i].clone(),
                speed: speeds[i],
                class: a.class,
                rows: ranges[i],
                steps,
            });
        }

        // The shared sync schedule, from any included device.
        let sync_points = devices
            .iter()
            .find(|d| d.included())
            .unwrap()
            .sync_states();

        let plan = Plan { devices, sync_points, params: params.clone() };
        plan.check_alignment()?;
        Ok(plan)
    }

    /// Invariant: every included device sees the identical ordered
    /// sequence of sync post-states.
    fn check_alignment(&self) -> Result<()> {
        for d in self.devices.iter().filter(|d| d.included()) {
            let s = d.sync_states();
            if s != self.sync_points {
                return Err(Error::Sched(format!(
                    "device {} sync schedule diverges: {:?} vs {:?}",
                    d.name,
                    &s[..s.len().min(5)],
                    &self.sync_points[..self.sync_points.len().min(5)]
                )));
            }
        }
        if self.sync_points.last() != Some(&None) {
            return Err(Error::Sched("final sync must be the clean state".into()));
        }
        Ok(())
    }

    pub fn included_devices(&self) -> impl Iterator<Item = &DevicePlan> {
        self.devices.iter().filter(|d| d.included())
    }

    /// Number of leading sync intervals that contain a warmup step.
    /// Re-plan suffixes built via [`Plan::build_on_grid`] carry no
    /// warmup steps, so this is 0 there — the displaced fallback rule
    /// stays plan-local either way.
    pub fn warmup_sync_count(&self) -> usize {
        let Some(d) = self.included_devices().next() else {
            return 0;
        };
        let mut count = 0;
        let mut any_warmup = false;
        for s in &d.steps {
            any_warmup |= s.is_warmup;
            if s.sync {
                if any_warmup {
                    count += 1;
                }
                any_warmup = false;
            }
        }
        count
    }

    /// Whether sync interval `si` (plan-local index into
    /// `sync_points`) must run the *blocking* exchange under a
    /// displaced halo with the given staleness budget. True for:
    /// budget 0 (≡ sync), warmup intervals (the paper's all-sync
    /// prefix), the first `budget` intervals (nothing old enough has
    /// been published yet), and the final interval (the gathered clean
    /// image must assemble from fresh buffers). The executors, the
    /// timeline and the byte accounting all route through this one
    /// rule so they cannot drift apart.
    pub fn displaced_fallback(&self, si: usize, budget: usize) -> bool {
        budget == 0
            || si < budget
            || si < self.warmup_sync_count()
            || si + 1 >= self.sync_points.len()
    }

    /// Total latent rows (for sanity checks).
    pub fn total_rows(&self) -> usize {
        self.devices.iter().map(|d| d.rows.rows).sum()
    }

    /// Whether two plans can run in **lockstep** as one fused batch:
    /// identical ordered sync schedules (so every barrier lines up),
    /// identical device sets, and identical row splits (so a batched
    /// step launches one kernel shape per device). This is the
    /// executable form of the batching compatibility rule — the
    /// serve-side `FuseKey` (same resolution, step grid, halo budget)
    /// is chosen so that compatible requests resolve to the *same*
    /// `PlanKey` and therefore trivially satisfy this; the predicate
    /// exists so fused execution can assert it rather than assume it.
    pub fn fuses_with(&self, other: &Plan) -> bool {
        self.sync_points == other.sync_points
            && self.devices.len() == other.devices.len()
            && self
                .devices
                .iter()
                .zip(&other.devices)
                .all(|(a, b)| {
                    a.device == b.device
                        && a.class == b.class
                        && a.rows == b.rows
                        && a.steps.len() == b.steps.len()
                })
    }

    /// Human-readable summary (used by `stadi plan`).
    pub fn describe(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "plan: M_base={} M_warmup={} a={} b={} TA={} SA={} syncs={}\n",
            self.params.m_base,
            self.params.m_warmup,
            self.params.a,
            self.params.b,
            self.params.temporal,
            self.params.spatial,
            self.sync_points.len()
        ));
        for d in &self.devices {
            s.push_str(&format!(
                "  {}: v={:.3} class={:?} steps={} rows=[{}..{})\n",
                d.name,
                d.speed,
                d.class,
                d.steps.len(),
                d.rows.row0,
                d.rows.end()
            ));
        }
        s
    }
}

// --- Plan cache -----------------------------------------------------

/// Cache key for one plan shape: request parameters + device subset +
/// quantized speeds. Speeds are quantized (1/1024) so the profiler's
/// per-request jitter doesn't defeat the cache; a hit may therefore
/// return a plan computed from speeds up to one quantum away — well
/// inside the noise of the estimates themselves. Thresholds are keyed
/// by their f64 bits (they are config constants, never computed).
///
/// `res` names a non-native execution resolution (latent h, w);
/// native-resolution keys carry `None`, so the default-spec path and
/// the spec path produce identical keys and the cache stays warm
/// across the multi-resolution upgrade. Today's builders derive the
/// split from `rows` alone (so two widths at the same row count build
/// identical plans and keying them separately costs a few duplicate
/// entries in a bounded cache); width is keyed *deliberately* —
/// width-aware cost models shift the fixed-vs-per-row balance, which
/// changes cost-aware splits, and a silently shared cache entry would
/// then serve wrong plans across widths.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub m_base: usize,
    pub m_warmup: usize,
    pub a_bits: u64,
    pub b_bits: u64,
    pub temporal: bool,
    pub spatial: bool,
    pub cost_aware: bool,
    pub rows: usize,
    pub devices: Vec<usize>,
    pub speeds_q: Vec<u32>,
    pub res: Option<(usize, usize)>,
    /// Effective halo mode the plan was built under. Keyed because
    /// the comm-aware Eq. 5 variant splits rows differently when the
    /// displaced exchange hides the x transfer; `Sync` is the
    /// constructor default, so pre-halo keys are unchanged.
    pub halo: crate::config::HaloMode,
}

impl PlanKey {
    pub fn new(
        params: &StadiParams,
        rows: usize,
        devices: &[usize],
        speeds: &[f64],
    ) -> PlanKey {
        PlanKey {
            m_base: params.m_base,
            m_warmup: params.m_warmup,
            a_bits: params.a.to_bits(),
            b_bits: params.b.to_bits(),
            temporal: params.temporal,
            spatial: params.spatial,
            cost_aware: params.cost_aware,
            rows,
            devices: devices.to_vec(),
            speeds_q: speeds.iter().map(|&v| quantize_speed(v)).collect(),
            res: None,
            halo: crate::config::HaloMode::Sync,
        }
    }

    /// Attach a non-native resolution to the key (`None` = native —
    /// the constructor's default, so existing native call sites are
    /// untouched).
    pub fn with_res(mut self, res: Option<(usize, usize)>) -> PlanKey {
        self.res = res;
        self
    }

    /// Attach the effective halo mode (`Sync` = the constructor's
    /// default, so existing call sites are untouched).
    pub fn with_halo(mut self, halo: crate::config::HaloMode) -> PlanKey {
        self.halo = halo;
        self
    }
}

/// Speed quantum for cache keys (see [`PlanKey`]).
pub fn quantize_speed(v: f64) -> u32 {
    (v.clamp(0.0, 4.0) * 1024.0).round() as u32
}

/// Cumulative hit/miss counters of a [`PlanCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    pub hits: u64,
    pub misses: u64,
}

struct PlanCacheInner {
    map: std::collections::HashMap<PlanKey, Plan>,
    /// Insertion order, for bounded FIFO eviction.
    order: std::collections::VecDeque<PlanKey>,
    /// Bumped by `clear()`. A build started against inputs read before
    /// a clear (e.g. the pre-calibrate cost model) must not be
    /// inserted after it — the key wouldn't change, so the stale plan
    /// would otherwise be served until eviction.
    epoch: u64,
    stats: PlanCacheStats,
}

/// Small keyed plan cache: repeated request shapes skip the Eq. 4/5
/// pass (and the sync-schedule assembly) entirely. Bounded FIFO — the
/// working set is "shapes currently in the traffic mix", tiny by
/// construction. The planner runs *outside* the lock on a miss, so a
/// slow cost-aware build never blocks concurrent lookups; two threads
/// racing the same cold key just build twice (idempotent).
pub struct PlanCache {
    capacity: usize,
    inner: std::sync::Mutex<PlanCacheInner>,
}

impl PlanCache {
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            capacity: capacity.max(1),
            inner: std::sync::Mutex::new(PlanCacheInner {
                map: std::collections::HashMap::new(),
                order: std::collections::VecDeque::new(),
                epoch: 0,
                stats: PlanCacheStats::default(),
            }),
        }
    }

    /// Current epoch. Callers snapshot this *before* reading the
    /// inputs their plan derives from (cluster, cost model) and pass
    /// it to [`Self::get_or_build_at`], so a concurrent `clear()`
    /// between snapshot and insert fences the stale plan out.
    pub fn epoch(&self) -> u64 {
        self.inner.lock().unwrap().epoch
    }

    /// Fetch the plan for `key`, building and inserting it on a miss.
    /// Convenience wrapper for callers whose build inputs are read
    /// inside `build` itself (no snapshot taken earlier).
    pub fn get_or_build(
        &self,
        key: PlanKey,
        build: impl FnOnce() -> Result<Plan>,
    ) -> Result<Plan> {
        let epoch = self.epoch();
        self.get_or_build_at(epoch, key, build)
    }

    /// Fetch the plan for `key`, building and inserting it on a miss.
    ///
    /// The build runs unlocked; the result is inserted only if no
    /// `clear()` happened since `input_epoch` was captured — a plan
    /// built from pre-clear inputs (e.g. the pre-calibrate cost model)
    /// is still *returned* to its caller, whose snapshot it matches,
    /// but never cached for later requests.
    pub fn get_or_build_at(
        &self,
        input_epoch: u64,
        key: PlanKey,
        build: impl FnOnce() -> Result<Plan>,
    ) -> Result<Plan> {
        {
            let mut g = self.inner.lock().unwrap();
            if let Some(p) = g.map.get(&key) {
                g.stats.hits += 1;
                return Ok(p.clone());
            }
            g.stats.misses += 1;
        }
        let plan = build()?;
        let mut g = self.inner.lock().unwrap();
        if g.epoch == input_epoch && !g.map.contains_key(&key) {
            if g.map.len() >= self.capacity {
                if let Some(old) = g.order.pop_front() {
                    g.map.remove(&old);
                }
            }
            g.order.push_back(key.clone());
            g.map.insert(key, plan.clone());
        }
        Ok(plan)
    }

    /// Drop every cached plan (after `calibrate` swaps the cost model
    /// the cost-aware allocator depends on) and fence out in-flight
    /// builds started before the clear.
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.map.clear();
        g.order.clear();
        g.epoch += 1;
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> PlanCacheStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{ensure, forall};

    fn sched() -> Schedule {
        Schedule::scaled_linear(1000, 0.00085, 0.012)
    }

    fn build(speeds: &[f64], params: &StadiParams) -> Result<Plan> {
        let names: Vec<String> =
            (0..speeds.len()).map(|i| format!("g{i}")).collect();
        Plan::build(&sched(), speeds, &names, params, 32, 4)
    }

    #[test]
    fn homogeneous_two_gpu_plan() {
        let p = StadiParams::default();
        let plan = build(&[1.0, 1.0], &p).unwrap();
        assert_eq!(plan.total_rows(), 32);
        assert_eq!(plan.devices[0].rows.rows, 16);
        // Same grid => every step syncs.
        assert_eq!(plan.sync_points.len(), 100);
        for d in &plan.devices {
            assert!(d.steps.iter().all(|s| s.sync));
        }
    }

    #[test]
    fn heterogeneous_plan_alternates_fast_syncs() {
        let p = StadiParams::default();
        let plan = build(&[1.0, 0.5], &p).unwrap();
        let fast = &plan.devices[0];
        let slow = &plan.devices[1];
        assert_eq!(fast.steps.len(), 100);
        assert_eq!(slow.steps.len(), 52);
        // Slow device syncs every step (its states are the common set).
        assert!(slow.steps.iter().all(|s| s.sync));
        // Fast device: the shared warmup prefix syncs (the M_warmup-th
        // transition is the slow device's first doubled step, so the
        // fast device's step 3 post-state fast[4] is NOT common); then
        // every other step starting at step 4; the final step (clean)
        // always syncs.
        for s in &fast.steps[..3] {
            assert!(s.sync && s.is_warmup);
        }
        assert!(!fast.steps[3].sync);
        for (k, s) in fast.steps[4..99].iter().enumerate() {
            assert_eq!(s.sync, k % 2 == 0, "step {}", k + 4);
        }
        assert!(fast.steps[99].sync && fast.steps[99].t_to.is_none());
        // Shared schedule length equals the slow device's step count.
        assert_eq!(plan.sync_points.len(), 52);
        assert_eq!(*plan.sync_points.last().unwrap(), None);
    }

    #[test]
    fn excluded_device_has_no_steps_or_rows() {
        let p = StadiParams::default();
        let plan = build(&[1.0, 0.2], &p).unwrap();
        assert_eq!(plan.devices[1].steps.len(), 0);
        assert_eq!(plan.devices[1].rows.rows, 0);
        assert_eq!(plan.devices[0].rows.rows, 32);
    }

    #[test]
    fn ta_disabled_gives_uniform_grids() {
        let mut p = StadiParams::default();
        p.temporal = false;
        let plan = build(&[1.0, 0.5], &p).unwrap();
        assert_eq!(plan.devices[0].steps.len(), 100);
        assert_eq!(plan.devices[1].steps.len(), 100);
        assert_eq!(plan.sync_points.len(), 100);
        // SA still balances rows.
        assert!(plan.devices[0].rows.rows > plan.devices[1].rows.rows);
    }

    #[test]
    fn sa_disabled_gives_uniform_rows() {
        let mut p = StadiParams::default();
        p.spatial = false;
        let plan = build(&[1.0, 0.5], &p).unwrap();
        assert_eq!(plan.devices[0].rows.rows, 16);
        assert_eq!(plan.devices[1].rows.rows, 16);
        // TA still halves steps.
        assert_eq!(plan.devices[1].steps.len(), 52);
    }

    #[test]
    fn coefficients_match_grid_transitions() {
        let p = StadiParams::default();
        let plan = build(&[1.0, 0.5], &p).unwrap();
        let s = sched();
        for d in plan.included_devices() {
            for st in &d.steps {
                let want = s.ddim_coefficients(st.t_from, st.t_to);
                assert_eq!(st.coef, want);
            }
        }
    }

    #[test]
    fn plan_cache_hits_reuse_and_evictions_bound_memory() {
        let p = StadiParams::default();
        let cache = PlanCache::new(2);
        let mut builds = 0usize;
        let mut get = |speeds: &[f64], builds: &mut usize| {
            let key = PlanKey::new(&p, 32, &[0, 1], speeds);
            cache
                .get_or_build(key, || {
                    *builds += 1;
                    build(speeds, &p)
                })
                .unwrap()
        };
        let a = get(&[1.0, 0.5], &mut builds);
        let b = get(&[1.0, 0.5], &mut builds);
        assert_eq!(builds, 1, "identical shape must hit");
        assert_eq!(a.total_rows(), b.total_rows());
        // Sub-quantum speed jitter still hits (the cache's point).
        get(&[1.0, 0.5001], &mut builds);
        assert_eq!(builds, 1);
        // Distinct shapes miss; capacity 2 evicts the oldest.
        get(&[1.0, 0.6], &mut builds);
        get(&[1.0, 0.7], &mut builds);
        assert_eq!(builds, 3);
        assert_eq!(cache.len(), 2);
        get(&[1.0, 0.5], &mut builds); // evicted above -> rebuild
        assert_eq!(builds, 4);
        let s = cache.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 4);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn plan_cache_key_separates_request_shapes() {
        let p = StadiParams::default();
        let k = |params: &StadiParams, rows, devs: &[usize], sp: &[f64]| {
            PlanKey::new(params, rows, devs, sp)
        };
        let base = k(&p, 32, &[0, 1], &[1.0, 0.5]);
        assert_ne!(base, k(&p.for_steps(50), 32, &[0, 1], &[1.0, 0.5]));
        assert_ne!(base, k(&p, 16, &[0, 1], &[1.0, 0.5]));
        assert_ne!(base, k(&p, 32, &[0, 2], &[1.0, 0.5]));
        assert_ne!(base, k(&p, 32, &[0, 1], &[1.0, 0.8]));
        assert_eq!(base, k(&p, 32, &[0, 1], &[1.0, 0.5]));
        // Resolutions separate otherwise-identical shapes (two sizes
        // with the same row count but different widths), and the
        // native attachment (None) is the constructor default, so
        // pre-multi-resolution native keys are unchanged.
        let wide = base.clone().with_res(Some((32, 64)));
        assert_ne!(base, wide);
        assert_ne!(wide, base.clone().with_res(Some((32, 32))));
        assert_eq!(base, base.clone().with_res(None));
        // Halo modes separate keys too (displaced plans may split rows
        // differently); Sync is the constructor default.
        use crate::config::HaloMode;
        let displaced = base
            .clone()
            .with_halo(HaloMode::Displaced { max_staleness: 2 });
        assert_ne!(base, displaced);
        assert_ne!(
            displaced,
            base.clone().with_halo(HaloMode::Displaced { max_staleness: 1 })
        );
        assert_eq!(base, base.clone().with_halo(HaloMode::Sync));
    }

    #[test]
    fn fuses_with_requires_identical_lockstep_shape() {
        let p = StadiParams::default();
        let a = build(&[1.0, 0.5], &p).unwrap();
        // Same shape (rebuilt) fuses; a plan always fuses with itself.
        assert!(a.fuses_with(&a));
        assert!(a.fuses_with(&build(&[1.0, 0.5], &p).unwrap()));
        // Different speeds -> different rows/grids -> no fuse.
        assert!(!a.fuses_with(&build(&[1.0, 1.0], &p).unwrap()));
        // Different step budget -> different sync schedule -> no fuse.
        assert!(!a.fuses_with(&build(&[1.0, 0.5], &p.for_steps(50)).unwrap()));
        // Different device count -> no fuse.
        assert!(!a.fuses_with(&build(&[1.0], &p).unwrap()));
    }

    #[test]
    fn displaced_fallback_covers_warmup_prefix_and_final() {
        let p = StadiParams::default(); // m_base 100, m_warmup 4
        let plan = build(&[1.0, 0.5], &p).unwrap();
        // Heterogeneous plan: the fast device's 4th (non-sync) warmup
        // step lands in the 4th sync interval, so 4 intervals carry
        // warmup steps (see heterogeneous_plan_alternates_fast_syncs).
        assert_eq!(plan.warmup_sync_count(), 4);
        let n = plan.sync_points.len();
        let budget = 2;
        // Warmup prefix and the first `budget` intervals fall back.
        for si in 0..plan.warmup_sync_count().max(budget) {
            assert!(plan.displaced_fallback(si, budget), "si={si}");
        }
        // Steady-state intervals displace.
        assert!(!plan.displaced_fallback(4, budget));
        assert!(!plan.displaced_fallback(n - 2, budget));
        // The final (clean-state) interval always falls back.
        assert!(plan.displaced_fallback(n - 1, budget));
        // Budget 0 is sync everywhere.
        for si in 0..n {
            assert!(plan.displaced_fallback(si, 0));
        }
        // A homogeneous plan has warmup syncs too (every step syncs).
        let homo = build(&[1.0, 1.0], &p).unwrap();
        assert_eq!(homo.warmup_sync_count(), p.m_warmup);
    }

    #[test]
    fn clear_fences_out_builds_started_before_it() {
        // A build racing a clear(): epoch captured pre-clear must not
        // insert its (stale-input) plan, but still returns it.
        let cache = PlanCache::new(4);
        let p = StadiParams::default();
        let key = PlanKey::new(&p, 32, &[0], &[1.0]);
        let epoch = cache.epoch();
        cache.clear(); // concurrent calibrate between snapshot & build
        let plan = cache
            .get_or_build_at(epoch, key.clone(), || build(&[1.0], &p))
            .unwrap();
        assert_eq!(plan.total_rows(), 32);
        assert!(cache.is_empty(), "stale-epoch plan was cached");
        // A fresh-epoch build for the same key caches normally.
        cache.get_or_build(key, || build(&[1.0], &p)).unwrap();
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cache_build_errors_are_not_cached() {
        let cache = PlanCache::new(4);
        let p = StadiParams::default();
        let key = PlanKey::new(&p, 32, &[0], &[1.0]);
        let e = cache.get_or_build(key.clone(), || {
            Err(crate::error::Error::Sched("boom".into()))
        });
        assert!(e.is_err());
        assert!(cache.is_empty());
        // The same key builds successfully afterwards.
        cache.get_or_build(key, || build(&[1.0], &p)).unwrap();
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn property_plan_invariants() {
        let p = StadiParams::default();
        forall(
            31,
            200,
            |rng| {
                let n = 1 + rng.below(6) as usize;
                (0..n)
                    .map(|_| 0.05 + 0.95 * rng.next_f64())
                    .collect::<Vec<f64>>()
            },
            |speeds| {
                let plan = match build(speeds, &p) {
                    Ok(pl) => pl,
                    Err(_) => return Ok(()), // infeasible configs skip
                };
                ensure(plan.total_rows() == 32, "rows != 32")?;
                // Aligned sync schedules (check_alignment ran, but
                // re-verify the public invariant).
                for d in plan.included_devices() {
                    ensure(
                        d.sync_states() == plan.sync_points,
                        "sync misalignment",
                    )?;
                    // Between consecutive syncs a device runs at most 2
                    // steps (Alg. 1's fast-device alternation bound).
                    let mut run = 0;
                    for s in &d.steps {
                        run += 1;
                        if s.sync {
                            ensure(
                                run <= 2,
                                format!("{run} steps without sync"),
                            )?;
                            run = 0;
                        }
                    }
                    ensure(run == 0, "program must end on a sync")?;
                    // Grid timesteps strictly decrease.
                    for w in d.steps.windows(2) {
                        ensure(
                            w[1].t_from < w[0].t_from,
                            "non-decreasing grid",
                        )?;
                    }
                }
                Ok(())
            },
        );
    }
}
