//! Serving-level queueing simulation (discrete-event).
//!
//! The paper optimizes single-request latency; a serving deployment
//! cares how that translates under load. This module runs an M/G/c
//! open-loop simulation on the `des` substrate: Poisson arrivals into
//! the router's FIFO queue, up to `servers` requests in service at
//! once (the server's worker pool; `servers = 1` is the classic
//! single-flight M/G/1), service time = the scheduler's simulated
//! end-to-end latency. Comparing STADI vs patch parallelism service
//! times shows how scheduler-level gains compound into queueing gains
//! (shorter service -> lower utilization -> much shorter waits near
//! saturation), and sweeping `servers` shows what the concurrent
//! serve stack buys once requests can overlap.

use std::collections::{HashMap, VecDeque};

use crate::des::Sim;
use crate::fleet::{FleetManager, GangPolicy, GpuLease, PolicyCtx};
use crate::util::rng::Pcg32;
use crate::util::stats;

/// One simulated request's timeline.
#[derive(Debug, Clone, Copy)]
pub struct RequestTrace {
    pub arrival_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
}

impl RequestTrace {
    pub fn wait_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }

    pub fn sojourn_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Aggregate results of one run.
#[derive(Debug, Clone)]
pub struct QueueStats {
    pub traces: Vec<RequestTrace>,
    /// rho = lambda * E[S] / c.
    pub offered_load: f64,
    pub mean_wait_s: f64,
    pub mean_sojourn_s: f64,
    pub p95_sojourn_s: f64,
    pub max_queue_len: usize,
    pub throughput_rps: f64,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival(usize),
    Departure(usize),
}

/// Single-flight convenience: M/G/1 (`servers = 1`).
pub fn simulate_open_loop(
    rate_rps: f64,
    n_requests: usize,
    service_s: &[f64],
    seed: u64,
) -> QueueStats {
    simulate_open_loop_servers(rate_rps, n_requests, service_s, 1, seed)
}

/// Simulate `n_requests` Poisson(`rate_rps`) arrivals served FIFO by
/// `servers` parallel workers; request i's service time is
/// `service_s[i % len]`. Deterministic for a seed.
pub fn simulate_open_loop_servers(
    rate_rps: f64,
    n_requests: usize,
    service_s: &[f64],
    servers: usize,
    seed: u64,
) -> QueueStats {
    assert!(rate_rps > 0.0 && !service_s.is_empty() && servers > 0);
    let mut rng = Pcg32::new(seed);
    let mut sim: Sim<Ev> = Sim::new();

    // Pre-draw arrival times (exponential gaps).
    let mut t = 0.0;
    for i in 0..n_requests {
        let u: f64 = 1.0 - rng.next_f64();
        t += -u.ln() / rate_rps;
        sim.schedule(t, Ev::Arrival(i));
    }

    let svc = |i: usize| service_s[i % service_s.len()];
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut in_service = 0usize;
    let mut arrival = vec![f64::NAN; n_requests];
    let mut start = vec![f64::NAN; n_requests];
    let mut finish = vec![f64::NAN; n_requests];
    let mut max_q = 0usize;

    sim.run(|sim, now, ev| {
        match ev {
            Ev::Arrival(i) => {
                arrival[i] = now;
                if in_service < servers {
                    in_service += 1;
                    start[i] = now;
                    sim.schedule_in(svc(i), Ev::Departure(i));
                } else {
                    queue.push_back(i);
                    max_q = max_q.max(queue.len());
                }
            }
            Ev::Departure(i) => {
                finish[i] = now;
                if let Some(j) = queue.pop_front() {
                    start[j] = now;
                    sim.schedule_in(svc(j), Ev::Departure(j));
                } else {
                    in_service -= 1;
                }
            }
        }
        true
    });

    let traces: Vec<RequestTrace> = (0..n_requests)
        .filter(|&i| finish[i].is_finite())
        .map(|i| RequestTrace {
            arrival_s: arrival[i],
            start_s: start[i],
            finish_s: finish[i],
        })
        .collect();

    let waits: Vec<f64> = traces.iter().map(RequestTrace::wait_s).collect();
    let soj: Vec<f64> = traces.iter().map(RequestTrace::sojourn_s).collect();
    let mean_service = stats::mean(
        &traces
            .iter()
            .map(|t| t.finish_s - t.start_s)
            .collect::<Vec<_>>(),
    );
    let total = traces
        .iter()
        .map(|t| t.finish_s)
        .fold(0.0f64, f64::max);
    QueueStats {
        offered_load: rate_rps * mean_service / servers as f64,
        mean_wait_s: stats::mean(&waits),
        mean_sojourn_s: stats::mean(&soj),
        p95_sojourn_s: stats::percentile(&soj, 95.0),
        max_queue_len: max_q,
        throughput_rps: if total > 0.0 {
            traces.len() as f64 / total
        } else {
            0.0
        },
        traces,
    }
}

// --- Gang-policy fleet simulation -----------------------------------

/// One granted lease in simulated time (for disjointness audits and
/// utilization plots).
#[derive(Debug, Clone)]
pub struct LeaseTrace {
    pub start_s: f64,
    pub finish_s: f64,
    pub devices: Vec<usize>,
}

/// Aggregate results of one gang-policy serving simulation.
#[derive(Debug, Clone)]
pub struct GangSimStats {
    pub policy: String,
    pub completed: usize,
    /// Requests the policy granted a gang the planner rejected.
    pub failed: usize,
    pub throughput_rps: f64,
    pub mean_service_s: f64,
    pub mean_sojourn_s: f64,
    pub p95_sojourn_s: f64,
    pub mean_gang_size: f64,
    pub max_in_flight: usize,
    /// Every granted lease with its lifetime (completed requests).
    pub leases: Vec<LeaseTrace>,
}

#[derive(Debug, Clone, Copy)]
enum FleetEv {
    Arrival(usize),
    Departure(usize),
}

/// Simulate `n_requests` Poisson(`rate_rps`) arrivals served FIFO on a
/// partitioned fleet: each request leases a gang chosen by `policy`
/// (through the real [`FleetManager`] ledger, so grants are disjoint
/// by construction) and holds it for `latency_of(gang)` simulated
/// seconds. This is how the latency-vs-throughput tradeoff of a gang
/// policy is measured offline before a deploy: `latency_of` is
/// typically `Plan::build` + `timeline::simulate` over the candidate
/// subset. Deterministic per seed.
pub fn simulate_gang_policy(
    rate_rps: f64,
    n_requests: usize,
    speeds: &[f64],
    policy: &dyn GangPolicy,
    latency_of: &dyn Fn(&[usize]) -> Option<f64>,
    seed: u64,
) -> GangSimStats {
    assert!(rate_rps > 0.0 && !speeds.is_empty());
    let mut rng = Pcg32::new(seed);
    let mut sim: Sim<FleetEv> = Sim::new();
    let mut t = 0.0;
    for i in 0..n_requests {
        let u: f64 = 1.0 - rng.next_f64();
        t += -u.ln() / rate_rps;
        sim.schedule(t, FleetEv::Arrival(i));
    }

    let mut st = FleetSimState {
        fleet: FleetManager::new(speeds.len()),
        policy,
        speeds,
        latency_of,
        pending: VecDeque::new(),
        held: HashMap::new(),
        start: vec![f64::NAN; n_requests],
        gangs: vec![Vec::new(); n_requests],
        failed: 0,
    };
    let mut arrival = vec![f64::NAN; n_requests];
    let mut finish = vec![f64::NAN; n_requests];
    let mut max_in_flight = 0usize;

    sim.run(|sim, now, ev| {
        match ev {
            FleetEv::Arrival(i) => {
                arrival[i] = now;
                st.pending.push_back(i);
            }
            FleetEv::Departure(i) => {
                finish[i] = now;
                st.held.remove(&i); // lease drops: devices freed
            }
        }
        st.admit(sim, now);
        max_in_flight = max_in_flight.max(st.held.len());
        true
    });

    let done: Vec<usize> =
        (0..n_requests).filter(|&i| finish[i].is_finite()).collect();
    let services: Vec<f64> =
        done.iter().map(|&i| finish[i] - st.start[i]).collect();
    let sojourns: Vec<f64> =
        done.iter().map(|&i| finish[i] - arrival[i]).collect();
    let sizes: Vec<f64> =
        done.iter().map(|&i| st.gangs[i].len() as f64).collect();
    let total = done
        .iter()
        .map(|&i| finish[i])
        .fold(0.0f64, f64::max);
    GangSimStats {
        policy: policy.name(),
        completed: done.len(),
        failed: st.failed,
        throughput_rps: if total > 0.0 {
            done.len() as f64 / total
        } else {
            0.0
        },
        mean_service_s: stats::mean(&services),
        mean_sojourn_s: stats::mean(&sojourns),
        p95_sojourn_s: stats::percentile(&sojourns, 95.0),
        mean_gang_size: stats::mean(&sizes),
        max_in_flight,
        leases: done
            .iter()
            .map(|&i| LeaseTrace {
                start_s: st.start[i],
                finish_s: finish[i],
                devices: st.gangs[i].clone(),
            })
            .collect(),
    }
}

/// Mutable state of one fleet simulation run (bundled so the admit
/// loop is a method rather than a 10-argument function).
struct FleetSimState<'a> {
    fleet: FleetManager,
    policy: &'a dyn GangPolicy,
    speeds: &'a [f64],
    latency_of: &'a dyn Fn(&[usize]) -> Option<f64>,
    pending: VecDeque<usize>,
    held: HashMap<usize, GpuLease>,
    start: Vec<f64>,
    gangs: Vec<Vec<usize>>,
    failed: usize,
}

impl FleetSimState<'_> {
    /// Admit as many queued requests (FIFO) as the policy + free set
    /// allow right now.
    fn admit(&mut self, sim: &mut Sim<FleetEv>, now: f64) {
        while let Some(&head) = self.pending.front() {
            let free = self.fleet.free_devices();
            if free.is_empty() {
                break;
            }
            let ctx = PolicyCtx {
                speeds: self.speeds,
                queue_depth: self.pending.len() - 1,
                in_flight: self.fleet.in_flight(),
                predict: Some(self.latency_of),
            };
            let Some(gang) = self.policy.choose(&free, &ctx) else {
                break; // policy waits (e.g. AllGpus with gaps)
            };
            let Ok(Some(lease)) = self.fleet.try_acquire(&gang) else {
                break; // defensive: policy chose a busy device
            };
            let Some(svc) = (self.latency_of)(lease.devices()) else {
                // Unplannable gang: fail the request rather than wedge
                // the FIFO head forever.
                self.pending.pop_front();
                self.failed += 1;
                continue; // lease drops here, devices return
            };
            self.pending.pop_front();
            self.start[head] = now;
            self.gangs[head] = lease.devices().to_vec();
            self.held.insert(head, lease);
            sim.schedule_in(svc, FleetEv::Departure(head));
        }
    }
}

/// Audit a lease trace: no two leases that overlap in time may share a
/// device. Returns the number of overlapping pairs checked.
pub fn assert_leases_disjoint(leases: &[LeaseTrace]) -> usize {
    let mut checked = 0;
    for (a, b) in leases
        .iter()
        .enumerate()
        .flat_map(|(i, a)| leases[i + 1..].iter().map(move |b| (a, b)))
    {
        // Half-open intervals: a lease ending exactly when another
        // starts does not overlap (the DES frees devices before the
        // next admit at the same timestamp).
        let overlap_time =
            a.start_s < b.finish_s && b.start_s < a.finish_s;
        if overlap_time {
            checked += 1;
            assert!(
                a.devices.iter().all(|d| !b.devices.contains(d)),
                "overlapping leases share a device: {a:?} vs {b:?}"
            );
        }
    }
    checked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_load_has_no_waiting() {
        // Service 0.1s, arrivals 0.5/s -> utilization 5%, waits ~0.
        let s = simulate_open_loop(0.5, 200, &[0.1], 1);
        assert!(s.offered_load < 0.1);
        assert!(s.mean_wait_s < 0.02, "wait {}", s.mean_wait_s);
        assert!((s.mean_sojourn_s - 0.1).abs() < 0.03);
    }

    #[test]
    fn near_saturation_waits_blow_up() {
        // rho = 0.9: M/D/1 mean wait = rho*s/(2(1-rho)) = 0.45s.
        let s_low = simulate_open_loop(2.0, 400, &[0.1], 2); // rho 0.2
        let s_high = simulate_open_loop(9.0, 400, &[0.1], 2); // rho 0.9
        assert!(s_high.mean_wait_s > 5.0 * s_low.mean_wait_s.max(1e-3));
        assert!(s_high.max_queue_len > s_low.max_queue_len);
    }

    #[test]
    fn shorter_service_dominates_everywhere() {
        for rate in [1.0, 4.0, 8.0] {
            let slow = simulate_open_loop(rate, 300, &[0.11], 3);
            let fast = simulate_open_loop(rate, 300, &[0.07], 3);
            assert!(
                fast.mean_sojourn_s < slow.mean_sojourn_s,
                "rate {rate}"
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = simulate_open_loop(3.0, 100, &[0.2, 0.3], 7);
        let b = simulate_open_loop(3.0, 100, &[0.2, 0.3], 7);
        assert_eq!(a.mean_sojourn_s, b.mean_sojourn_s);
        assert_eq!(a.max_queue_len, b.max_queue_len);
    }

    #[test]
    fn all_requests_complete() {
        let s = simulate_open_loop(5.0, 250, &[0.15], 9);
        assert_eq!(s.traces.len(), 250);
        for t in &s.traces {
            assert!(t.finish_s >= t.start_s && t.start_s >= t.arrival_s);
        }
    }

    #[test]
    fn second_server_cuts_waits_near_saturation() {
        // rho(c=1) = 0.9 -> heavy queueing; the same load on 2 workers
        // is rho = 0.45 -> waits collapse.
        let one = simulate_open_loop_servers(9.0, 400, &[0.1], 1, 4);
        let two = simulate_open_loop_servers(9.0, 400, &[0.1], 2, 4);
        assert!((one.offered_load - 2.0 * two.offered_load).abs() < 1e-9);
        assert!(
            two.mean_wait_s < 0.25 * one.mean_wait_s,
            "2 servers {} vs 1 server {}",
            two.mean_wait_s,
            one.mean_wait_s
        );
        assert!(two.max_queue_len <= one.max_queue_len);
    }

    #[test]
    fn servers_lift_the_capacity_ceiling() {
        // Arrivals at 2x a single server's capacity: c=1 diverges (waits
        // grow with n), c=4 is stable at rho = 0.5.
        let overloaded = simulate_open_loop_servers(20.0, 400, &[0.1], 1, 5);
        let pooled = simulate_open_loop_servers(20.0, 400, &[0.1], 4, 5);
        assert!(overloaded.offered_load > 1.5);
        assert!(pooled.offered_load < 0.6);
        assert!(pooled.mean_wait_s < 0.05);
        assert!(overloaded.mean_wait_s > 10.0 * pooled.mean_wait_s.max(1e-3));
        // Pooling also moves throughput toward the offered rate.
        assert!(pooled.throughput_rps > 1.8 * overloaded.throughput_rps);
    }

    #[test]
    fn all_complete_with_servers() {
        for c in [1usize, 2, 3, 8] {
            let s = simulate_open_loop_servers(6.0, 200, &[0.12, 0.2], c, 11);
            assert_eq!(s.traces.len(), 200, "c={c}");
            for t in &s.traces {
                assert!(t.finish_s >= t.start_s && t.start_s >= t.arrival_s);
            }
        }
    }

    // --- gang-policy fleet simulation -------------------------------

    use crate::fleet::{Adaptive, AllGpus, FixedGang};

    /// Toy latency model: a fixed overhead plus work divided across
    /// the gang's total speed — bigger gangs are faster per request,
    /// with diminishing returns (the knob the policies trade on).
    fn toy_latency(speeds: &'static [f64]) -> impl Fn(&[usize]) -> Option<f64>
    {
        move |gang: &[usize]| {
            let cap: f64 = gang.iter().map(|&d| speeds[d]).sum();
            if cap <= 0.0 {
                return None;
            }
            Some(0.05 + 1.0 / cap)
        }
    }

    const TOY_SPEEDS: &[f64] = &[1.0, 0.9, 0.8, 0.5];

    #[test]
    fn gang_sim_all_requests_complete_and_leases_disjoint() {
        let lat = toy_latency(TOY_SPEEDS);
        for policy in [
            &AllGpus as &dyn crate::fleet::GangPolicy,
            &FixedGang(2),
            &Adaptive::default(),
        ] {
            let s = simulate_gang_policy(
                2.0, 100, TOY_SPEEDS, policy, &lat, 17,
            );
            assert_eq!(s.completed, 100, "policy {}", s.policy);
            assert_eq!(s.failed, 0);
            assert!(s.mean_gang_size >= 1.0);
            assert_leases_disjoint(&s.leases);
        }
    }

    #[test]
    fn gang_sim_deterministic_per_seed() {
        let lat = toy_latency(TOY_SPEEDS);
        let a = simulate_gang_policy(
            3.0, 80, TOY_SPEEDS, &Adaptive::default(), &lat, 5,
        );
        let b = simulate_gang_policy(
            3.0, 80, TOY_SPEEDS, &Adaptive::default(), &lat, 5,
        );
        assert_eq!(a.mean_sojourn_s, b.mean_sojourn_s);
        assert_eq!(a.throughput_rps, b.throughput_rps);
        assert_eq!(a.mean_gang_size, b.mean_gang_size);
    }

    #[test]
    fn sharding_beats_whole_fleet_under_load() {
        // Under heavy load, FixedGang(2) runs two requests at once;
        // AllGpus serializes. With the toy model's strong fixed
        // overhead, two half-fleet gangs clear the queue faster.
        let lat = toy_latency(TOY_SPEEDS);
        let rate = 6.0; // well past AllGpus capacity (~2.6 rps)
        let all =
            simulate_gang_policy(rate, 150, TOY_SPEEDS, &AllGpus, &lat, 9);
        let duo = simulate_gang_policy(
            rate, 150, TOY_SPEEDS, &FixedGang(2), &lat, 9,
        );
        assert!(
            duo.throughput_rps > all.throughput_rps,
            "fixed:2 {} <= all {}",
            duo.throughput_rps,
            all.throughput_rps
        );
        // But one request on the whole fleet is served faster.
        assert!(all.mean_service_s < duo.mean_service_s);
    }

    #[test]
    fn unplannable_gang_counts_as_failed_not_wedged() {
        // A latency model that rejects singleton gangs: FixedGang(1)
        // must fail every request (planner says no) yet terminate.
        let lat = |gang: &[usize]| -> Option<f64> {
            if gang.len() < 2 {
                None
            } else {
                Some(0.1)
            }
        };
        let s = simulate_gang_policy(
            2.0, 40, TOY_SPEEDS, &FixedGang(1), &lat, 3,
        );
        assert_eq!(s.completed, 0);
        assert_eq!(s.failed, 40);
    }
}
