//! Serving-level queueing simulation (discrete-event).
//!
//! The paper optimizes single-request latency; a serving deployment
//! cares how that translates under load. This module runs an M/G/1-
//! style open-loop simulation on the `des` substrate: Poisson arrivals
//! into the router's FIFO queue, one request in service at a time (the
//! whole cluster cooperates per image), service time = the scheduler's
//! simulated end-to-end latency. Comparing STADI vs patch parallelism
//! service times shows how scheduler-level gains compound into
//! queueing gains (shorter service -> lower utilization -> much
//! shorter waits near saturation).

use crate::des::Sim;
use crate::util::rng::Pcg32;
use crate::util::stats;

/// One simulated request's timeline.
#[derive(Debug, Clone, Copy)]
pub struct RequestTrace {
    pub arrival_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
}

impl RequestTrace {
    pub fn wait_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }

    pub fn sojourn_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Aggregate results of one run.
#[derive(Debug, Clone)]
pub struct QueueStats {
    pub traces: Vec<RequestTrace>,
    pub offered_load: f64,
    pub mean_wait_s: f64,
    pub mean_sojourn_s: f64,
    pub p95_sojourn_s: f64,
    pub max_queue_len: usize,
    pub throughput_rps: f64,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival(usize),
    Departure,
}

/// Simulate `n_requests` Poisson(`rate_rps`) arrivals served FIFO by a
/// single engine whose service time for request i is `service_s[i %
/// len]`. Deterministic for a seed.
pub fn simulate_open_loop(
    rate_rps: f64,
    n_requests: usize,
    service_s: &[f64],
    seed: u64,
) -> QueueStats {
    assert!(rate_rps > 0.0 && !service_s.is_empty());
    let mut rng = Pcg32::new(seed);
    let mut sim: Sim<Ev> = Sim::new();

    // Pre-draw arrival times (exponential gaps).
    let mut t = 0.0;
    for i in 0..n_requests {
        let u: f64 = 1.0 - rng.next_f64();
        t += -u.ln() / rate_rps;
        sim.schedule(t, Ev::Arrival(i));
    }

    let mut queue: std::collections::VecDeque<(usize, f64)> =
        std::collections::VecDeque::new();
    let mut busy_with: Option<(usize, f64)> = None; // (req, start)
    let mut traces: Vec<Option<RequestTrace>> = vec![None; n_requests];
    let mut max_q = 0usize;

    sim.run(|sim, now, ev| {
        match ev {
            Ev::Arrival(i) => {
                if busy_with.is_none() {
                    busy_with = Some((i, now));
                    sim.schedule_in(service_s[i % service_s.len()], Ev::Departure);
                } else {
                    queue.push_back((i, now));
                    max_q = max_q.max(queue.len());
                }
            }
            Ev::Departure => {
                let (i, start) = busy_with.take().unwrap();
                let arrival = traces[i]
                    .map(|t| t.arrival_s)
                    .unwrap_or(start); // set below for queued ones
                let _ = arrival;
                // We record arrival lazily: for directly-served
                // requests arrival == start.
                let arr = traces[i].map(|t| t.arrival_s).unwrap_or(start);
                traces[i] = Some(RequestTrace {
                    arrival_s: arr,
                    start_s: start,
                    finish_s: now,
                });
                if let Some((j, arr_j)) = queue.pop_front() {
                    traces[j] = Some(RequestTrace {
                        arrival_s: arr_j,
                        start_s: now,
                        finish_s: f64::NAN, // filled at departure
                    });
                    busy_with = Some((j, now));
                    sim.schedule_in(
                        service_s[j % service_s.len()],
                        Ev::Departure,
                    );
                }
            }
        }
        true
    });

    // Fix up arrival times for directly-served requests and finish
    // times (the simple lazy recording above): re-run trace sanity.
    let traces: Vec<RequestTrace> = traces
        .into_iter()
        .flatten()
        .filter(|t| t.finish_s.is_finite())
        .collect();

    let waits: Vec<f64> = traces.iter().map(RequestTrace::wait_s).collect();
    let soj: Vec<f64> = traces.iter().map(RequestTrace::sojourn_s).collect();
    let mean_service = stats::mean(
        &traces
            .iter()
            .map(|t| t.finish_s - t.start_s)
            .collect::<Vec<_>>(),
    );
    let total = traces
        .iter()
        .map(|t| t.finish_s)
        .fold(0.0f64, f64::max);
    QueueStats {
        offered_load: rate_rps * mean_service,
        mean_wait_s: stats::mean(&waits),
        mean_sojourn_s: stats::mean(&soj),
        p95_sojourn_s: stats::percentile(&soj, 95.0),
        max_queue_len: max_q,
        throughput_rps: if total > 0.0 {
            traces.len() as f64 / total
        } else {
            0.0
        },
        traces,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_load_has_no_waiting() {
        // Service 0.1s, arrivals 0.5/s -> utilization 5%, waits ~0.
        let s = simulate_open_loop(0.5, 200, &[0.1], 1);
        assert!(s.offered_load < 0.1);
        assert!(s.mean_wait_s < 0.02, "wait {}", s.mean_wait_s);
        assert!((s.mean_sojourn_s - 0.1).abs() < 0.03);
    }

    #[test]
    fn near_saturation_waits_blow_up() {
        // rho = 0.9: M/D/1 mean wait = rho*s/(2(1-rho)) = 0.45s.
        let s_low = simulate_open_loop(2.0, 400, &[0.1], 2); // rho 0.2
        let s_high = simulate_open_loop(9.0, 400, &[0.1], 2); // rho 0.9
        assert!(s_high.mean_wait_s > 5.0 * s_low.mean_wait_s.max(1e-3));
        assert!(s_high.max_queue_len > s_low.max_queue_len);
    }

    #[test]
    fn shorter_service_dominates_everywhere() {
        for rate in [1.0, 4.0, 8.0] {
            let slow = simulate_open_loop(rate, 300, &[0.11], 3);
            let fast = simulate_open_loop(rate, 300, &[0.07], 3);
            assert!(
                fast.mean_sojourn_s < slow.mean_sojourn_s,
                "rate {rate}"
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = simulate_open_loop(3.0, 100, &[0.2, 0.3], 7);
        let b = simulate_open_loop(3.0, 100, &[0.2, 0.3], 7);
        assert_eq!(a.mean_sojourn_s, b.mean_sojourn_s);
        assert_eq!(a.max_queue_len, b.max_queue_len);
    }

    #[test]
    fn all_requests_complete() {
        let s = simulate_open_loop(5.0, 250, &[0.15], 9);
        assert_eq!(s.traces.len(), 250);
        for t in &s.traces {
            assert!(t.finish_s >= t.start_s && t.start_s >= t.arrival_s);
        }
    }
}
