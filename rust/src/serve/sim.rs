//! Serving-level queueing simulation (discrete-event).
//!
//! The paper optimizes single-request latency; a serving deployment
//! cares how that translates under load. This module runs an M/G/c
//! open-loop simulation on the `des` substrate: Poisson arrivals into
//! the router's FIFO queue, up to `servers` requests in service at
//! once (the server's worker pool; `servers = 1` is the classic
//! single-flight M/G/1), service time = the scheduler's simulated
//! end-to-end latency. Comparing STADI vs patch parallelism service
//! times shows how scheduler-level gains compound into queueing gains
//! (shorter service -> lower utilization -> much shorter waits near
//! saturation), and sweeping `servers` shows what the concurrent
//! serve stack buys once requests can overlap.

use std::collections::VecDeque;

use crate::des::Sim;
use crate::util::rng::Pcg32;
use crate::util::stats;

/// One simulated request's timeline.
#[derive(Debug, Clone, Copy)]
pub struct RequestTrace {
    pub arrival_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
}

impl RequestTrace {
    pub fn wait_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }

    pub fn sojourn_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Aggregate results of one run.
#[derive(Debug, Clone)]
pub struct QueueStats {
    pub traces: Vec<RequestTrace>,
    /// rho = lambda * E[S] / c.
    pub offered_load: f64,
    pub mean_wait_s: f64,
    pub mean_sojourn_s: f64,
    pub p95_sojourn_s: f64,
    pub max_queue_len: usize,
    pub throughput_rps: f64,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival(usize),
    Departure(usize),
}

/// Single-flight convenience: M/G/1 (`servers = 1`).
pub fn simulate_open_loop(
    rate_rps: f64,
    n_requests: usize,
    service_s: &[f64],
    seed: u64,
) -> QueueStats {
    simulate_open_loop_servers(rate_rps, n_requests, service_s, 1, seed)
}

/// Simulate `n_requests` Poisson(`rate_rps`) arrivals served FIFO by
/// `servers` parallel workers; request i's service time is
/// `service_s[i % len]`. Deterministic for a seed.
pub fn simulate_open_loop_servers(
    rate_rps: f64,
    n_requests: usize,
    service_s: &[f64],
    servers: usize,
    seed: u64,
) -> QueueStats {
    assert!(rate_rps > 0.0 && !service_s.is_empty() && servers > 0);
    let mut rng = Pcg32::new(seed);
    let mut sim: Sim<Ev> = Sim::new();

    // Pre-draw arrival times (exponential gaps).
    let mut t = 0.0;
    for i in 0..n_requests {
        let u: f64 = 1.0 - rng.next_f64();
        t += -u.ln() / rate_rps;
        sim.schedule(t, Ev::Arrival(i));
    }

    let svc = |i: usize| service_s[i % service_s.len()];
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut in_service = 0usize;
    let mut arrival = vec![f64::NAN; n_requests];
    let mut start = vec![f64::NAN; n_requests];
    let mut finish = vec![f64::NAN; n_requests];
    let mut max_q = 0usize;

    sim.run(|sim, now, ev| {
        match ev {
            Ev::Arrival(i) => {
                arrival[i] = now;
                if in_service < servers {
                    in_service += 1;
                    start[i] = now;
                    sim.schedule_in(svc(i), Ev::Departure(i));
                } else {
                    queue.push_back(i);
                    max_q = max_q.max(queue.len());
                }
            }
            Ev::Departure(i) => {
                finish[i] = now;
                if let Some(j) = queue.pop_front() {
                    start[j] = now;
                    sim.schedule_in(svc(j), Ev::Departure(j));
                } else {
                    in_service -= 1;
                }
            }
        }
        true
    });

    let traces: Vec<RequestTrace> = (0..n_requests)
        .filter(|&i| finish[i].is_finite())
        .map(|i| RequestTrace {
            arrival_s: arrival[i],
            start_s: start[i],
            finish_s: finish[i],
        })
        .collect();

    let waits: Vec<f64> = traces.iter().map(RequestTrace::wait_s).collect();
    let soj: Vec<f64> = traces.iter().map(RequestTrace::sojourn_s).collect();
    let mean_service = stats::mean(
        &traces
            .iter()
            .map(|t| t.finish_s - t.start_s)
            .collect::<Vec<_>>(),
    );
    let total = traces
        .iter()
        .map(|t| t.finish_s)
        .fold(0.0f64, f64::max);
    QueueStats {
        offered_load: rate_rps * mean_service / servers as f64,
        mean_wait_s: stats::mean(&waits),
        mean_sojourn_s: stats::mean(&soj),
        p95_sojourn_s: stats::percentile(&soj, 95.0),
        max_queue_len: max_q,
        throughput_rps: if total > 0.0 {
            traces.len() as f64 / total
        } else {
            0.0
        },
        traces,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_load_has_no_waiting() {
        // Service 0.1s, arrivals 0.5/s -> utilization 5%, waits ~0.
        let s = simulate_open_loop(0.5, 200, &[0.1], 1);
        assert!(s.offered_load < 0.1);
        assert!(s.mean_wait_s < 0.02, "wait {}", s.mean_wait_s);
        assert!((s.mean_sojourn_s - 0.1).abs() < 0.03);
    }

    #[test]
    fn near_saturation_waits_blow_up() {
        // rho = 0.9: M/D/1 mean wait = rho*s/(2(1-rho)) = 0.45s.
        let s_low = simulate_open_loop(2.0, 400, &[0.1], 2); // rho 0.2
        let s_high = simulate_open_loop(9.0, 400, &[0.1], 2); // rho 0.9
        assert!(s_high.mean_wait_s > 5.0 * s_low.mean_wait_s.max(1e-3));
        assert!(s_high.max_queue_len > s_low.max_queue_len);
    }

    #[test]
    fn shorter_service_dominates_everywhere() {
        for rate in [1.0, 4.0, 8.0] {
            let slow = simulate_open_loop(rate, 300, &[0.11], 3);
            let fast = simulate_open_loop(rate, 300, &[0.07], 3);
            assert!(
                fast.mean_sojourn_s < slow.mean_sojourn_s,
                "rate {rate}"
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = simulate_open_loop(3.0, 100, &[0.2, 0.3], 7);
        let b = simulate_open_loop(3.0, 100, &[0.2, 0.3], 7);
        assert_eq!(a.mean_sojourn_s, b.mean_sojourn_s);
        assert_eq!(a.max_queue_len, b.max_queue_len);
    }

    #[test]
    fn all_requests_complete() {
        let s = simulate_open_loop(5.0, 250, &[0.15], 9);
        assert_eq!(s.traces.len(), 250);
        for t in &s.traces {
            assert!(t.finish_s >= t.start_s && t.start_s >= t.arrival_s);
        }
    }

    #[test]
    fn second_server_cuts_waits_near_saturation() {
        // rho(c=1) = 0.9 -> heavy queueing; the same load on 2 workers
        // is rho = 0.45 -> waits collapse.
        let one = simulate_open_loop_servers(9.0, 400, &[0.1], 1, 4);
        let two = simulate_open_loop_servers(9.0, 400, &[0.1], 2, 4);
        assert!((one.offered_load - 2.0 * two.offered_load).abs() < 1e-9);
        assert!(
            two.mean_wait_s < 0.25 * one.mean_wait_s,
            "2 servers {} vs 1 server {}",
            two.mean_wait_s,
            one.mean_wait_s
        );
        assert!(two.max_queue_len <= one.max_queue_len);
    }

    #[test]
    fn servers_lift_the_capacity_ceiling() {
        // Arrivals at 2x a single server's capacity: c=1 diverges (waits
        // grow with n), c=4 is stable at rho = 0.5.
        let overloaded = simulate_open_loop_servers(20.0, 400, &[0.1], 1, 5);
        let pooled = simulate_open_loop_servers(20.0, 400, &[0.1], 4, 5);
        assert!(overloaded.offered_load > 1.5);
        assert!(pooled.offered_load < 0.6);
        assert!(pooled.mean_wait_s < 0.05);
        assert!(overloaded.mean_wait_s > 10.0 * pooled.mean_wait_s.max(1e-3));
        // Pooling also moves throughput toward the offered rate.
        assert!(pooled.throughput_rps > 1.8 * overloaded.throughput_rps);
    }

    #[test]
    fn all_complete_with_servers() {
        for c in [1usize, 2, 3, 8] {
            let s = simulate_open_loop_servers(6.0, 200, &[0.12, 0.2], c, 11);
            assert_eq!(s.traces.len(), 200, "c={c}");
            for t in &s.traces {
                assert!(t.finish_s >= t.start_s && t.start_s >= t.arrival_s);
            }
        }
    }
}
