//! Serving-level queueing simulation (discrete-event).
//!
//! The paper optimizes single-request latency; a serving deployment
//! cares how that translates under load. This module runs an M/G/c
//! open-loop simulation on the `des` substrate: Poisson arrivals into
//! the router's FIFO queue, up to `servers` requests in service at
//! once (the server's worker pool; `servers = 1` is the classic
//! single-flight M/G/1), service time = the scheduler's simulated
//! end-to-end latency. Comparing STADI vs patch parallelism service
//! times shows how scheduler-level gains compound into queueing gains
//! (shorter service -> lower utilization -> much shorter waits near
//! saturation), and sweeping `servers` shows what the concurrent
//! serve stack buys once requests can overlap.

use std::collections::{HashMap, VecDeque};

use crate::des::Sim;
use crate::fleet::{FleetManager, GangPolicy, GpuLease, PolicyCtx};
use crate::serve::batch::{group_compatible, FuseKey};
use crate::util::rng::Pcg32;
use crate::util::stats;

/// One simulated request's timeline.
#[derive(Debug, Clone, Copy)]
pub struct RequestTrace {
    pub arrival_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
}

impl RequestTrace {
    pub fn wait_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }

    pub fn sojourn_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Aggregate results of one run.
#[derive(Debug, Clone)]
pub struct QueueStats {
    pub traces: Vec<RequestTrace>,
    /// rho = lambda * E[S] / c.
    pub offered_load: f64,
    pub mean_wait_s: f64,
    pub mean_sojourn_s: f64,
    pub p95_sojourn_s: f64,
    pub max_queue_len: usize,
    pub throughput_rps: f64,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrival(usize),
    Departure(usize),
}

/// Single-flight convenience: M/G/1 (`servers = 1`).
pub fn simulate_open_loop(
    rate_rps: f64,
    n_requests: usize,
    service_s: &[f64],
    seed: u64,
) -> QueueStats {
    simulate_open_loop_servers(rate_rps, n_requests, service_s, 1, seed)
}

/// Simulate `n_requests` Poisson(`rate_rps`) arrivals served FIFO by
/// `servers` parallel workers; request i's service time is
/// `service_s[i % len]`. Deterministic for a seed.
pub fn simulate_open_loop_servers(
    rate_rps: f64,
    n_requests: usize,
    service_s: &[f64],
    servers: usize,
    seed: u64,
) -> QueueStats {
    assert!(rate_rps > 0.0 && !service_s.is_empty() && servers > 0);
    let mut rng = Pcg32::new(seed);
    let mut sim: Sim<Ev> = Sim::new();

    // Pre-draw arrival times (exponential gaps).
    let mut t = 0.0;
    for i in 0..n_requests {
        let u: f64 = 1.0 - rng.next_f64();
        t += -u.ln() / rate_rps;
        sim.schedule(t, Ev::Arrival(i));
    }

    let svc = |i: usize| service_s[i % service_s.len()];
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut in_service = 0usize;
    let mut arrival = vec![f64::NAN; n_requests];
    let mut start = vec![f64::NAN; n_requests];
    let mut finish = vec![f64::NAN; n_requests];
    let mut max_q = 0usize;

    sim.run(|sim, now, ev| {
        match ev {
            Ev::Arrival(i) => {
                arrival[i] = now;
                if in_service < servers {
                    in_service += 1;
                    start[i] = now;
                    sim.schedule_in(svc(i), Ev::Departure(i));
                } else {
                    queue.push_back(i);
                    max_q = max_q.max(queue.len());
                }
            }
            Ev::Departure(i) => {
                finish[i] = now;
                if let Some(j) = queue.pop_front() {
                    start[j] = now;
                    sim.schedule_in(svc(j), Ev::Departure(j));
                } else {
                    in_service -= 1;
                }
            }
        }
        true
    });

    let traces: Vec<RequestTrace> = (0..n_requests)
        .filter(|&i| finish[i].is_finite())
        .map(|i| RequestTrace {
            arrival_s: arrival[i],
            start_s: start[i],
            finish_s: finish[i],
        })
        .collect();

    let waits: Vec<f64> = traces.iter().map(RequestTrace::wait_s).collect();
    let soj: Vec<f64> = traces.iter().map(RequestTrace::sojourn_s).collect();
    let mean_service = stats::mean(
        &traces
            .iter()
            .map(|t| t.finish_s - t.start_s)
            .collect::<Vec<_>>(),
    );
    let total = traces
        .iter()
        .map(|t| t.finish_s)
        .fold(0.0f64, f64::max);
    QueueStats {
        offered_load: rate_rps * mean_service / servers as f64,
        mean_wait_s: stats::mean(&waits),
        mean_sojourn_s: stats::mean(&soj),
        p95_sojourn_s: stats::percentile(&soj, 95.0),
        max_queue_len: max_q,
        throughput_rps: if total > 0.0 {
            traces.len() as f64 / total
        } else {
            0.0
        },
        traces,
    }
}

// --- Gang-policy fleet simulation -----------------------------------

/// One granted lease in simulated time (for disjointness audits and
/// utilization plots).
#[derive(Debug, Clone)]
pub struct LeaseTrace {
    pub start_s: f64,
    pub finish_s: f64,
    pub devices: Vec<usize>,
}

/// Aggregate results of one gang-policy serving simulation.
#[derive(Debug, Clone)]
pub struct GangSimStats {
    pub policy: String,
    pub completed: usize,
    /// Requests the policy granted a gang the planner rejected.
    pub failed: usize,
    pub throughput_rps: f64,
    pub mean_service_s: f64,
    pub mean_sojourn_s: f64,
    pub p95_sojourn_s: f64,
    pub mean_gang_size: f64,
    pub max_in_flight: usize,
    /// Every granted lease with its lifetime (completed requests).
    pub leases: Vec<LeaseTrace>,
}

#[derive(Debug, Clone, Copy)]
enum FleetEv {
    Arrival(usize),
    Departure(usize),
}

/// Simulate `n_requests` Poisson(`rate_rps`) arrivals served FIFO on a
/// partitioned fleet: each request leases a gang chosen by `policy`
/// (through the real [`FleetManager`] ledger, so grants are disjoint
/// by construction) and holds it for `latency_of(gang)` simulated
/// seconds. This is how the latency-vs-throughput tradeoff of a gang
/// policy is measured offline before a deploy: `latency_of` is
/// typically `Plan::build` + `timeline::simulate` over the candidate
/// subset. Deterministic per seed.
pub fn simulate_gang_policy(
    rate_rps: f64,
    n_requests: usize,
    speeds: &[f64],
    policy: &dyn GangPolicy,
    latency_of: &dyn Fn(&[usize]) -> Option<f64>,
    seed: u64,
) -> GangSimStats {
    assert!(rate_rps > 0.0 && !speeds.is_empty());
    let mut rng = Pcg32::new(seed);
    let mut sim: Sim<FleetEv> = Sim::new();
    let mut t = 0.0;
    for i in 0..n_requests {
        let u: f64 = 1.0 - rng.next_f64();
        t += -u.ln() / rate_rps;
        sim.schedule(t, FleetEv::Arrival(i));
    }

    let mut st = FleetSimState {
        fleet: FleetManager::new(speeds.len()),
        policy,
        speeds,
        latency_of,
        pending: VecDeque::new(),
        held: HashMap::new(),
        start: vec![f64::NAN; n_requests],
        gangs: vec![Vec::new(); n_requests],
        failed: 0,
    };
    let mut arrival = vec![f64::NAN; n_requests];
    let mut finish = vec![f64::NAN; n_requests];
    let mut max_in_flight = 0usize;

    sim.run(|sim, now, ev| {
        match ev {
            FleetEv::Arrival(i) => {
                arrival[i] = now;
                st.pending.push_back(i);
            }
            FleetEv::Departure(i) => {
                finish[i] = now;
                st.held.remove(&i); // lease drops: devices freed
            }
        }
        st.admit(sim, now);
        max_in_flight = max_in_flight.max(st.held.len());
        true
    });

    let done: Vec<usize> =
        (0..n_requests).filter(|&i| finish[i].is_finite()).collect();
    let services: Vec<f64> =
        done.iter().map(|&i| finish[i] - st.start[i]).collect();
    let sojourns: Vec<f64> =
        done.iter().map(|&i| finish[i] - arrival[i]).collect();
    let sizes: Vec<f64> =
        done.iter().map(|&i| st.gangs[i].len() as f64).collect();
    let total = done
        .iter()
        .map(|&i| finish[i])
        .fold(0.0f64, f64::max);
    GangSimStats {
        policy: policy.name(),
        completed: done.len(),
        failed: st.failed,
        throughput_rps: if total > 0.0 {
            done.len() as f64 / total
        } else {
            0.0
        },
        mean_service_s: stats::mean(&services),
        mean_sojourn_s: stats::mean(&sojourns),
        p95_sojourn_s: stats::percentile(&sojourns, 95.0),
        mean_gang_size: stats::mean(&sizes),
        max_in_flight,
        leases: done
            .iter()
            .map(|&i| LeaseTrace {
                start_s: st.start[i],
                finish_s: finish[i],
                devices: st.gangs[i].clone(),
            })
            .collect(),
    }
}

/// Mutable state of one fleet simulation run (bundled so the admit
/// loop is a method rather than a 10-argument function).
struct FleetSimState<'a> {
    fleet: FleetManager,
    policy: &'a dyn GangPolicy,
    speeds: &'a [f64],
    latency_of: &'a dyn Fn(&[usize]) -> Option<f64>,
    pending: VecDeque<usize>,
    held: HashMap<usize, GpuLease>,
    start: Vec<f64>,
    gangs: Vec<Vec<usize>>,
    failed: usize,
}

impl FleetSimState<'_> {
    /// Admit as many queued requests (FIFO) as the policy + free set
    /// allow right now.
    fn admit(&mut self, sim: &mut Sim<FleetEv>, now: f64) {
        while let Some(&head) = self.pending.front() {
            let free = self.fleet.free_devices();
            if free.is_empty() {
                break;
            }
            let ctx = PolicyCtx {
                speeds: self.speeds,
                queue_depth: self.pending.len() - 1,
                in_flight: self.fleet.in_flight(),
                predict: Some(self.latency_of),
                priority: crate::spec::Priority::Normal,
                deadline_s: None,
            };
            let Some(gang) = self.policy.choose(&free, &ctx) else {
                break; // policy waits (e.g. AllGpus with gaps)
            };
            let Ok(Some(lease)) = self.fleet.try_acquire(&gang) else {
                break; // defensive: policy chose a busy device
            };
            let Some(svc) = (self.latency_of)(lease.devices()) else {
                // Unplannable gang: fail the request rather than wedge
                // the FIFO head forever.
                self.pending.pop_front();
                self.failed += 1;
                continue; // lease drops here, devices return
            };
            self.pending.pop_front();
            self.start[head] = now;
            self.gangs[head] = lease.devices().to_vec();
            self.held.insert(head, lease);
            sim.schedule_in(svc, FleetEv::Departure(head));
        }
    }
}

// --- Mixed-workload (priority/deadline) simulation -------------------

/// One class of a mixed workload: how often it arrives, what it costs,
/// and its SLO shape. Service times typically come from the real
/// planner priced per spec (`EngineCore::predict_latency_for`), which
/// is what makes this a mixed-*size* sweep and not just mixed-weight.
#[derive(Debug, Clone)]
pub struct WorkloadClass {
    pub name: String,
    /// Relative arrival weight (normalized across classes).
    pub weight: f64,
    /// Service time of one request of this class.
    pub service_s: f64,
    /// Router rank: higher = served first (see `spec::Priority`).
    pub priority: u8,
    /// Relative deadline from arrival; `None` = no SLO.
    pub deadline_s: Option<f64>,
    /// Output resolution in pixels (height, width) when this class
    /// models one request size of a mixed-resolution sweep; `None`
    /// for size-agnostic classes. Flows into the stats JSON so sweeps
    /// stay self-describing.
    pub resolution: Option<(usize, usize)>,
}

/// Queue discipline under simulation: the old FIFO router vs the
/// priority/deadline router (priority desc, EDF within a rank, FIFO
/// among equals, expired requests shed on dequeue — mirroring
/// [`super::router::Router`]'s ordering in simulated time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    Fifo,
    PriorityEdf,
}

/// Per-class outcome of one mixed-workload run.
#[derive(Debug, Clone)]
pub struct ClassStats {
    pub name: String,
    /// Echo of the class's resolution label, if any.
    pub resolution: Option<(usize, usize)>,
    pub arrived: usize,
    pub completed: usize,
    /// Shed on dequeue, after the deadline passed in queue
    /// (PriorityEdf only; FIFO serves late instead).
    pub shed: usize,
    /// Requests with a deadline that finished within it.
    pub deadlines_met: usize,
    /// Requests with a deadline (met + missed + shed).
    pub deadlines_total: usize,
    pub mean_sojourn_s: f64,
    pub p95_sojourn_s: f64,
}

/// Aggregate outcome of one mixed-workload run.
#[derive(Debug, Clone)]
pub struct MixedStats {
    pub discipline: Discipline,
    pub per_class: Vec<ClassStats>,
    pub completed: usize,
    pub shed: usize,
    pub deadlines_met: usize,
    pub deadlines_total: usize,
    pub throughput_rps: f64,
}

impl MixedStats {
    pub fn class(&self, name: &str) -> &ClassStats {
        self.per_class
            .iter()
            .find(|c| c.name == name)
            .unwrap_or_else(|| panic!("no class {name:?}"))
    }

    /// Structured stats for bench output files. Field order is fixed
    /// and every number is computed deterministically from the seeded
    /// DES, so two runs at the same seed serialize byte-identically —
    /// pinned by a regression test.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{Object, Value};
        let mut o = Object::new();
        o.insert(
            "discipline",
            Value::Str(
                match self.discipline {
                    Discipline::Fifo => "fifo",
                    Discipline::PriorityEdf => "priority_edf",
                }
                .into(),
            ),
        );
        o.insert("completed", Value::Num(self.completed as f64));
        o.insert("shed", Value::Num(self.shed as f64));
        o.insert("deadlines_met", Value::Num(self.deadlines_met as f64));
        o.insert(
            "deadlines_total",
            Value::Num(self.deadlines_total as f64),
        );
        o.insert("throughput_rps", Value::Num(self.throughput_rps));
        let classes: Vec<Value> = self
            .per_class
            .iter()
            .map(|c| {
                let mut co = Object::new();
                co.insert("name", Value::Str(c.name.clone()));
                if let Some((h, w)) = c.resolution {
                    co.insert(
                        "resolution",
                        Value::Str(format!("{h}x{w}")),
                    );
                }
                co.insert("arrived", Value::Num(c.arrived as f64));
                co.insert("completed", Value::Num(c.completed as f64));
                co.insert("shed", Value::Num(c.shed as f64));
                co.insert(
                    "deadlines_met",
                    Value::Num(c.deadlines_met as f64),
                );
                co.insert(
                    "deadlines_total",
                    Value::Num(c.deadlines_total as f64),
                );
                co.insert(
                    "mean_sojourn_s",
                    Value::Num(c.mean_sojourn_s),
                );
                co.insert("p95_sojourn_s", Value::Num(c.p95_sojourn_s));
                Value::Obj(co)
            })
            .collect();
        o.insert("classes", Value::Arr(classes));
        Value::Obj(o)
    }
}

#[derive(Debug, Clone, Copy)]
enum MixEv {
    Arrival(usize),
    Departure(usize),
}

/// Simulate `n_requests` Poisson(`rate_rps`) arrivals of a mixed
/// workload (class sampled by weight) into `servers` workers under the
/// chosen queue `discipline`. Deterministic per seed — the same
/// arrival sequence is generated for every discipline at a given
/// seed, so FIFO vs PriorityEdf comparisons are paired, not sampled.
pub fn simulate_mixed_workload(
    rate_rps: f64,
    n_requests: usize,
    classes: &[WorkloadClass],
    discipline: Discipline,
    servers: usize,
    seed: u64,
) -> MixedStats {
    assert!(rate_rps > 0.0 && !classes.is_empty() && servers > 0);
    let wsum: f64 = classes.iter().map(|c| c.weight).sum();
    assert!(wsum > 0.0, "all class weights are zero");
    let mut rng = Pcg32::new(seed);
    let mut sim: Sim<MixEv> = Sim::new();

    // Pre-draw arrivals + class assignment (identical across
    // disciplines for a given seed).
    let mut t = 0.0;
    let mut class_of = Vec::with_capacity(n_requests);
    let mut arrival = vec![f64::NAN; n_requests];
    for i in 0..n_requests {
        let u: f64 = 1.0 - rng.next_f64();
        t += -u.ln() / rate_rps;
        sim.schedule(t, MixEv::Arrival(i));
        let mut pick = rng.next_f64() * wsum;
        let mut k = 0usize;
        for (j, c) in classes.iter().enumerate() {
            k = j;
            pick -= c.weight;
            if pick <= 0.0 {
                break;
            }
        }
        class_of.push(k);
    }

    let mut queue: Vec<usize> = Vec::new();
    let mut in_service = 0usize;
    let mut start = vec![f64::NAN; n_requests];
    let mut finish = vec![f64::NAN; n_requests];
    let mut shed = vec![false; n_requests];

    sim.run(|sim, now, ev| {
        match ev {
            MixEv::Arrival(i) => {
                arrival[i] = now;
                queue.push(i);
                if in_service < servers
                    && dequeue_and_start(
                        &mut queue, &mut shed, &mut start, &arrival,
                        classes, &class_of, discipline, sim, now,
                    )
                {
                    in_service += 1;
                }
            }
            MixEv::Departure(i) => {
                finish[i] = now;
                if !dequeue_and_start(
                    &mut queue, &mut shed, &mut start, &arrival, classes,
                    &class_of, discipline, sim, now,
                ) {
                    in_service -= 1;
                }
            }
        }
        true
    });

    let total_end = finish
        .iter()
        .filter(|f| f.is_finite())
        .fold(0.0f64, |a, &b| a.max(b));
    let mut per_class = Vec::with_capacity(classes.len());
    let mut agg = (0usize, 0usize, 0usize, 0usize);
    for (k, c) in classes.iter().enumerate() {
        let idx: Vec<usize> =
            (0..n_requests).filter(|&i| class_of[i] == k).collect();
        let sojourns: Vec<f64> = idx
            .iter()
            .filter(|&&i| finish[i].is_finite())
            .map(|&i| finish[i] - arrival[i])
            .collect();
        let n_shed = idx.iter().filter(|&&i| shed[i]).count();
        let mut met = 0usize;
        let mut with_deadline = 0usize;
        if let Some(rel) = c.deadline_s {
            for &i in &idx {
                // Arrived but never served (still queued at sim end)
                // requests don't count either way; shed and late ones
                // count as missed.
                if shed[i] || finish[i].is_finite() {
                    with_deadline += 1;
                }
                if finish[i].is_finite() && finish[i] <= arrival[i] + rel
                {
                    met += 1;
                }
            }
        }
        agg.0 += sojourns.len();
        agg.1 += n_shed;
        agg.2 += met;
        agg.3 += with_deadline;
        per_class.push(ClassStats {
            name: c.name.clone(),
            resolution: c.resolution,
            arrived: idx.len(),
            completed: sojourns.len(),
            shed: n_shed,
            deadlines_met: met,
            deadlines_total: with_deadline,
            mean_sojourn_s: stats::mean(&sojourns),
            p95_sojourn_s: stats::percentile(&sojourns, 95.0),
        });
    }
    MixedStats {
        discipline,
        per_class,
        completed: agg.0,
        shed: agg.1,
        deadlines_met: agg.2,
        deadlines_total: agg.3,
        throughput_rps: if total_end > 0.0 {
            agg.0 as f64 / total_end
        } else {
            0.0
        },
    }
}

/// Pull the best queued request per the discipline and start serving
/// it, shedding expired ones on dequeue (PriorityEdf), until one
/// sticks or the queue empties. Returns whether a request started.
#[allow(clippy::too_many_arguments)]
fn dequeue_and_start(
    queue: &mut Vec<usize>,
    shed: &mut [bool],
    start: &mut [f64],
    arrival: &[f64],
    classes: &[WorkloadClass],
    class_of: &[usize],
    discipline: Discipline,
    sim: &mut Sim<MixEv>,
    now: f64,
) -> bool {
    let abs_deadline = |i: usize| -> Option<f64> {
        classes[class_of[i]].deadline_s.map(|d| arrival[i] + d)
    };
    loop {
        if queue.is_empty() {
            return false;
        }
        let pos = match discipline {
            Discipline::Fifo => 0,
            Discipline::PriorityEdf => {
                // argmin over (rank_inv, deadline-or-inf); `queue`
                // holds arrival order, so position breaks ties FIFO —
                // the same (priority desc, EDF, FIFO) discipline as
                // the real router.
                let key = |i: usize| {
                    (
                        u8::MAX - classes[class_of[i]].priority,
                        abs_deadline(i).unwrap_or(f64::INFINITY),
                    )
                };
                let mut best = 0usize;
                for (p, &i) in queue.iter().enumerate() {
                    let (kb, ki) = (key(queue[best]), key(i));
                    if ki.0 < kb.0 || (ki.0 == kb.0 && ki.1 < kb.1) {
                        best = p;
                    }
                }
                best
            }
        };
        let i = queue.remove(pos);
        if discipline == Discipline::PriorityEdf {
            if let Some(d) = abs_deadline(i) {
                if d < now {
                    shed[i] = true;
                    continue; // shed on dequeue, pick again
                }
            }
        }
        start[i] = now;
        sim.schedule_in(
            classes[class_of[i]].service_s,
            MixEv::Departure(i),
        );
        return true;
    }
}

/// Audit a lease trace: no two leases that overlap in time may share a
/// device. Returns the number of overlapping pairs checked.
pub fn assert_leases_disjoint(leases: &[LeaseTrace]) -> usize {
    let mut checked = 0;
    for (a, b) in leases
        .iter()
        .enumerate()
        .flat_map(|(i, a)| leases[i + 1..].iter().map(move |b| (a, b)))
    {
        // Half-open intervals: a lease ending exactly when another
        // starts does not overlap (the DES frees devices before the
        // next admit at the same timestamp).
        let overlap_time =
            a.start_s < b.finish_s && b.start_s < a.finish_s;
        if overlap_time {
            checked += 1;
            assert!(
                a.devices.iter().all(|d| !b.devices.contains(d)),
                "overlapping leases share a device: {a:?} vs {b:?}"
            );
        }
    }
    checked
}

// --- Cross-request batching frontier (fused vs disjoint DES) ---------

/// Fixture for the batched-vs-disjoint throughput/latency frontier.
///
/// The cost model is the serving-layer pricing model from
/// [`crate::coordinator::timeline::simulate_batched`] collapsed to two
/// scalars: a fused session pays `session_fixed_s` once (per-step fixed
/// launch cost plus the halo/KV all-gathers, which are shared across
/// the batch) and `per_member_s` for each fused request (the per-row
/// denoise work, which scales with batch size). A solo session is the
/// `members == 1` case of the same formula, so batching OFF is the same
/// cost model, not a different one.
///
/// `scripts/gen_bench_artifacts.py` mirrors this arithmetic (same
/// constants, same grouping rule, same queue discipline) to emit
/// `BENCH_batching.json`; keep the two in sync.
#[derive(Debug, Clone)]
pub struct BatchFrontierConfig {
    /// Independent gangs (servers in the queueing sense).
    pub servers: usize,
    /// Admission cap per fused session (`--batch-max`).
    pub max_batch: usize,
    /// Admission window a leader holds open for joiners
    /// (`--batch-window`, in seconds here).
    pub window_s: f64,
    /// Per-session cost paid once regardless of batch size.
    pub session_fixed_s: f64,
    /// Incremental cost per fused member.
    pub per_member_s: f64,
    /// Latency SLO used for the deadline-hit-rate column.
    pub deadline_s: f64,
    /// Requests per sweep point.
    pub n_requests: usize,
    /// Offered-load multiples of the disjoint-lease capacity.
    pub load_multiples: Vec<f64>,
}

impl BatchFrontierConfig {
    /// The stub-geometry fixture shared with
    /// `scripts/gen_bench_artifacts.py`: 8 denoise steps on a 2-gang
    /// fleet over the slow interconnect (20 ms latency, 20 MB/s), with
    /// 16 latent rows per device per member. The comm term is one x
    /// all-gather plus one KV all-gather per sync on the stub tensor
    /// shapes; it is paid once per fused step, which is what makes
    /// batching amortize.
    pub fn stub_fixture() -> Self {
        let steps = 8.0;
        let (lat_s, bw) = (0.02, 2e7);
        // Stub geometry: 16 rows x 32 cols x 4 channels, f32.
        let x_bytes = 16.0 * 32.0 * 4.0 * 4.0;
        // 2 layers, (16/2)*(32/2) patch tokens, K+V, dim 16, f32.
        let kv_bytes =
            2.0 * ((16.0 / 2.0) * (32.0 / 2.0)) * 2.0 * 16.0 * 4.0;
        let per_sync_comm =
            (lat_s + x_bytes / bw) + (lat_s + kv_bytes / bw);
        BatchFrontierConfig {
            servers: 2,
            max_batch: 4,
            window_s: 0.25,
            session_fixed_s: steps * (0.004 + per_sync_comm),
            per_member_s: steps * 0.0012 * 16.0,
            deadline_s: 4.0,
            n_requests: 240,
            load_multiples: vec![0.5, 1.0, 2.0, 4.0, 6.0, 8.0, 10.0],
        }
    }

    /// Wall time of one session carrying `members` fused requests.
    pub fn service_s(&self, members: usize) -> f64 {
        self.session_fixed_s + members as f64 * self.per_member_s
    }

    /// Saturation throughput of the disjoint-lease (solo) discipline.
    pub fn solo_capacity_rps(&self) -> f64 {
        self.servers as f64 / self.service_s(1)
    }
}

/// Per-discipline outcome at one offered load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchSideStats {
    /// Completed requests divided by makespan.
    pub throughput_rps: f64,
    /// Mean request sojourn (arrival to session finish).
    pub mean_sojourn_s: f64,
    /// p95 request sojourn.
    pub p95_sojourn_s: f64,
    /// Fraction of requests finishing within `deadline_s`.
    pub deadline_hit_rate: f64,
    /// Mean fused session size (1.0 for the disjoint discipline).
    pub mean_group: f64,
}

/// One point on the throughput-vs-latency frontier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchFrontierPoint {
    /// Offered load as a multiple of solo capacity.
    pub load_x: f64,
    /// Arrival rate in requests per second.
    pub rate_rps: f64,
    /// One request per session, disjoint gang leases.
    pub disjoint: BatchSideStats,
    /// Admission-window fused sessions on shared gangs.
    pub batched: BatchSideStats,
}

/// The full sweep, JSON-serializable for `BENCH_batching.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchFrontier {
    pub servers: usize,
    pub max_batch: usize,
    pub window_s: f64,
    pub session_fixed_s: f64,
    pub per_member_s: f64,
    pub deadline_s: f64,
    pub points: Vec<BatchFrontierPoint>,
}

impl BatchFrontier {
    /// Fixed field order; byte-identical across runs (the sweep is
    /// fully deterministic — arrivals are `i / rate`, no RNG).
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{Object, Value};
        let side = |s: &BatchSideStats| {
            let mut o = Object::new();
            o.insert("throughput_rps", Value::Num(s.throughput_rps));
            o.insert("mean_sojourn_s", Value::Num(s.mean_sojourn_s));
            o.insert("p95_sojourn_s", Value::Num(s.p95_sojourn_s));
            o.insert(
                "deadline_hit_rate",
                Value::Num(s.deadline_hit_rate),
            );
            o.insert("mean_group", Value::Num(s.mean_group));
            Value::Obj(o)
        };
        let mut o = Object::new();
        o.insert("servers", Value::Num(self.servers as f64));
        o.insert("max_batch", Value::Num(self.max_batch as f64));
        o.insert("window_s", Value::Num(self.window_s));
        o.insert("session_fixed_s", Value::Num(self.session_fixed_s));
        o.insert("per_member_s", Value::Num(self.per_member_s));
        o.insert("deadline_s", Value::Num(self.deadline_s));
        // Comm (the halo/KV all-gathers) is the shared, paid-once part
        // of `session_fixed_s`; fused members synchronize at every
        // step barrier.
        o.insert("halo", Value::Str("shared-per-session".into()));
        let points: Vec<Value> = self
            .points
            .iter()
            .map(|p| {
                let mut po = Object::new();
                po.insert("load_x", Value::Num(p.load_x));
                po.insert("rate_rps", Value::Num(p.rate_rps));
                po.insert("disjoint", side(&p.disjoint));
                po.insert("batched", side(&p.batched));
                Value::Obj(po)
            })
            .collect();
        o.insert("points", Value::Arr(points));
        Value::Obj(o)
    }
}

/// FIFO-by-ready-time service of pre-formed groups on `servers`
/// identical gangs. Each group occupies one gang for
/// `service(members)`; every member's sojourn runs from its own
/// arrival to the shared session finish.
fn serve_groups(
    arrivals: &[f64],
    groups: &[(f64, Vec<usize>)],
    servers: usize,
    service: &dyn Fn(usize) -> f64,
    deadline_s: f64,
) -> BatchSideStats {
    let mut free = vec![0.0f64; servers.max(1)];
    let mut sojourns = vec![0.0f64; arrivals.len()];
    let mut makespan = 0.0f64;
    for (ready, members) in groups {
        let (mut k, mut best) = (0usize, free[0]);
        for (i, &f) in free.iter().enumerate() {
            if f < best {
                k = i;
                best = f;
            }
        }
        let start = ready.max(best);
        let finish = start + service(members.len());
        free[k] = finish;
        makespan = makespan.max(finish);
        for &m in members {
            sojourns[m] = finish - arrivals[m];
        }
    }
    let hits =
        sojourns.iter().filter(|&&s| s <= deadline_s).count();
    let n = sojourns.len();
    BatchSideStats {
        throughput_rps: if makespan > 0.0 {
            n as f64 / makespan
        } else {
            0.0
        },
        mean_sojourn_s: stats::mean(&sojourns),
        p95_sojourn_s: stats::percentile(&sojourns, 95.0),
        deadline_hit_rate: if n == 0 {
            1.0
        } else {
            hits as f64 / n as f64
        },
        mean_group: n as f64 / groups.len().max(1) as f64,
    }
}

/// Sweep offered load and compare disjoint-lease serving (one request
/// per session, one session per gang) against admission-window fused
/// sessions, using the exact grouping rule the serve worker applies
/// ([`group_compatible`]). Arrivals are deterministic (`t_i = i /
/// rate`) with two interleaved [`FuseKey`] classes (every third
/// request is a different resolution), so incompatible neighbours
/// exercise the key-split path at every load.
pub fn simulate_batch_frontier(
    cfg: &BatchFrontierConfig,
) -> BatchFrontier {
    let key_a = FuseKey {
        rows: 32,
        cols: 32,
        steps: 8,
        warmup: 2,
        halo_budget: 0,
    };
    let key_b = FuseKey { rows: 48, ..key_a };
    let cap = cfg.solo_capacity_rps();
    let mut points = Vec::new();
    for &load_x in &cfg.load_multiples {
        let rate = load_x * cap;
        let arrivals: Vec<(f64, FuseKey)> = (0..cfg.n_requests)
            .map(|i| {
                let key = if i % 3 == 2 { key_b } else { key_a };
                (i as f64 / rate, key)
            })
            .collect();
        let times: Vec<f64> =
            arrivals.iter().map(|(t, _)| *t).collect();
        // Disjoint leases: every request founds its own session.
        let solo: Vec<(f64, Vec<usize>)> =
            times.iter().enumerate().map(|(i, &t)| (t, vec![i])).collect();
        let disjoint = serve_groups(
            &times,
            &solo,
            cfg.servers,
            &|m| cfg.service_s(m),
            cfg.deadline_s,
        );
        // Fused sessions: a full group dispatches the moment its last
        // member arrives; a partial group waits out the leader's
        // admission window (`pop_match_timeout` semantics).
        let mut fused: Vec<(f64, Vec<usize>)> =
            group_compatible(&arrivals, cfg.window_s, cfg.max_batch)
                .into_iter()
                .map(|g| {
                    let ready = if g.len() == cfg.max_batch {
                        times[*g.last().expect("non-empty group")]
                    } else {
                        times[g[0]] + cfg.window_s
                    };
                    (ready, g)
                })
                .collect();
        fused.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).expect("finite ready times")
        });
        let batched = serve_groups(
            &times,
            &fused,
            cfg.servers,
            &|m| cfg.service_s(m),
            cfg.deadline_s,
        );
        points.push(BatchFrontierPoint {
            load_x,
            rate_rps: rate,
            disjoint,
            batched,
        });
    }
    BatchFrontier {
        servers: cfg.servers,
        max_batch: cfg.max_batch,
        window_s: cfg.window_s,
        session_fixed_s: cfg.session_fixed_s,
        per_member_s: cfg.per_member_s,
        deadline_s: cfg.deadline_s,
        points,
    }
}

// --- In-request drift scenarios (mid-flight re-planning DES) ---------

/// A deterministic drift scenario: `requests` back-to-back requests on
/// one cluster while the [`crate::device::OccupancySchedule`] shifts
/// device speeds *mid-request* (keyed by each device's cumulative
/// executed steps across the whole scenario). Compares three planning
/// strategies:
///
/// * **frozen** — the paper's static plan from the initial speeds,
///   never updated (PR-1 behavior);
/// * **ewma** — re-plan *between* requests from the profiler's EWMA of
///   previous requests' step timings (`bench_ext_dynamic_occupancy`'s
///   adaptive loop, PR-4 behavior): the estimate only helps the next
///   request;
/// * **midflight** — the same per-request EWMA planning *plus*
///   in-request re-planning at the warmup barrier and every
///   `replan.every_k_syncs` sync points (this PR).
///
/// Entirely virtual (planner + timeline, no executor), so every number
/// is a pure function of the inputs — byte-reproducible for the flake
/// gate.
#[derive(Debug, Clone)]
pub struct DriftScenario {
    pub requests: usize,
    pub drift: crate::device::OccupancySchedule,
    pub replan: crate::config::ReplanConfig,
}

/// One strategy's outcome over the scenario.
#[derive(Debug, Clone)]
pub struct DriftStrategyStats {
    /// Sum of per-request makespans (back-to-back, single-tenant).
    pub total_s: f64,
    pub per_request_s: Vec<f64>,
    /// Mid-flight re-plans applied (0 for frozen/ewma).
    pub replans: usize,
    /// Rows migrated across all re-plans.
    pub migrated_rows: usize,
}

/// The three strategies side by side.
#[derive(Debug, Clone)]
pub struct DriftComparison {
    pub frozen: DriftStrategyStats,
    pub ewma: DriftStrategyStats,
    pub midflight: DriftStrategyStats,
}

impl DriftComparison {
    /// Structured stats for bench output files and the CI flake gate:
    /// fixed field order, every number a deterministic function of the
    /// scenario — two runs must serialize byte-identically.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{Object, Value};
        let strat = |s: &DriftStrategyStats| {
            let mut o = Object::new();
            o.insert("total_s", Value::Num(s.total_s));
            o.insert(
                "per_request_s",
                Value::Arr(
                    s.per_request_s
                        .iter()
                        .map(|&v| Value::Num(v))
                        .collect(),
                ),
            );
            o.insert("replans", Value::Num(s.replans as f64));
            o.insert("migrated_rows", Value::Num(s.migrated_rows as f64));
            Value::Obj(o)
        };
        let mut o = Object::new();
        o.insert("frozen", strat(&self.frozen));
        o.insert("ewma", strat(&self.ewma));
        o.insert("midflight", strat(&self.midflight));
        Value::Obj(o)
    }
}

/// Run the three strategies over one scenario. `devices` + `cost`
/// define the cluster, `model` the latent geometry, `params` the
/// STADI knobs; the schedule's device keys are the `devices` indices.
pub fn simulate_drift_strategies(
    schedule: &crate::model::schedule::Schedule,
    params: &crate::config::StadiParams,
    devices: &[crate::config::DeviceConfig],
    cost: crate::device::CostModel,
    comm: &crate::config::CommConfig,
    model: &crate::runtime::artifacts::ModelInfo,
    scenario: &DriftScenario,
) -> crate::error::Result<DriftComparison> {
    use crate::coordinator::timeline;
    use crate::device::build_cluster;
    use crate::sched::plan::Plan;
    use crate::sched::replan::{drift_detected, live_speeds};
    use crate::sched::Profiler;

    let cluster = build_cluster(devices, cost);
    let costs: Vec<crate::device::CostModel> =
        cluster.iter().map(|g| g.cost).collect();
    let names: Vec<String> =
        devices.iter().map(|d| d.name.clone()).collect();
    let map: Vec<usize> = (0..devices.len()).collect();
    let rows = model.latent_h;
    let gran = model.row_granularity;
    let speeds0: Vec<f64> =
        devices.iter().map(|d| d.effective_speed()).collect();
    // The same allocator family the engine would use for these params
    // (a cost-aware scenario must not be priced with the plain Eq. 5
    // split the engine would never build).
    let build_plan = |speeds: &[f64]| -> crate::error::Result<Plan> {
        if params.cost_aware && params.spatial {
            Plan::build_cost_aware(
                schedule, speeds, &names, params, &cost, rows, gran,
            )
        } else {
            Plan::build(schedule, speeds, &names, params, rows, gran)
        }
    };
    let replan_cost =
        if params.cost_aware { Some(&cost) } else { None };
    let plan0 = build_plan(&speeds0)?;

    // frozen: the initial plan replayed under drift, request after
    // request (device step counters carry across requests — the
    // background job does not reset between them).
    let frozen = {
        let mut offsets = vec![0usize; devices.len()];
        let mut per = Vec::with_capacity(scenario.requests);
        for _ in 0..scenario.requests {
            let mut st = timeline::SimState::new(devices.len());
            st.steps_done = offsets.clone();
            timeline::simulate_span(
                &plan0,
                &cluster,
                comm,
                model,
                Some((&scenario.drift, &map)),
                &mut st,
                plan0.sync_points.len(),
                crate::config::HaloMode::Sync,
            )?;
            offsets = st.steps_done.clone();
            per.push(st.now);
        }
        DriftStrategyStats {
            total_s: per.iter().sum(),
            per_request_s: per,
            replans: 0,
            migrated_rows: 0,
        }
    };

    // Shared request driver for the EWMA strategies: plan from the
    // profiler's current estimate, optionally re-plan mid-request,
    // feed the virtual timings back.
    let run_strategy =
        |midflight: bool| -> crate::error::Result<DriftStrategyStats> {
            let mut profiler = Profiler::new(devices);
            let mut offsets = vec![0usize; devices.len()];
            let mut per = Vec::with_capacity(scenario.requests);
            let mut replans = 0usize;
            let mut migrated = 0usize;
            for _ in 0..scenario.requests {
                let est = profiler.effective_speeds();
                let plan = build_plan(&est)?;
                let k = scenario.replan.every_k_syncs.max(1);
                let mut st = timeline::SimState::new(devices.len());
                st.steps_done = offsets.clone();
                let mut cur = plan;
                let mut rows_run = vec![0usize; devices.len()];
                let mut global_sync = 0usize;
                let mut next_replan = if cur.params.m_warmup > 0 {
                    cur.params.m_warmup
                } else {
                    k
                };
                loop {
                    let remaining = cur.sync_points.len() - st.synced;
                    if remaining == 0 {
                        break;
                    }
                    let span = next_replan
                        .saturating_sub(global_sync)
                        .max(1)
                        .min(remaining);
                    let steps_before = st.steps_done.clone();
                    let busy_before = st.busy.clone();
                    timeline::simulate_span(
                        &cur,
                        &cluster,
                        comm,
                        model,
                        Some((&scenario.drift, &map)),
                        &mut st,
                        span,
                        crate::config::HaloMode::Sync,
                    )?;
                    for d in cur.included_devices() {
                        let delta = st.steps_done[d.device]
                            - steps_before[d.device];
                        rows_run[d.device] += d.rows.rows * delta;
                    }
                    global_sync += span;
                    if st.synced >= cur.sync_points.len() {
                        break;
                    }
                    if !midflight || global_sync < next_replan {
                        continue;
                    }
                    next_replan = global_sync + k;
                    // The session's own estimator (the detection math
                    // is shared code; the surrounding cadence loop
                    // mirrors `Session::execute_adaptive_seeded` and
                    // must be kept in step with it by hand).
                    let sec_delta: Vec<f64> = (0..devices.len())
                        .map(|i| st.busy[i] - busy_before[i])
                        .collect();
                    let live = live_speeds(
                        &cur,
                        &costs,
                        &steps_before,
                        &st.steps_done,
                        &sec_delta,
                    );
                    if !drift_detected(
                        &cur,
                        &live,
                        scenario.replan.drift_threshold,
                    ) {
                        continue;
                    }
                    match crate::sched::replan_at_sync(
                        schedule,
                        &cur,
                        st.synced,
                        &live,
                        replan_cost,
                        gran,
                    )? {
                        Some(rp) if !rp.is_structural_noop() => {
                            st.charge_migration(
                                comm,
                                rp.migration_bytes(model),
                            );
                            replans += 1;
                            migrated += rp.migrated_rows;
                            cur = rp.plan;
                            st.switch_plan();
                        }
                        Some(_) => {}
                        None => {
                            next_replan = global_sync + 1;
                        }
                    }
                }
                // Per-request EWMA feedback (the PR-4 loop): virtual
                // seconds per device over the whole request.
                for i in 0..devices.len() {
                    if rows_run[i] > 0 {
                        profiler.record_step(
                            i,
                            rows_run[i],
                            st.busy[i],
                        );
                    }
                }
                offsets = st.steps_done.clone();
                per.push(st.now);
            }
            Ok(DriftStrategyStats {
                total_s: per.iter().sum(),
                per_request_s: per,
                replans,
                migrated_rows: migrated,
            })
        };

    let ewma = run_strategy(false)?;
    let midflight = run_strategy(true)?;
    Ok(DriftComparison { frozen, ewma, midflight })
}

// --- Federated serving DES (multi-node tier, BENCH_federation) -------

/// Fixture for the federation frontier sweep: `nodes` identical nodes
/// of `servers_per_node` workers each, unit-speed service split into
/// `segments` equal sync intervals (the barrier grid migration rides
/// on). A brownout rotates through the tier — during the k-th
/// `window_s` window node `k % nodes` runs at `spike_speed` — so every
/// node periodically slows *after* requests were admitted to it. The
/// router's load probe sees only current speeds (no future knowledge);
/// blindsided in-flight requests are exactly what barrier-checkpoint
/// migration exists to rescue.
///
/// `scripts/gen_bench_artifacts.py` mirrors this arithmetic
/// operation-for-operation (same constants, same greedy admission,
/// same segment loop) to emit `BENCH_federation.json`; keep the two
/// in sync.
#[derive(Debug, Clone)]
pub struct FederationSimConfig {
    /// Coordinator nodes in the tier.
    pub nodes: usize,
    /// Concurrent requests per node (worker pool / gang count).
    pub servers_per_node: usize,
    /// Full-speed service time of one request.
    pub service_s: f64,
    /// Sync barriers per request; migration may fire at any interior
    /// boundary.
    pub segments: usize,
    /// Latency SLO for the deadline-hit-rate column.
    pub deadline_s: f64,
    /// Envelope transfer time charged on a migration handoff.
    pub migration_s: f64,
    /// Spill threshold: a request spills off its home node when the
    /// home's estimated finish lags its arrival by more than this.
    pub busy_wait_s: f64,
    /// Relative speed of the browned-out node during its window.
    pub spike_speed: f64,
    /// Length of one brownout window; the slowed node is
    /// `floor(t / window_s) % nodes`.
    pub window_s: f64,
    /// Requests per sweep point.
    pub n_requests: usize,
    /// Offered-load multiples of a single node's capacity (so `2.0`
    /// means twice what the no-tier baseline can serve).
    pub load_multiples: Vec<f64>,
}

impl FederationSimConfig {
    /// The fixture shared with `scripts/gen_bench_artifacts.py` and
    /// `BENCH_federation.json`.
    pub fn stub_fixture() -> Self {
        FederationSimConfig {
            nodes: 4,
            servers_per_node: 2,
            service_s: 1.0,
            segments: 4,
            deadline_s: 3.0,
            migration_s: 0.05,
            busy_wait_s: 1.0,
            spike_speed: 0.1,
            window_s: 5.0,
            n_requests: 240,
            load_multiples: vec![0.5, 1.0, 1.5, 2.0, 2.5],
        }
    }

    /// Saturation throughput of ONE node at full speed — the sweep's
    /// load unit, so multiples compare against the single-node
    /// baseline's ceiling rather than the whole tier's.
    pub fn capacity_rps(&self) -> f64 {
        self.servers_per_node as f64 / self.service_s
    }
}

/// The three arrival traces of the sweep, in emission order.
pub const FEDERATION_TRACES: [&str; 3] = ["bursty", "diurnal", "flash"];

/// Deterministic arrival times for one named trace at `rate` rps —
/// closed-form, RNG-free, strictly non-decreasing:
///
/// * `bursty` — groups of 6 arrive together at the group's mean slot;
/// * `diurnal` — four equal phases at 0.5x / 1.5x / 2.0x / 1.0x rate;
/// * `flash` — steady, except a 3x crowd between n/3 and n/2.
pub fn federation_arrivals(trace: &str, rate: f64, n: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(n);
    match trace {
        "bursty" => {
            for i in 0..n {
                out.push((i / 6) as f64 * (6.0 / rate));
            }
        }
        "diurnal" => {
            let mult = [0.5, 1.5, 2.0, 1.0];
            let mut t = 0.0;
            for i in 0..n {
                let q = (i * 4 / n).min(3);
                t += 1.0 / (rate * mult[q]);
                out.push(t);
            }
        }
        "flash" => {
            let mut t = 0.0;
            for i in 0..n {
                let dt = if i >= n / 3 && i < n / 2 {
                    1.0 / (3.0 * rate)
                } else {
                    1.0 / rate
                };
                t += dt;
                out.push(t);
            }
        }
        other => panic!("unknown federation trace {other:?}"),
    }
    out
}

/// Serving discipline under comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FedMode {
    /// One node (node 0) takes all traffic — no tier.
    Single,
    /// Federated admission (shard + spill); no mid-flight migration.
    FederatedNoMigrate,
    /// Federated admission plus barrier-checkpoint migration.
    FederatedMigrate,
}

/// Per-discipline outcome at one (trace, load) point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FedSideStats {
    /// Fraction of requests finishing within `deadline_s`.
    pub deadline_hit_rate: f64,
    pub mean_sojourn_s: f64,
    pub p95_sojourn_s: f64,
    /// Completed requests over the arrival-to-last-finish span.
    pub throughput_rps: f64,
    /// Barrier handoffs that actually fired.
    pub migrations: usize,
    /// Admissions granted off the home node.
    pub spills: usize,
}

/// One point of the sweep: the same arrival train through all three
/// disciplines (paired comparison, not sampled).
#[derive(Debug, Clone, PartialEq)]
pub struct FederationPoint {
    pub load_x: f64,
    pub rate_rps: f64,
    pub single: FedSideStats,
    pub fed_nomig: FedSideStats,
    pub fed_mig: FedSideStats,
}

/// One trace's load sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct FederationTraceSweep {
    pub trace: String,
    pub points: Vec<FederationPoint>,
}

/// The full frontier, JSON-serializable for `BENCH_federation.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct FederationFrontier {
    pub config: FederationSimConfig,
    pub traces: Vec<FederationTraceSweep>,
}

fn fed_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Linear-interpolated percentile on a sorted copy (mirrored digit for
/// digit by the python generator — do not swap in another estimator).
fn fed_percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("finite sojourns"));
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    s[lo] + (s[hi] - s[lo]) * (rank - lo as f64)
}

/// Node `node`'s relative speed at time `t`: the brownout rotates, one
/// node at a time, every `window_s`.
fn fed_speed(cfg: &FederationSimConfig, node: usize, t: f64) -> f64 {
    if (t / cfg.window_s).floor() as usize % cfg.nodes == node {
        cfg.spike_speed
    } else {
        1.0
    }
}

/// Greedy FIFO service of one arrival train under one discipline.
/// Requests are admitted in arrival order; each takes the earliest-free
/// server of its chosen node and executes `segments` intervals whose
/// durations follow the node's live speed. Admission prices a node by
/// probing its queue depth and *current* speed (`fin_est`) — it cannot
/// foresee the next brownout window, which is what keeps the scenario
/// honest. Under [`FedMode::FederatedMigrate`], a request finding
/// itself on a slowed node at an interior barrier moves to an idle
/// full-speed sibling when staying would blow its deadline and moving
/// still makes it — paying `migration_s` and freeing its source server
/// at the barrier, exactly the envelope handoff's cost shape. At most
/// one migration per request (one envelope hop).
fn fed_run(
    cfg: &FederationSimConfig,
    arrivals: &[f64],
    mode: FedMode,
) -> FedSideStats {
    let n_nodes = if mode == FedMode::Single { 1 } else { cfg.nodes };
    let mut free = vec![vec![0.0f64; cfg.servers_per_node]; n_nodes];
    let seg_work = cfg.service_s / cfg.segments as f64;
    let min_server = |free: &[Vec<f64>], nd: usize| -> (usize, f64) {
        let mut k = 0usize;
        let mut best = free[nd][0];
        for (i, &f) in free[nd].iter().enumerate() {
            if f < best {
                k = i;
                best = f;
            }
        }
        (k, best)
    };
    let mut sojourns = Vec::with_capacity(arrivals.len());
    let mut migrations = 0usize;
    let mut spills = 0usize;
    let mut last_finish = 0.0f64;
    for (i, &a) in arrivals.iter().enumerate() {
        // Admission: home node by shard; the probe estimates finish as
        // queue-drain plus one service at the node's *current* speed,
        // and the request spills to the best-probing node when the
        // home estimate lags arrival by more than `busy_wait_s`.
        let node = match mode {
            FedMode::Single => 0,
            _ => {
                let home = i % cfg.nodes;
                let fin_est = |nd: usize| {
                    min_server(&free, nd).1.max(a)
                        + cfg.service_s / fed_speed(cfg, nd, a)
                };
                if fin_est(home) - a > cfg.busy_wait_s {
                    let mut chosen = home;
                    let mut best = fin_est(home);
                    for nd in 0..cfg.nodes {
                        if fin_est(nd) < best {
                            chosen = nd;
                            best = fin_est(nd);
                        }
                    }
                    if chosen != home {
                        spills += 1;
                    }
                    chosen
                } else {
                    home
                }
            }
        };
        let (mut cur_k, f0) = min_server(&free, node);
        let mut cur_node = node;
        let mut t = a.max(f0);
        let mut migrated = false;
        for s in 0..cfg.segments {
            t += seg_work / fed_speed(cfg, cur_node, t);
            if mode == FedMode::FederatedMigrate
                && !migrated
                && s + 1 < cfg.segments
            {
                let spd_now = fed_speed(cfg, cur_node, t);
                if spd_now < 1.0 {
                    let remaining =
                        (cfg.segments - s - 1) as f64 * seg_work;
                    let stay = t + remaining / spd_now;
                    // Candidate destinations: full-speed siblings with
                    // an idle server (the tier migrates onto spare
                    // capacity; it never steals a sibling's queue).
                    let mut best: Option<(f64, usize, usize)> = None;
                    for nd in 0..cfg.nodes {
                        if nd == cur_node || fed_speed(cfg, nd, t) < 1.0
                        {
                            continue;
                        }
                        let (kk, fdest) = min_server(&free, nd);
                        if fdest > t + cfg.migration_s {
                            continue;
                        }
                        let fin = (t + cfg.migration_s).max(fdest)
                            + remaining;
                        if best.map(|(b, _, _)| fin < b).unwrap_or(true)
                        {
                            best = Some((fin, nd, kk));
                        }
                    }
                    // Deadline rescue: move only when staying misses
                    // the SLO and the handoff still makes it.
                    let deadline = a + cfg.deadline_s;
                    if let Some((fin, nd, kk)) = best {
                        if stay > deadline && fin <= deadline {
                            free[cur_node][cur_k] = t;
                            t = (t + cfg.migration_s).max(free[nd][kk]);
                            cur_node = nd;
                            cur_k = kk;
                            migrated = true;
                            migrations += 1;
                        }
                    }
                }
            }
        }
        free[cur_node][cur_k] = t;
        sojourns.push(t - a);
        if t > last_finish {
            last_finish = t;
        }
    }
    let hits = sojourns
        .iter()
        .filter(|&&s| s <= cfg.deadline_s)
        .count();
    let n = sojourns.len();
    let span = last_finish - arrivals[0];
    FedSideStats {
        deadline_hit_rate: if n == 0 {
            1.0
        } else {
            hits as f64 / n as f64
        },
        mean_sojourn_s: fed_mean(&sojourns),
        p95_sojourn_s: fed_percentile(&sojourns, 95.0),
        throughput_rps: if span > 0.0 { n as f64 / span } else { 0.0 },
        migrations,
        spills,
    }
}

/// Sweep every (trace, load) pair through the three disciplines. Each
/// point replays the identical arrival train, so the comparison is
/// paired rather than sampled; the rotating brownout timing is fixed
/// by `window_s` alone and shared by all three runs.
pub fn simulate_federation_frontier(
    cfg: &FederationSimConfig,
) -> FederationFrontier {
    let cap = cfg.capacity_rps();
    let traces = FEDERATION_TRACES
        .iter()
        .map(|&trace| {
            let points = cfg
                .load_multiples
                .iter()
                .map(|&load_x| {
                    let rate = load_x * cap;
                    let arr =
                        federation_arrivals(trace, rate, cfg.n_requests);
                    FederationPoint {
                        load_x,
                        rate_rps: rate,
                        single: fed_run(cfg, &arr, FedMode::Single),
                        fed_nomig: fed_run(
                            cfg,
                            &arr,
                            FedMode::FederatedNoMigrate,
                        ),
                        fed_mig: fed_run(
                            cfg,
                            &arr,
                            FedMode::FederatedMigrate,
                        ),
                    }
                })
                .collect();
            FederationTraceSweep { trace: trace.to_string(), points }
        })
        .collect();
    FederationFrontier { config: cfg.clone(), traces }
}

impl FederationFrontier {
    /// Fixed field order, byte-identical across runs (the sweep is
    /// RNG-free); matches `scripts/gen_bench_artifacts.py` field for
    /// field so `BENCH_federation.json` can be re-derived either way.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{Object, Value};
        let side = |s: &FedSideStats| {
            let mut o = Object::new();
            o.insert(
                "deadline_hit_rate",
                Value::Num(s.deadline_hit_rate),
            );
            o.insert("mean_sojourn_s", Value::Num(s.mean_sojourn_s));
            o.insert("p95_sojourn_s", Value::Num(s.p95_sojourn_s));
            o.insert("throughput_rps", Value::Num(s.throughput_rps));
            o.insert("migrations", Value::Num(s.migrations as f64));
            o.insert("spills", Value::Num(s.spills as f64));
            Value::Obj(o)
        };
        let mut o = Object::new();
        o.insert("bench", Value::Str("federation".into()));
        o.insert(
            "source",
            Value::Str("scripts/gen_bench_artifacts.py".into()),
        );
        // Migration ships a fully-fresh barrier snapshot; the halo
        // label names the comm mode the handoff relies on.
        o.insert("halo", Value::Str("checkpoint-migration".into()));
        let c = &self.config;
        let mut co = Object::new();
        co.insert("nodes", Value::Num(c.nodes as f64));
        co.insert(
            "servers_per_node",
            Value::Num(c.servers_per_node as f64),
        );
        co.insert("service_s", Value::Num(c.service_s));
        co.insert("segments", Value::Num(c.segments as f64));
        co.insert("deadline_s", Value::Num(c.deadline_s));
        co.insert("migration_s", Value::Num(c.migration_s));
        co.insert("busy_wait_s", Value::Num(c.busy_wait_s));
        co.insert("spike_speed", Value::Num(c.spike_speed));
        co.insert("window_s", Value::Num(c.window_s));
        co.insert("n_requests", Value::Num(c.n_requests as f64));
        co.insert(
            "load_multiples",
            Value::Arr(
                c.load_multiples
                    .iter()
                    .map(|&x| Value::Num(x))
                    .collect(),
            ),
        );
        o.insert("config", Value::Obj(co));
        let traces: Vec<Value> = self
            .traces
            .iter()
            .map(|tr| {
                let mut to = Object::new();
                to.insert("trace", Value::Str(tr.trace.clone()));
                let points: Vec<Value> = tr
                    .points
                    .iter()
                    .map(|p| {
                        let mut po = Object::new();
                        po.insert("load_x", Value::Num(p.load_x));
                        po.insert("rate_rps", Value::Num(p.rate_rps));
                        po.insert("single", side(&p.single));
                        po.insert("fed_nomig", side(&p.fed_nomig));
                        po.insert("fed_mig", side(&p.fed_mig));
                        Value::Obj(po)
                    })
                    .collect();
                to.insert("points", Value::Arr(points));
                Value::Obj(to)
            })
            .collect();
        o.insert("traces", Value::Arr(traces));
        Value::Obj(o)
    }
}

// --- Graceful-degradation DES (quality ladder, BENCH_degradation) ----

/// Fixture for the degradation frontier sweep: `servers` identical
/// workers, one request in service per worker, per-tier service cost
/// `service_s * Quality::factor()` (draft 0.5x / standard 1.0x / high
/// 1.5x — the same knob the real `GenerationSpec` path re-keys on
/// demotion). A brownout rotates through the pool — during the k-th
/// `window_s` window server `k % servers` runs at `brownout_speed` —
/// so requests admitted against full-speed predictions keep getting
/// blindsided mid-flight, which is what arms the barrier
/// re-quantization lever on top of admission demotion.
///
/// Each sweep point replays the identical arrival train with the
/// ladder OFF and ON (paired comparison, not sampled); the ON side
/// runs the *real* ladder arithmetic —
/// [`degrade::pressure_signal`](crate::serve::degrade::pressure_signal),
/// [`degrade::admission_demotion`](crate::serve::degrade::admission_demotion),
/// [`degrade::wants_requantize`](crate::serve::degrade::wants_requantize)
/// — against a queue-depth snapshot and the per-request deadline
/// budget, so the bench exercises the shipped demotion code, not a
/// re-derivation of it.
///
/// `scripts/gen_bench_artifacts.py` mirrors this arithmetic
/// operation-for-operation (same constants, same greedy admission,
/// same ladder walk) to emit `BENCH_degradation.json`; keep the two
/// in sync.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeSimConfig {
    /// Concurrent requests (worker pool size).
    pub servers: usize,
    /// Full-speed service time of a standard-tier request.
    pub service_s: f64,
    /// Latency SLO for the deadline-hit-rate column.
    pub deadline_s: f64,
    /// The ladder under test: thresholds + floor. `enabled` must stay
    /// true — the OFF side of the pair skips the ladder wholesale
    /// rather than threading a second config through.
    pub degrade: crate::config::DegradeConfig,
    /// Router admission budget the queue term normalizes by.
    pub queue_capacity: usize,
    /// Relative speed of the browned-out server during its window.
    pub brownout_speed: f64,
    /// Length of one brownout window; the slowed server is
    /// `floor(t / window_s) % servers`.
    pub window_s: f64,
    /// Requests per sweep point.
    pub n_requests: usize,
    /// Offered-load multiples of the full-speed pool capacity.
    pub load_multiples: Vec<f64>,
}

impl DegradeSimConfig {
    /// The fixture shared with `scripts/gen_bench_artifacts.py` and
    /// `BENCH_degradation.json`.
    pub fn stub_fixture() -> Self {
        DegradeSimConfig {
            servers: 3,
            service_s: 1.0,
            deadline_s: 3.0,
            degrade: crate::config::DegradeConfig {
                enabled: true,
                pressure_thresholds: vec![0.8, 1.6],
                floor: crate::spec::Quality::Draft,
            },
            queue_capacity: 6,
            brownout_speed: 0.25,
            window_s: 5.0,
            n_requests: 240,
            load_multiples: vec![1.0, 1.5, 2.0, 2.5, 3.0],
        }
    }

    /// Saturation throughput of the full-speed pool over the request
    /// mix — the tier cycle's mean factor is exactly 1.0, so this is
    /// just `servers / service_s` — the sweep's load unit.
    pub fn capacity_rps(&self) -> f64 {
        self.servers as f64 / self.service_s
    }
}

/// The arrival tier of request `i`: the train cycles high / standard
/// / draft, so every third request already sits on the default floor
/// and exercises the "nothing below you" branch of the ladder.
pub fn degrade_tier(i: usize) -> crate::spec::Quality {
    use crate::spec::Quality;
    match i % 3 {
        0 => Quality::High,
        1 => Quality::Standard,
        _ => Quality::Draft,
    }
}

/// Deterministic steady arrival train at `rate` rps — closed-form,
/// RNG-free, starting at t = 0.
pub fn degradation_arrivals(rate: f64, n: usize) -> Vec<f64> {
    (0..n).map(|i| i as f64 / rate).collect()
}

/// Server `server`'s relative speed at time `t`: the brownout
/// rotates, one server at a time, every `window_s`.
fn degrade_speed(cfg: &DegradeSimConfig, server: usize, t: f64) -> f64 {
    if (t / cfg.window_s).floor() as usize % cfg.servers == server {
        cfg.brownout_speed
    } else {
        1.0
    }
}

/// Per-side outcome at one load point.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeSideStats {
    /// Fraction of requests finishing within `deadline_s`.
    pub deadline_hit_rate: f64,
    pub mean_sojourn_s: f64,
    pub p95_sojourn_s: f64,
    /// Completed requests over the arrival-to-last-finish span.
    pub throughput_rps: f64,
    /// Requests demoted at least one tier at admission.
    pub demoted: usize,
    /// Requests whose step suffix was re-quantized at the barrier.
    pub requantized: usize,
    /// Mean *served* tier rank (draft 0 .. high 2); the arrival mix
    /// averages exactly 1.0, so the gap to 1.0 is the quality paid.
    pub mean_tier: f64,
    /// Lowest served tier rank — the floor guarantee, pinned.
    pub min_tier: u8,
}

/// One point of the sweep: the same arrival train with the ladder
/// OFF and ON.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradationPoint {
    pub load_x: f64,
    pub rate_rps: f64,
    pub off: DegradeSideStats,
    pub on: DegradeSideStats,
}

/// The full frontier, JSON-serializable for `BENCH_degradation.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradationFrontier {
    pub config: DegradeSimConfig,
    pub points: Vec<DegradationPoint>,
}

/// Greedy FIFO service of one arrival train, ladder OFF or ON.
/// Requests are admitted in arrival order onto the earliest-free
/// server; each executes as two equal step-halves whose durations
/// follow the server's live speed sampled at the half's start — the
/// interior boundary is the sync barrier the mid-flight lever fires
/// at. The ON side walks the real admission ladder against a
/// queue-depth snapshot and the remaining deadline budget; past the
/// top threshold it additionally re-quantizes the remaining suffix at
/// the barrier (halving the remaining step work — the 2:1 grid) when
/// the priced second half would blow the deadline. Both levers are
/// floor-gated; neither fires on the OFF side.
fn degrade_run(
    cfg: &DegradeSimConfig,
    arrivals: &[f64],
    ladder_on: bool,
) -> DegradeSideStats {
    use crate::serve::degrade;
    let mut free = vec![0.0f64; cfg.servers];
    let mut finishes: Vec<f64> = Vec::with_capacity(arrivals.len());
    let mut sojourns = Vec::with_capacity(arrivals.len());
    let mut demoted = 0usize;
    let mut requantized = 0usize;
    let mut tier_sum = 0.0f64;
    let mut min_tier = u8::MAX;
    let mut last_finish = 0.0f64;
    for (i, &a) in arrivals.iter().enumerate() {
        let mut q = degrade_tier(i);
        let mut k = 0usize;
        let mut f0 = free[0];
        for (j, &f) in free.iter().enumerate() {
            if f < f0 {
                k = j;
                f0 = f;
            }
        }
        let start = a.max(f0);
        // Admission snapshot: remaining deadline budget after the
        // queue wait, and the number of requests arrived-but-not-
        // finished (the router backlog the queue term normalizes).
        let budget = cfg.deadline_s - (start - a);
        let backlog = finishes.iter().filter(|&&f| f > a).count();
        if ladder_on {
            let spd = degrade_speed(cfg, k, start);
            let mut predict = |qq: crate::spec::Quality| {
                Some(cfg.service_s * qq.factor() / spd)
            };
            let p = degrade::pressure_signal(
                backlog,
                cfg.queue_capacity,
                predict(q),
                Some(budget),
            );
            let nq = degrade::admission_demotion(
                q,
                p,
                &cfg.degrade,
                Some(budget),
                &mut predict,
            );
            if nq != q {
                demoted += 1;
                q = nq;
            }
        }
        let work = cfg.service_s * q.factor();
        let mut t = start + 0.5 * work / degrade_speed(cfg, k, start);
        let mut rest = 0.5 * work;
        if ladder_on
            && degrade::tier_rank(q) > degrade::tier_rank(cfg.degrade.floor)
        {
            // Barrier snapshot: live speed (the brownout may have
            // rotated onto this server mid-request), live queue
            // depth, and what remains of the deadline.
            let pred = rest / degrade_speed(cfg, k, t);
            let rem_budget = a + cfg.deadline_s - t;
            let arrived = arrivals.iter().filter(|&&x| x <= t).count();
            let done = finishes.iter().filter(|&&f| f <= t).count();
            let backlog_mid = arrived.saturating_sub(done + 1);
            let p = degrade::pressure_signal(
                backlog_mid,
                cfg.queue_capacity,
                Some(pred),
                Some(rem_budget),
            );
            if degrade::wants_requantize(
                p,
                &cfg.degrade.pressure_thresholds,
            ) && pred * degrade::PRICE_SLACK > rem_budget
            {
                rest *= 0.5; // 2:1 grid on the remaining suffix
                requantized += 1;
            }
        }
        t += rest / degrade_speed(cfg, k, t);
        free[k] = t;
        finishes.push(t);
        sojourns.push(t - a);
        tier_sum += degrade::tier_rank(q) as f64;
        min_tier = min_tier.min(degrade::tier_rank(q));
        if t > last_finish {
            last_finish = t;
        }
    }
    let n = sojourns.len();
    let hits = sojourns
        .iter()
        .filter(|&&s| s <= cfg.deadline_s)
        .count();
    let span = last_finish - arrivals[0];
    DegradeSideStats {
        deadline_hit_rate: if n == 0 {
            1.0
        } else {
            hits as f64 / n as f64
        },
        mean_sojourn_s: fed_mean(&sojourns),
        p95_sojourn_s: fed_percentile(&sojourns, 95.0),
        throughput_rps: if span > 0.0 { n as f64 / span } else { 0.0 },
        demoted,
        requantized,
        mean_tier: if n == 0 { 0.0 } else { tier_sum / n as f64 },
        min_tier: if min_tier == u8::MAX { 0 } else { min_tier },
    }
}

/// Sweep every load multiple through the paired OFF/ON runs. The
/// rotating brownout timing is fixed by `window_s` alone and shared
/// by both sides of every point.
pub fn simulate_degradation_frontier(
    cfg: &DegradeSimConfig,
) -> DegradationFrontier {
    let cap = cfg.capacity_rps();
    let points = cfg
        .load_multiples
        .iter()
        .map(|&load_x| {
            let rate = load_x * cap;
            let arr = degradation_arrivals(rate, cfg.n_requests);
            DegradationPoint {
                load_x,
                rate_rps: rate,
                off: degrade_run(cfg, &arr, false),
                on: degrade_run(cfg, &arr, true),
            }
        })
        .collect();
    DegradationFrontier { config: cfg.clone(), points }
}

impl DegradationFrontier {
    /// Fixed field order, byte-identical across runs (the sweep is
    /// RNG-free); matches `scripts/gen_bench_artifacts.py` field for
    /// field so `BENCH_degradation.json` can be re-derived either
    /// way.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{Object, Value};
        let side = |s: &DegradeSideStats| {
            let mut o = Object::new();
            o.insert(
                "deadline_hit_rate",
                Value::Num(s.deadline_hit_rate),
            );
            o.insert("mean_sojourn_s", Value::Num(s.mean_sojourn_s));
            o.insert("p95_sojourn_s", Value::Num(s.p95_sojourn_s));
            o.insert("throughput_rps", Value::Num(s.throughput_rps));
            o.insert("demoted", Value::Num(s.demoted as f64));
            o.insert("requantized", Value::Num(s.requantized as f64));
            o.insert("mean_tier", Value::Num(s.mean_tier));
            o.insert("min_tier", Value::Num(s.min_tier as f64));
            Value::Obj(o)
        };
        let mut o = Object::new();
        o.insert("bench", Value::Str("degradation".into()));
        o.insert(
            "source",
            Value::Str("scripts/gen_bench_artifacts.py".into()),
        );
        // The ladder sheds quality, not halo traffic; the label names
        // the lever the top rung pulls at the sync barrier.
        o.insert("halo", Value::Str("quality-ladder".into()));
        let c = &self.config;
        let mut co = Object::new();
        co.insert("servers", Value::Num(c.servers as f64));
        co.insert("service_s", Value::Num(c.service_s));
        co.insert("deadline_s", Value::Num(c.deadline_s));
        co.insert(
            "pressure_thresholds",
            Value::Arr(
                c.degrade
                    .pressure_thresholds
                    .iter()
                    .map(|&x| Value::Num(x))
                    .collect(),
            ),
        );
        co.insert("floor", Value::Str(c.degrade.floor.as_str().into()));
        co.insert(
            "queue_capacity",
            Value::Num(c.queue_capacity as f64),
        );
        co.insert("brownout_speed", Value::Num(c.brownout_speed));
        co.insert("window_s", Value::Num(c.window_s));
        co.insert("n_requests", Value::Num(c.n_requests as f64));
        co.insert(
            "load_multiples",
            Value::Arr(
                c.load_multiples
                    .iter()
                    .map(|&x| Value::Num(x))
                    .collect(),
            ),
        );
        o.insert("config", Value::Obj(co));
        let points: Vec<Value> = self
            .points
            .iter()
            .map(|p| {
                let mut po = Object::new();
                po.insert("load_x", Value::Num(p.load_x));
                po.insert("rate_rps", Value::Num(p.rate_rps));
                po.insert("off", side(&p.off));
                po.insert("on", side(&p.on));
                Value::Obj(po)
            })
            .collect();
        o.insert("points", Value::Arr(points));
        Value::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drift_fixture() -> (
        crate::model::schedule::Schedule,
        crate::config::StadiParams,
        Vec<crate::config::DeviceConfig>,
        crate::device::CostModel,
        crate::config::CommConfig,
        crate::runtime::artifacts::ModelInfo,
        DriftScenario,
    ) {
        use crate::config::{
            CommConfig, DeviceConfig, ReplanConfig, StadiParams,
        };
        let schedule =
            crate::model::schedule::Schedule::scaled_linear(
                1000, 0.00085, 0.012,
            );
        let params = StadiParams {
            m_base: 16,
            m_warmup: 2,
            ..StadiParams::default()
        };
        let devices = vec![
            DeviceConfig::new("g0", 1.0, 0.0),
            DeviceConfig::new("g1", 1.0, 0.0),
        ];
        let cost = crate::device::CostModel {
            fixed_s: 0.004,
            per_row_s: 0.0012,
        };
        let model = crate::runtime::artifacts::ModelInfo {
            latent_h: 32,
            latent_w: 32,
            latent_c: 4,
            patch: 2,
            dim: 96,
            heads: 4,
            layers: 3,
            temb_dim: 64,
            row_granularity: 4,
            tokens_full: 256,
            param_count: 1,
            params_seed: 0,
        };
        let scenario = DriftScenario {
            requests: 3,
            drift: crate::device::OccupancySchedule::parse(
                "0@0;0@0,0.7@6",
            )
            .unwrap(),
            replan: ReplanConfig {
                enabled: true,
                every_k_syncs: 2,
                drift_threshold: 0.1,
            },
        };
        (schedule, params, devices, cost, CommConfig::default(), model,
         scenario)
    }

    /// Acceptance criterion, DES half: a background job landing
    /// mid-request strictly favors mid-flight re-planning over both
    /// the frozen plan and the between-requests EWMA loop, and the
    /// whole comparison is a pure function of the scenario (pinned
    /// byte-identical serialization — the CI flake gate diffs it
    /// across two full test-suite runs).
    #[test]
    fn midflight_beats_ewma_beats_frozen_under_injected_drift() {
        let (schedule, params, devices, cost, comm, model, scenario) =
            drift_fixture();
        let cmp = simulate_drift_strategies(
            &schedule, &params, &devices, cost, &comm, &model, &scenario,
        )
        .unwrap();
        assert!(
            cmp.midflight.total_s < cmp.frozen.total_s,
            "midflight {} !< frozen {}",
            cmp.midflight.total_s,
            cmp.frozen.total_s
        );
        assert!(
            cmp.midflight.total_s < cmp.ewma.total_s,
            "midflight {} !< ewma {}",
            cmp.midflight.total_s,
            cmp.ewma.total_s
        );
        assert!(
            cmp.ewma.total_s < cmp.frozen.total_s,
            "ewma {} !< frozen {}",
            cmp.ewma.total_s,
            cmp.frozen.total_s
        );
        assert!(cmp.midflight.replans >= 1);
        assert!(cmp.midflight.migrated_rows > 0);
        assert_eq!(cmp.frozen.replans, 0);
        assert_eq!(cmp.ewma.replans, 0);
        assert_eq!(cmp.frozen.per_request_s.len(), 3);
        // Byte-identical serialization across runs (determinism).
        let again = simulate_drift_strategies(
            &schedule, &params, &devices, cost, &comm, &model, &scenario,
        )
        .unwrap();
        let a = crate::util::json::to_string_pretty(&cmp.to_json());
        let b = crate::util::json::to_string_pretty(&again.to_json());
        assert_eq!(a, b, "drift DES not deterministic");
    }

    #[test]
    fn flat_drift_never_replans_and_strategies_agree() {
        let (schedule, params, devices, cost, comm, model, mut scenario) =
            drift_fixture();
        // A schedule pinning every device at its config occupancy:
        // nothing drifts, nobody re-plans, all strategies coincide.
        scenario.drift =
            crate::device::OccupancySchedule::parse("0@0;0@0").unwrap();
        let cmp = simulate_drift_strategies(
            &schedule, &params, &devices, cost, &comm, &model, &scenario,
        )
        .unwrap();
        assert_eq!(cmp.midflight.replans, 0, "zero drift re-planned");
        assert_eq!(cmp.midflight.migrated_rows, 0);
        assert_eq!(cmp.frozen.total_s, cmp.ewma.total_s);
        assert_eq!(cmp.frozen.total_s, cmp.midflight.total_s);
    }

    #[test]
    fn low_load_has_no_waiting() {
        // Service 0.1s, arrivals 0.5/s -> utilization 5%, waits ~0.
        let s = simulate_open_loop(0.5, 200, &[0.1], 1);
        assert!(s.offered_load < 0.1);
        assert!(s.mean_wait_s < 0.02, "wait {}", s.mean_wait_s);
        assert!((s.mean_sojourn_s - 0.1).abs() < 0.03);
    }

    #[test]
    fn near_saturation_waits_blow_up() {
        // rho = 0.9: M/D/1 mean wait = rho*s/(2(1-rho)) = 0.45s.
        let s_low = simulate_open_loop(2.0, 400, &[0.1], 2); // rho 0.2
        let s_high = simulate_open_loop(9.0, 400, &[0.1], 2); // rho 0.9
        assert!(s_high.mean_wait_s > 5.0 * s_low.mean_wait_s.max(1e-3));
        assert!(s_high.max_queue_len > s_low.max_queue_len);
    }

    #[test]
    fn shorter_service_dominates_everywhere() {
        for rate in [1.0, 4.0, 8.0] {
            let slow = simulate_open_loop(rate, 300, &[0.11], 3);
            let fast = simulate_open_loop(rate, 300, &[0.07], 3);
            assert!(
                fast.mean_sojourn_s < slow.mean_sojourn_s,
                "rate {rate}"
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = simulate_open_loop(3.0, 100, &[0.2, 0.3], 7);
        let b = simulate_open_loop(3.0, 100, &[0.2, 0.3], 7);
        assert_eq!(a.mean_sojourn_s, b.mean_sojourn_s);
        assert_eq!(a.max_queue_len, b.max_queue_len);
    }

    #[test]
    fn all_requests_complete() {
        let s = simulate_open_loop(5.0, 250, &[0.15], 9);
        assert_eq!(s.traces.len(), 250);
        for t in &s.traces {
            assert!(t.finish_s >= t.start_s && t.start_s >= t.arrival_s);
        }
    }

    #[test]
    fn second_server_cuts_waits_near_saturation() {
        // rho(c=1) = 0.9 -> heavy queueing; the same load on 2 workers
        // is rho = 0.45 -> waits collapse.
        let one = simulate_open_loop_servers(9.0, 400, &[0.1], 1, 4);
        let two = simulate_open_loop_servers(9.0, 400, &[0.1], 2, 4);
        assert!((one.offered_load - 2.0 * two.offered_load).abs() < 1e-9);
        assert!(
            two.mean_wait_s < 0.25 * one.mean_wait_s,
            "2 servers {} vs 1 server {}",
            two.mean_wait_s,
            one.mean_wait_s
        );
        assert!(two.max_queue_len <= one.max_queue_len);
    }

    #[test]
    fn servers_lift_the_capacity_ceiling() {
        // Arrivals at 2x a single server's capacity: c=1 diverges (waits
        // grow with n), c=4 is stable at rho = 0.5.
        let overloaded = simulate_open_loop_servers(20.0, 400, &[0.1], 1, 5);
        let pooled = simulate_open_loop_servers(20.0, 400, &[0.1], 4, 5);
        assert!(overloaded.offered_load > 1.5);
        assert!(pooled.offered_load < 0.6);
        assert!(pooled.mean_wait_s < 0.05);
        assert!(overloaded.mean_wait_s > 10.0 * pooled.mean_wait_s.max(1e-3));
        // Pooling also moves throughput toward the offered rate.
        assert!(pooled.throughput_rps > 1.8 * overloaded.throughput_rps);
    }

    #[test]
    fn all_complete_with_servers() {
        for c in [1usize, 2, 3, 8] {
            let s = simulate_open_loop_servers(6.0, 200, &[0.12, 0.2], c, 11);
            assert_eq!(s.traces.len(), 200, "c={c}");
            for t in &s.traces {
                assert!(t.finish_s >= t.start_s && t.start_s >= t.arrival_s);
            }
        }
    }

    // --- gang-policy fleet simulation -------------------------------

    use crate::fleet::{Adaptive, AllGpus, FixedGang};

    /// Toy latency model: a fixed overhead plus work divided across
    /// the gang's total speed — bigger gangs are faster per request,
    /// with diminishing returns (the knob the policies trade on).
    fn toy_latency(speeds: &'static [f64]) -> impl Fn(&[usize]) -> Option<f64>
    {
        move |gang: &[usize]| {
            let cap: f64 = gang.iter().map(|&d| speeds[d]).sum();
            if cap <= 0.0 {
                return None;
            }
            Some(0.05 + 1.0 / cap)
        }
    }

    const TOY_SPEEDS: &[f64] = &[1.0, 0.9, 0.8, 0.5];

    #[test]
    fn gang_sim_all_requests_complete_and_leases_disjoint() {
        let lat = toy_latency(TOY_SPEEDS);
        for policy in [
            &AllGpus as &dyn crate::fleet::GangPolicy,
            &FixedGang(2),
            &Adaptive::default(),
        ] {
            let s = simulate_gang_policy(
                2.0, 100, TOY_SPEEDS, policy, &lat, 17,
            );
            assert_eq!(s.completed, 100, "policy {}", s.policy);
            assert_eq!(s.failed, 0);
            assert!(s.mean_gang_size >= 1.0);
            assert_leases_disjoint(&s.leases);
        }
    }

    #[test]
    fn gang_sim_deterministic_per_seed() {
        let lat = toy_latency(TOY_SPEEDS);
        let a = simulate_gang_policy(
            3.0, 80, TOY_SPEEDS, &Adaptive::default(), &lat, 5,
        );
        let b = simulate_gang_policy(
            3.0, 80, TOY_SPEEDS, &Adaptive::default(), &lat, 5,
        );
        assert_eq!(a.mean_sojourn_s, b.mean_sojourn_s);
        assert_eq!(a.throughput_rps, b.throughput_rps);
        assert_eq!(a.mean_gang_size, b.mean_gang_size);
    }

    #[test]
    fn sharding_beats_whole_fleet_under_load() {
        // Under heavy load, FixedGang(2) runs two requests at once;
        // AllGpus serializes. With the toy model's strong fixed
        // overhead, two half-fleet gangs clear the queue faster.
        let lat = toy_latency(TOY_SPEEDS);
        let rate = 6.0; // well past AllGpus capacity (~2.6 rps)
        let all =
            simulate_gang_policy(rate, 150, TOY_SPEEDS, &AllGpus, &lat, 9);
        let duo = simulate_gang_policy(
            rate, 150, TOY_SPEEDS, &FixedGang(2), &lat, 9,
        );
        assert!(
            duo.throughput_rps > all.throughput_rps,
            "fixed:2 {} <= all {}",
            duo.throughput_rps,
            all.throughput_rps
        );
        // But one request on the whole fleet is served faster.
        assert!(all.mean_service_s < duo.mean_service_s);
    }

    // --- mixed priority/deadline workload ----------------------------

    /// Interactive small/urgent requests sharing the fleet with heavy
    /// batch work — the canonical mixed traffic shape.
    fn mixed_classes() -> Vec<WorkloadClass> {
        vec![
            WorkloadClass {
                name: "interactive".into(),
                weight: 0.5,
                service_s: 0.08,
                priority: 2,
                deadline_s: Some(0.5),
                resolution: Some((128, 256)),
            },
            WorkloadClass {
                name: "batch".into(),
                weight: 0.5,
                service_s: 0.4,
                priority: 0,
                deadline_s: None,
                resolution: Some((256, 256)),
            },
        ]
    }

    /// Satellite regression: the mixed-resolution DES is a pure
    /// function of its seed — two runs serialize byte-identically
    /// (stats JSON included), and a different seed actually changes
    /// the trajectory (the test isn't vacuous).
    #[test]
    fn mixed_resolution_stats_json_is_byte_identical_per_seed() {
        let classes = mixed_classes();
        for d in [Discipline::Fifo, Discipline::PriorityEdf] {
            let a = simulate_mixed_workload(6.0, 300, &classes, d, 2, 42);
            let b = simulate_mixed_workload(6.0, 300, &classes, d, 2, 42);
            let ja = crate::util::json::to_string(&a.to_json());
            let jb = crate::util::json::to_string(&b.to_json());
            assert_eq!(ja, jb, "{d:?} DES drifted across identical runs");
            // Resolutions are echoed into the JSON.
            assert!(ja.contains("\"resolution\":\"128x256\""), "{ja}");
            let c = simulate_mixed_workload(6.0, 300, &classes, d, 2, 43);
            let jc = crate::util::json::to_string(&c.to_json());
            assert_ne!(ja, jc, "{d:?} seed does not reach the DES");
        }
    }

    #[test]
    fn mixed_sim_deterministic_and_paired_across_disciplines() {
        let classes = mixed_classes();
        let a = simulate_mixed_workload(
            4.0, 200, &classes, Discipline::Fifo, 2, 7,
        );
        let b = simulate_mixed_workload(
            4.0, 200, &classes, Discipline::Fifo, 2, 7,
        );
        assert_eq!(
            a.class("interactive").completed,
            b.class("interactive").completed
        );
        assert_eq!(a.throughput_rps, b.throughput_rps);
        // Same seed, different discipline: identical arrivals, so the
        // per-class arrival counts match exactly (paired comparison).
        let c = simulate_mixed_workload(
            4.0, 200, &classes, Discipline::PriorityEdf, 2, 7,
        );
        assert_eq!(
            a.class("batch").arrived,
            c.class("batch").arrived
        );
    }

    #[test]
    fn fifo_never_sheds_and_low_load_meets_everything() {
        let classes = mixed_classes();
        // Utilization ~12%: both disciplines meet essentially all
        // deadlines; FIFO must never shed by construction.
        for d in [Discipline::Fifo, Discipline::PriorityEdf] {
            let s = simulate_mixed_workload(0.5, 200, &classes, d, 2, 3);
            if d == Discipline::Fifo {
                assert_eq!(s.shed, 0);
            }
            assert!(
                s.deadlines_met as f64
                    >= 0.95 * s.deadlines_total as f64,
                "{d:?} missed deadlines at 12% load: {}/{}",
                s.deadlines_met,
                s.deadlines_total
            );
        }
    }

    /// The acceptance criterion of the v2 redesign, pinned in an
    /// always-runnable test: at 2x overload the priority/deadline
    /// discipline must meet strictly more deadlines than FIFO and cut
    /// the high-priority p95 sojourn.
    #[test]
    fn priority_edf_beats_fifo_on_high_priority_at_2x_load() {
        let classes = mixed_classes();
        // Capacity of 2 servers at E[S] = 0.24s is ~8.3 rps; drive 2x.
        let mean_s = 0.5 * 0.08 + 0.5 * 0.4;
        let rate = 2.0 * 2.0 / mean_s;
        let fifo = simulate_mixed_workload(
            rate, 400, &classes, Discipline::Fifo, 2, 11,
        );
        let pq = simulate_mixed_workload(
            rate, 400, &classes, Discipline::PriorityEdf, 2, 11,
        );
        assert!(
            pq.deadlines_met > fifo.deadlines_met,
            "priority/deadline met {} deadlines vs FIFO {} at 2x load",
            pq.deadlines_met,
            fifo.deadlines_met
        );
        let (hi_pq, hi_fifo) =
            (pq.class("interactive"), fifo.class("interactive"));
        assert!(
            hi_pq.p95_sojourn_s < hi_fifo.p95_sojourn_s,
            "high-priority p95 {} vs FIFO {}",
            hi_pq.p95_sojourn_s,
            hi_fifo.p95_sojourn_s
        );
        // Under 2x overload FIFO queues grow without bound, so its
        // interactive class misses nearly everything; EDF sheds or
        // serves, it doesn't serve uselessly late.
        assert!(
            hi_fifo.deadlines_met < hi_fifo.deadlines_total / 2,
            "FIFO unexpectedly fine: {}/{}",
            hi_fifo.deadlines_met,
            hi_fifo.deadlines_total
        );
    }

    #[test]
    fn unplannable_gang_counts_as_failed_not_wedged() {
        // A latency model that rejects singleton gangs: FixedGang(1)
        // must fail every request (planner says no) yet terminate.
        let lat = |gang: &[usize]| -> Option<f64> {
            if gang.len() < 2 {
                None
            } else {
                Some(0.1)
            }
        };
        let s = simulate_gang_policy(
            2.0, 40, TOY_SPEEDS, &FixedGang(1), &lat, 3,
        );
        assert_eq!(s.completed, 0);
        assert_eq!(s.failed, 40);
    }

    /// The PR 7 acceptance criterion, pinned always-runnable: from 2x
    /// overload up, admission-window fusion must deliver strictly more
    /// throughput than disjoint leases without giving back deadline
    /// hits, and its p95 penalty is bounded by window + amortized
    /// batch growth at every load.
    #[test]
    fn batched_frontier_beats_disjoint_at_overload() {
        let cfg = BatchFrontierConfig::stub_fixture();
        let sweep = simulate_batch_frontier(&cfg);
        assert_eq!(sweep.points.len(), cfg.load_multiples.len());
        let p95_slack = cfg.window_s
            + (cfg.service_s(cfg.max_batch) - cfg.service_s(1))
            + 1e-9;
        for p in &sweep.points {
            if p.load_x >= 2.0 {
                assert!(
                    p.batched.throughput_rps
                        > p.disjoint.throughput_rps,
                    "batched {} rps <= disjoint {} rps at {}x load",
                    p.batched.throughput_rps,
                    p.disjoint.throughput_rps,
                    p.load_x
                );
                assert!(
                    p.batched.deadline_hit_rate
                        >= p.disjoint.deadline_hit_rate,
                    "batched hit-rate {} < disjoint {} at {}x load",
                    p.batched.deadline_hit_rate,
                    p.disjoint.deadline_hit_rate,
                    p.load_x
                );
                // Saturated arrivals fill the window: sessions fuse
                // (at exactly 2x the mix is A-pairs plus solo B's,
                // i.e. a mean of 1.5; denser loads fuse harder).
                assert!(
                    p.batched.mean_group >= 1.5 - 1e-9,
                    "no fusion at {}x load: mean group {}",
                    p.load_x,
                    p.batched.mean_group
                );
            }
            // p95 delta bounded at every load, including underload
            // where batching can only lose latency.
            assert!(
                p.batched.p95_sojourn_s
                    <= p.disjoint.p95_sojourn_s + p95_slack,
                "unbounded p95 delta at {}x: {} vs {} (slack {})",
                p.load_x,
                p.batched.p95_sojourn_s,
                p.disjoint.p95_sojourn_s,
                p95_slack
            );
            assert!(
                (p.disjoint.mean_group - 1.0).abs() < 1e-12,
                "disjoint side must never fuse"
            );
            assert!(
                p.batched.mean_group <= cfg.max_batch as f64 + 1e-12
            );
        }
    }

    /// The sweep is RNG-free; two runs must serialize byte-identically
    /// (this is what lets `scripts/gen_bench_artifacts.py` mirror it
    /// and `BENCH_batching.json` stay reproducible).
    #[test]
    fn batch_frontier_is_deterministic_and_json_stable() {
        let cfg = BatchFrontierConfig::stub_fixture();
        let a = simulate_batch_frontier(&cfg);
        let b = simulate_batch_frontier(&cfg);
        assert_eq!(a, b);
        let ja = crate::util::json::to_string(&a.to_json());
        assert_eq!(ja, crate::util::json::to_string(&b.to_json()));
        // Schema gate: every committed BENCH_*.json must carry a
        // "halo" key; the frontier labels its comm-sharing mode.
        assert!(ja.contains("\"halo\""));
        assert!(ja.contains("\"points\""));
    }

    /// Underload sanity: with arrivals further apart than the window,
    /// nothing fuses and the batched side degrades to solo sessions
    /// plus the admission-window wait — never worse than that.
    #[test]
    fn batch_frontier_underload_degenerates_to_solo_plus_window() {
        let mut cfg = BatchFrontierConfig::stub_fixture();
        cfg.load_multiples = vec![0.1];
        cfg.n_requests = 40;
        let sweep = simulate_batch_frontier(&cfg);
        let p = &sweep.points[0];
        assert!((p.batched.mean_group - 1.0).abs() < 1e-12);
        let expect = cfg.service_s(1) + cfg.window_s;
        assert!(
            (p.batched.mean_sojourn_s - expect).abs() < 1e-9,
            "solo-plus-window sojourn {} vs expected {}",
            p.batched.mean_sojourn_s,
            expect
        );
        assert!(
            (p.disjoint.mean_sojourn_s - cfg.service_s(1)).abs()
                < 1e-9
        );
    }

    /// The tentpole claim of BENCH_federation: at every load point at
    /// or past 2x a single node's capacity, on every trace, migration
    /// strictly beats migration-off federation, which strictly beats
    /// the single-node baseline, on deadline hits — and the wins come
    /// from actual barrier handoffs, not routing luck.
    #[test]
    fn federation_migration_strictly_wins_at_high_load() {
        let cfg = FederationSimConfig::stub_fixture();
        let sweep = simulate_federation_frontier(&cfg);
        let mut asserted = 0usize;
        for tr in &sweep.traces {
            for p in &tr.points {
                if p.load_x < 2.0 {
                    continue;
                }
                asserted += 1;
                assert!(
                    p.fed_mig.deadline_hit_rate
                        > p.fed_nomig.deadline_hit_rate,
                    "{} x{}: migration must beat nomig ({} vs {})",
                    tr.trace,
                    p.load_x,
                    p.fed_mig.deadline_hit_rate,
                    p.fed_nomig.deadline_hit_rate
                );
                assert!(
                    p.fed_nomig.deadline_hit_rate
                        > p.single.deadline_hit_rate,
                    "{} x{}: federation must beat single ({} vs {})",
                    tr.trace,
                    p.load_x,
                    p.fed_nomig.deadline_hit_rate,
                    p.single.deadline_hit_rate
                );
                assert!(
                    p.fed_mig.migrations > 0,
                    "{} x{}: the winning side must actually migrate",
                    tr.trace,
                    p.load_x
                );
            }
        }
        assert!(asserted >= 6, "sweep must cover >= 2x on every trace");
    }

    /// Discipline invariants that hold at every point: the single-node
    /// baseline can neither spill nor migrate, the migration-off side
    /// never migrates, and every run serves all requests.
    #[test]
    fn federation_disciplines_respect_their_contracts() {
        let cfg = FederationSimConfig::stub_fixture();
        let sweep = simulate_federation_frontier(&cfg);
        for tr in &sweep.traces {
            for p in &tr.points {
                assert_eq!(p.single.migrations, 0);
                assert_eq!(p.single.spills, 0);
                assert_eq!(p.fed_nomig.migrations, 0);
                for side in
                    [&p.single, &p.fed_nomig, &p.fed_mig]
                {
                    assert!(side.deadline_hit_rate >= 0.0);
                    assert!(side.deadline_hit_rate <= 1.0);
                    assert!(side.throughput_rps > 0.0);
                    assert!(side.mean_sojourn_s > 0.0);
                    assert!(
                        side.p95_sojourn_s
                            >= side.mean_sojourn_s * 0.5
                    );
                }
            }
        }
    }

    /// RNG-free determinism + the BENCH schema gate: two sweeps
    /// serialize byte-identically and carry the "halo" key that
    /// scripts/check.sh requires of every committed BENCH_*.json.
    #[test]
    fn federation_frontier_is_deterministic_and_json_stable() {
        let cfg = FederationSimConfig::stub_fixture();
        let a = simulate_federation_frontier(&cfg);
        let b = simulate_federation_frontier(&cfg);
        assert_eq!(a, b);
        let ja = crate::util::json::to_string(&a.to_json());
        assert_eq!(ja, crate::util::json::to_string(&b.to_json()));
        assert!(ja.contains("\"halo\""));
        assert!(ja.contains("\"checkpoint-migration\""));
        assert!(ja.contains("\"traces\""));
        assert!(ja.contains("\"window_s\""));
    }

    /// The arrival generators are closed-form: non-decreasing, sized
    /// to n, and the flash crowd really compresses its middle third.
    #[test]
    fn federation_arrivals_are_ordered_and_shaped() {
        for trace in FEDERATION_TRACES {
            let arr = federation_arrivals(trace, 4.0, 120);
            assert_eq!(arr.len(), 120);
            for w in arr.windows(2) {
                assert!(w[1] >= w[0], "{trace} must be non-decreasing");
            }
        }
        let flash = federation_arrivals("flash", 4.0, 120);
        let crowd = flash[59] - flash[40];
        let steady = flash[100] - flash[81];
        assert!(
            crowd < steady * 0.5,
            "flash crowd must arrive >= 2x denser"
        );
    }

    /// The tentpole claim of BENCH_degradation: at every load point
    /// at or past 2x the pool's capacity the ladder converts strictly
    /// more deadline misses into hits than shedding alone, the wins
    /// come from actual demotions paid in tiers, and no request is
    /// ever served below the configured floor.
    #[test]
    fn degradation_ladder_strictly_wins_at_overload() {
        let cfg = DegradeSimConfig::stub_fixture();
        let sweep = simulate_degradation_frontier(&cfg);
        assert_eq!(sweep.points.len(), cfg.load_multiples.len());
        let floor =
            crate::serve::degrade::tier_rank(cfg.degrade.floor);
        let mut asserted = 0usize;
        let mut requant_total = 0usize;
        for p in &sweep.points {
            // The OFF side never touches either lever, and its tier
            // mix is the arrival mix exactly.
            assert_eq!(p.off.demoted, 0, "x{}", p.load_x);
            assert_eq!(p.off.requantized, 0, "x{}", p.load_x);
            assert!((p.off.mean_tier - 1.0).abs() < 1e-12);
            // Floor guarantee at every load, not just overload.
            assert!(
                p.on.min_tier >= floor,
                "x{}: served below the floor",
                p.load_x
            );
            requant_total += p.on.requantized;
            if p.load_x < 2.0 {
                continue;
            }
            asserted += 1;
            assert!(
                p.on.deadline_hit_rate > p.off.deadline_hit_rate,
                "x{}: ladder must beat shedding ({} vs {})",
                p.load_x,
                p.on.deadline_hit_rate,
                p.off.deadline_hit_rate
            );
            assert!(
                p.on.demoted > 0,
                "x{}: the winning side must demote",
                p.load_x
            );
            assert!(
                p.on.mean_tier < p.off.mean_tier,
                "x{}: the win is paid in tiers",
                p.load_x
            );
        }
        assert!(asserted >= 3, "sweep must cover >= 2x");
        assert!(
            requant_total > 0,
            "the top rung must fire somewhere in the sweep"
        );
    }

    /// Raising the floor to standard really binds: high-tier arrivals
    /// stop one rung up (mean served tier can lose at most 1/3),
    /// which preserves quality relative to the draft floor and pays
    /// for it in deadline hits at 3x load.
    #[test]
    fn degradation_floor_binds_at_standard() {
        let mut std_cfg = DegradeSimConfig::stub_fixture();
        std_cfg.degrade.floor = crate::spec::Quality::Standard;
        std_cfg.load_multiples = vec![3.0];
        let mut draft_cfg = DegradeSimConfig::stub_fixture();
        draft_cfg.load_multiples = vec![3.0];
        let std_p =
            &simulate_degradation_frontier(&std_cfg).points[0];
        let draft_p =
            &simulate_degradation_frontier(&draft_cfg).points[0];
        assert!(std_p.on.demoted > 0);
        assert!(draft_p.on.demoted > 0);
        // Only High -> Standard demotions remain: the served mean
        // cannot drop below (1 + 1 + 0) / 3.
        assert!(
            std_p.on.mean_tier >= 2.0 / 3.0 - 1e-12,
            "standard floor crossed: mean tier {}",
            std_p.on.mean_tier
        );
        assert!(
            std_p.on.mean_tier > draft_p.on.mean_tier,
            "higher floor must preserve more quality ({} vs {})",
            std_p.on.mean_tier,
            draft_p.on.mean_tier
        );
        assert!(
            std_p.on.deadline_hit_rate
                <= draft_p.on.deadline_hit_rate,
            "quality preserved must cost hits, not conjure them"
        );
    }

    /// RNG-free determinism + the BENCH schema gate: two sweeps
    /// serialize byte-identically and carry the "halo" key that
    /// scripts/check.sh requires of every committed BENCH_*.json.
    #[test]
    fn degradation_frontier_is_deterministic_and_json_stable() {
        let cfg = DegradeSimConfig::stub_fixture();
        let a = simulate_degradation_frontier(&cfg);
        let b = simulate_degradation_frontier(&cfg);
        assert_eq!(a, b);
        let ja = crate::util::json::to_string(&a.to_json());
        assert_eq!(ja, crate::util::json::to_string(&b.to_json()));
        assert!(ja.contains("\"halo\""));
        assert!(ja.contains("\"quality-ladder\""));
        assert!(ja.contains("\"points\""));
        assert!(ja.contains("\"pressure_thresholds\""));
        assert!(ja.contains("\"floor\":\"draft\""));
    }

    /// The arrival train is closed-form: steady spacing, sized to n,
    /// starting at zero; the tier cycle really averages 1.0.
    #[test]
    fn degradation_arrivals_and_tiers_are_shaped() {
        let arr = degradation_arrivals(4.0, 17);
        assert_eq!(arr.len(), 17);
        assert_eq!(arr[0], 0.0);
        for w in arr.windows(2) {
            assert!((w[1] - w[0] - 0.25).abs() < 1e-12);
        }
        use crate::spec::Quality;
        assert_eq!(degrade_tier(0), Quality::High);
        assert_eq!(degrade_tier(1), Quality::Standard);
        assert_eq!(degrade_tier(2), Quality::Draft);
        let sum: f64 = (0..240)
            .map(|i| {
                crate::serve::degrade::tier_rank(degrade_tier(i))
                    as f64
            })
            .sum();
        assert!((sum / 240.0 - 1.0).abs() < 1e-12);
    }
}
