//! Request router: bounded FIFO queue with backpressure + per-request
//! metrics, decoupling protocol handling from the engine.
//!
//! The engine executes one request at a time (the whole cluster
//! cooperates on each image — the paper targets single-request
//! latency, §II-C), so the router's job is admission control and
//! ordering: reject when the queue is full (backpressure), serve in
//! arrival order, and keep latency statistics per outcome.

use std::collections::VecDeque;

use crate::coordinator::{Engine, Generation, Request};
use crate::error::{Error, Result};
use crate::metrics::latency::LatencyTracker;

/// A queued unit of work.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: String,
    pub seed: u64,
}

/// Router statistics snapshot.
#[derive(Debug, Clone)]
pub struct RouterStats {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub queue_len: usize,
    pub latency_summary: String,
}

/// FIFO router with a bounded queue.
pub struct Router {
    queue: VecDeque<Job>,
    capacity: usize,
    admitted: u64,
    rejected: u64,
    completed: u64,
    failed: u64,
    latency: LatencyTracker,
}

impl Router {
    pub fn new(capacity: usize) -> Self {
        Router {
            queue: VecDeque::new(),
            capacity: capacity.max(1),
            admitted: 0,
            rejected: 0,
            completed: 0,
            failed: 0,
            latency: LatencyTracker::new(),
        }
    }

    /// Admit a job, or reject with backpressure when full.
    pub fn submit(&mut self, job: Job) -> Result<()> {
        if self.queue.len() >= self.capacity {
            self.rejected += 1;
            return Err(Error::Protocol(format!(
                "queue full ({} jobs), request {} rejected",
                self.queue.len(),
                job.id
            )));
        }
        self.admitted += 1;
        self.queue.push_back(job);
        Ok(())
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Pop and execute the next job on the engine.
    /// Returns None when idle.
    pub fn serve_next(
        &mut self,
        engine: &mut Engine,
    ) -> Option<(Job, Result<(Generation, f64)>)> {
        let job = self.queue.pop_front()?;
        let t0 = std::time::Instant::now();
        let res = engine.generate(&Request { seed: job.seed });
        let wall = t0.elapsed().as_secs_f64();
        let out = match res {
            Ok(g) => {
                self.completed += 1;
                self.latency.record(wall);
                Ok((g, wall))
            }
            Err(e) => {
                self.failed += 1;
                Err(e)
            }
        };
        Some((job, out))
    }

    /// Drain the whole queue.
    pub fn serve_all(
        &mut self,
        engine: &mut Engine,
    ) -> Vec<(Job, Result<(Generation, f64)>)> {
        let mut out = Vec::new();
        while let Some(r) = self.serve_next(engine) {
            out.push(r);
        }
        out
    }

    pub fn stats(&self) -> RouterStats {
        RouterStats {
            admitted: self.admitted,
            rejected: self.rejected,
            completed: self.completed,
            failed: self.failed,
            queue_len: self.queue.len(),
            latency_summary: self.latency.summary(),
        }
    }

    pub fn mean_latency_s(&self) -> f64 {
        self.latency.mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_backpressure() {
        let mut r = Router::new(2);
        r.submit(Job { id: "a".into(), seed: 1 }).unwrap();
        r.submit(Job { id: "b".into(), seed: 2 }).unwrap();
        let err = r.submit(Job { id: "c".into(), seed: 3 }).unwrap_err();
        assert!(err.to_string().contains("rejected"));
        assert_eq!(r.queue_len(), 2);
        let s = r.stats();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.rejected, 1);
        // FIFO: front is "a".
        assert_eq!(r.queue.front().unwrap().id, "a");
    }

    #[test]
    fn property_queue_never_exceeds_capacity() {
        use crate::util::proptest::{ensure, forall};
        forall(
            7,
            100,
            |rng| {
                (0..rng.below(40))
                    .map(|_| rng.below(2) as usize)
                    .collect::<Vec<usize>>()
            },
            |ops| {
                // op 0 = submit, op 1 = pop (without engine).
                let mut r = Router::new(4);
                let mut next = 0u64;
                for &op in ops {
                    if op == 0 {
                        next += 1;
                        let _ = r.submit(Job {
                            id: format!("j{next}"),
                            seed: next,
                        });
                    } else {
                        r.queue.pop_front();
                    }
                    ensure(r.queue_len() <= 4, "capacity exceeded")?;
                }
                let s = r.stats();
                ensure(
                    s.admitted + s.rejected == next,
                    "admission accounting broken",
                )?;
                Ok(())
            },
        );
    }
}
