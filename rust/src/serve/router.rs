//! Request router: a thread-safe bounded FIFO queue with backpressure
//! and per-outcome latency metrics, decoupling admission control from
//! execution.
//!
//! Connection handlers `submit` from their own threads; the worker
//! pool blocks in `pop` until work (or shutdown) arrives. Rejection is
//! a structured [`Error::Busy`] carrying the observed queue depth —
//! the wire protocol reports it as a `busy` code plus a `queue_depth`
//! field instead of leaking internal state into the message string.
//!
//! The router is generic over the queued payload so the serving layer
//! can enqueue jobs bundled with their reply route while unit tests
//! use bare [`Job`]s (the default payload type).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::error::{Error, Result};
use crate::metrics::latency::LatencyTracker;

/// A queued unit of work.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: String,
    pub seed: u64,
}

/// Router statistics snapshot.
#[derive(Debug, Clone)]
pub struct RouterStats {
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub queue_len: usize,
    /// Mean completed-job latency (exact over all samples).
    pub latency_mean_s: f64,
    /// Median / tail latency from the tracker's bounded reservoir.
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_summary: String,
}

struct Inner<T> {
    queue: VecDeque<T>,
    closed: bool,
    admitted: u64,
    rejected: u64,
    completed: u64,
    failed: u64,
    latency: LatencyTracker,
}

/// Thread-safe FIFO router with a bounded queue.
pub struct Router<T = Job> {
    capacity: usize,
    inner: Mutex<Inner<T>>,
    /// Signalled on submit (work available) and close (shutdown).
    available: Condvar,
}

impl<T> Router<T> {
    pub fn new(capacity: usize) -> Self {
        Router {
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                closed: false,
                admitted: 0,
                rejected: 0,
                completed: 0,
                failed: 0,
                latency: LatencyTracker::new(),
            }),
            available: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit an item, or reject with backpressure when full / closed.
    pub fn submit(&self, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            g.rejected += 1;
            return Err(Error::Protocol("router is shut down".into()));
        }
        if g.queue.len() >= self.capacity {
            g.rejected += 1;
            return Err(Error::Busy { queue_depth: g.queue.len() });
        }
        g.admitted += 1;
        g.queue.push_back(item);
        self.available.notify_one();
        Ok(())
    }

    /// Block until an item is available (FIFO) or the router closes.
    /// Returns `None` only after `close()`.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(x) = g.queue.pop_front() {
                return Some(x);
            }
            if g.closed {
                return None;
            }
            g = self.available.wait(g).unwrap();
        }
    }

    /// Non-blocking pop (tests / drain loops).
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().queue.pop_front()
    }

    /// Close the router: wake every blocked `pop`, reject future
    /// submits, and hand back the still-queued items so the caller can
    /// answer their submitters (the server sends shutdown error lines
    /// rather than leaving clients waiting on a response that will
    /// never come).
    pub fn drain_close(&self) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        let drained: Vec<T> = g.queue.drain(..).collect();
        self.available.notify_all();
        drained
    }

    /// Close and discard queued items; returns how many were dropped.
    pub fn close(&self) -> usize {
        self.drain_close().len()
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    pub fn queue_len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Record the outcome of one executed item (workers call this).
    pub fn record_outcome(&self, ok: bool, latency_s: f64) {
        let mut g = self.inner.lock().unwrap();
        if ok {
            g.completed += 1;
            g.latency.record(latency_s);
        } else {
            g.failed += 1;
        }
    }

    pub fn stats(&self) -> RouterStats {
        let g = self.inner.lock().unwrap();
        RouterStats {
            admitted: g.admitted,
            rejected: g.rejected,
            completed: g.completed,
            failed: g.failed,
            queue_len: g.queue.len(),
            latency_mean_s: g.latency.mean(),
            latency_p50_s: g.latency.p50(),
            latency_p95_s: g.latency.p95(),
            latency_summary: g.latency.summary(),
        }
    }

    pub fn mean_latency_s(&self) -> f64 {
        self.inner.lock().unwrap().latency.mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_and_backpressure() {
        let r: Router<Job> = Router::new(2);
        r.submit(Job { id: "a".into(), seed: 1 }).unwrap();
        r.submit(Job { id: "b".into(), seed: 2 }).unwrap();
        let err = r.submit(Job { id: "c".into(), seed: 3 }).unwrap_err();
        match err {
            Error::Busy { queue_depth } => assert_eq!(queue_depth, 2),
            other => panic!("expected Busy, got {other}"),
        }
        assert_eq!(r.queue_len(), 2);
        let s = r.stats();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.rejected, 1);
        // FIFO: front is "a".
        assert_eq!(r.pop().unwrap().id, "a");
        assert_eq!(r.pop().unwrap().id, "b");
    }

    #[test]
    fn close_wakes_blocked_pop_and_discards_queue() {
        let r: Arc<Router<Job>> = Arc::new(Router::new(4));
        let waiter = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || r.pop())
        };
        r.submit(Job { id: "x".into(), seed: 1 }).unwrap();
        // `pop` blocks until work or close, so the waiter is
        // guaranteed to drain the item eventually; spin (no timing
        // assumptions) until it has.
        while r.queue_len() > 0 {
            std::thread::yield_now();
        }
        assert!(waiter.join().unwrap().is_some());
        // A second waiter blocks on the now-empty queue: close() must
        // wake it (no item will ever arrive) and make it return None.
        let blocked = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || r.pop())
        };
        // Best-effort pause so the waiter actually blocks in `wait`
        // (the assertion holds either way: pop on a closed empty
        // router returns None immediately).
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(r.close(), 0, "queue already drained");
        assert!(blocked.join().unwrap().is_none());
        // After close: pops return None, submits are rejected.
        assert!(r.is_closed());
        assert!(r.pop().is_none());
        assert!(r.submit(Job { id: "y".into(), seed: 2 }).is_err());
    }

    #[test]
    fn concurrent_producers_consumers_account_exactly() {
        let r: Arc<Router<u64>> = Arc::new(Router::new(8));
        let n_producers = 4;
        let per_producer = 50u64;
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let mut got = 0u64;
                    while r.pop().is_some() {
                        got += 1;
                    }
                    got
                })
            })
            .collect();
        let producers: Vec<_> = (0..n_producers)
            .map(|p| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let mut accepted = 0u64;
                    for i in 0..per_producer {
                        loop {
                            match r.submit(p * 1000 + i) {
                                Ok(()) => break,
                                Err(Error::Busy { .. }) => {
                                    std::thread::yield_now()
                                }
                                Err(_) => return accepted,
                            }
                        }
                        accepted += 1;
                    }
                    accepted
                })
            })
            .collect();
        let sent: u64 =
            producers.into_iter().map(|h| h.join().unwrap()).sum();
        // All producers retried until accepted.
        assert_eq!(sent, n_producers * per_producer);
        // Let consumers drain before closing — close() discards
        // whatever is still queued.
        while r.queue_len() > 0 {
            std::thread::yield_now();
        }
        r.close();
        let got: u64 = consumers.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(got, sent);
        let s = r.stats();
        assert_eq!(s.admitted, sent);
        assert_eq!(s.queue_len, 0);
    }

    #[test]
    fn stats_expose_latency_percentiles() {
        let r: Router<u64> = Router::new(4);
        for i in 1..=100 {
            r.record_outcome(true, i as f64 / 100.0);
        }
        // Failures count, but never pollute the latency distribution.
        r.record_outcome(false, 9.9);
        let s = r.stats();
        assert_eq!(s.completed, 100);
        assert_eq!(s.failed, 1);
        assert!((s.latency_mean_s - 0.505).abs() < 1e-9);
        assert!((s.latency_p50_s - 0.505).abs() < 0.02);
        assert!((s.latency_p95_s - 0.955).abs() < 0.02);
        assert!(s.latency_p95_s < 2.0, "failure latency leaked in");
    }

    #[test]
    fn property_queue_never_exceeds_capacity() {
        use crate::util::proptest::{ensure, forall};
        forall(
            7,
            100,
            |rng| {
                (0..rng.below(40))
                    .map(|_| rng.below(2) as usize)
                    .collect::<Vec<usize>>()
            },
            |ops| {
                // op 0 = submit, op 1 = pop.
                let r: Router<u64> = Router::new(4);
                let mut next = 0u64;
                for &op in ops {
                    if op == 0 {
                        next += 1;
                        let _ = r.submit(next);
                    } else {
                        r.try_pop();
                    }
                    ensure(r.queue_len() <= 4, "capacity exceeded")?;
                }
                let s = r.stats();
                ensure(
                    s.admitted + s.rejected == next,
                    "admission accounting broken",
                )?;
                Ok(())
            },
        );
    }
}
