//! Request router: a thread-safe bounded **priority queue** with
//! backpressure, deadline shedding, and per-outcome latency metrics,
//! decoupling admission control from execution.
//!
//! Ordering is (priority desc, earliest deadline, FIFO): higher
//! [`Prioritized::priority_rank`] first; within a rank, requests with
//! deadlines run earliest-deadline-first ahead of deadline-less ones;
//! among equals, submission order. Payloads without priorities (the
//! default trait impls) degrade to exactly the old FIFO behavior.
//!
//! Deadline shedding happens **on dequeue**: a request whose deadline
//! already passed when a worker picks it up is handed back as
//! [`Dequeued::Expired`] so the caller can answer it with a typed
//! [`Error::DeadlineExceeded`] (wire code `deadline`) instead of
//! burning GPU time on a response nobody is waiting for.
//!
//! Connection handlers `submit` from their own threads; the worker
//! pool blocks in `pop` until work (or shutdown) arrives. Rejection is
//! a structured [`Error::Busy`] carrying the observed queue depth —
//! the wire protocol reports it as a `busy` code plus a `queue_depth`
//! field instead of leaking internal state into the message string.
//!
//! The router is generic over the queued payload so the serving layer
//! can enqueue jobs bundled with their reply route while unit tests
//! use bare [`Job`]s (the default payload type).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::metrics::latency::LatencyTracker;
use crate::spec::GenerationSpec;

/// Queue-discipline hooks for router payloads. The defaults (constant
/// rank, no deadline) give plain FIFO — payload types only override
/// what they carry.
pub trait Prioritized {
    /// Higher = served first.
    fn priority_rank(&self) -> u8 {
        0
    }

    /// Absolute shed deadline; `None` = serve whenever.
    fn deadline(&self) -> Option<Instant> {
        None
    }
}

/// Plain payloads used by unit tests / simple harnesses.
impl Prioritized for u64 {}
impl Prioritized for String {}

/// A queued unit of work: request id + full generation spec, stamped
/// with its admission time (deadlines are relative to admission).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: String,
    pub spec: GenerationSpec,
    /// Absolute deadline, fixed when the job was created at admission.
    pub deadline: Option<Instant>,
}

impl Job {
    /// Build a job from a parsed request, stamping `spec.deadline_s`
    /// against the current time.
    pub fn new(id: impl Into<String>, spec: GenerationSpec) -> Job {
        let deadline = spec
            .deadline_s
            .map(|d| Instant::now() + std::time::Duration::from_secs_f64(d));
        Job { id: id.into(), spec, deadline }
    }

    /// v1 shape: default spec around a bare seed.
    pub fn seeded(id: impl Into<String>, seed: u64) -> Job {
        Job::new(id, GenerationSpec::new().seed(seed))
    }

    pub fn seed(&self) -> u64 {
        self.spec.seed
    }

    /// Seconds until the deadline (negative = already expired).
    pub fn deadline_slack_s(&self) -> Option<f64> {
        self.deadline.map(|d| {
            let now = Instant::now();
            if d >= now {
                (d - now).as_secs_f64()
            } else {
                -((now - d).as_secs_f64())
            }
        })
    }
}

impl Prioritized for Job {
    fn priority_rank(&self) -> u8 {
        self.spec.priority.rank()
    }

    fn deadline(&self) -> Option<Instant> {
        self.deadline
    }
}

/// One dequeued item: ready to run, or already past its deadline (the
/// caller owes its client a typed `deadline` error, not a result).
#[derive(Debug)]
pub enum Dequeued<T> {
    Ready(T),
    Expired(T),
}

impl<T> Dequeued<T> {
    pub fn into_inner(self) -> T {
        match self {
            Dequeued::Ready(t) | Dequeued::Expired(t) => t,
        }
    }
}

/// Queue position: priority desc, then earliest deadline (deadline-less
/// after every deadline at the same rank), then submission order.
/// `Ord` is derived lexicographically over the inverted rank, the
/// deadline key, and the sequence number.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct OrderKey {
    rank_inv: u8,
    deadline: DeadlineKey,
    seq: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct DeadlineKey(Option<Instant>);

impl Ord for DeadlineKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        use std::cmp::Ordering::*;
        match (self.0, other.0) {
            (Some(a), Some(b)) => a.cmp(&b),
            (Some(_), None) => Less,
            (None, Some(_)) => Greater,
            (None, None) => Equal,
        }
    }
}

impl PartialOrd for DeadlineKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Router statistics snapshot.
#[derive(Debug, Clone)]
pub struct RouterStats {
    pub admitted: u64,
    pub rejected: u64,
    /// Rejected by the admission gate (`JobRunner::admit`) before ever
    /// entering the queue — e.g. unregistered resolutions answered
    /// with `bad_spec`. Disjoint from `admitted`/`rejected`, so
    /// operators can tell a junk-request flood from an idle server.
    pub inadmissible: u64,
    pub completed: u64,
    pub failed: u64,
    /// Dequeued after their deadline had already passed (subset of
    /// whatever outcome the caller then records — the serve worker
    /// records them as failed). Counts both dequeue-time expiries and
    /// entries moved to the expiry pen by the slot sweep.
    pub deadline_shed: u64,
    /// Requests the degradation ladder demoted at admission (one per
    /// request, however many rungs it walked).
    pub demoted: u64,
    /// Requests whose running step suffix was re-quantized mid-flight
    /// at a sync barrier under queueing pressure.
    pub requantized: u64,
    /// Request lines the lazy in-place scanner handled without
    /// building a JSON tree (`serve::protocol::parse_lazy` fast path).
    pub lazy_parsed: u64,
    /// Request lines that bailed from the lazy scan to the full-tree
    /// parse (escapes, unknown fields, errors — anything unusual).
    pub fallback_parsed: u64,
    /// Lines that blew past the event loop's line-length cap and were
    /// answered with a typed `bad_request` (connection kept).
    pub oversized: u64,
    pub queue_len: usize,
    /// Requests currently parked in a batching admission window
    /// (popped by a worker, not yet executing). Part of the backlog
    /// signal gang policies see.
    pub parked: usize,
    /// Requests served as members of a fused session (founders and
    /// barrier joiners alike).
    pub batched: u64,
    /// Requests served alone (batching off, no compatible peer, or a
    /// window that closed empty).
    pub solo: u64,
    /// Fused sessions dispatched (each counted once).
    pub fused_sessions: u64,
    /// Mean members per fused session (0.0 before the first one).
    pub mean_fused: f64,
    /// Mean completed-job latency (exact over all samples).
    pub latency_mean_s: f64,
    /// Median / tail latency from the tracker's bounded reservoir.
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_summary: String,
}

struct Inner<T> {
    queue: BTreeMap<OrderKey, T>,
    /// Expiry pen: entries whose deadline passed while queued, moved
    /// out of the queue by the slot sweep so they stop occupying
    /// admission capacity. They still surface to workers (ahead of
    /// live work) as [`Dequeued::Expired`] so their clients get a
    /// typed `deadline` answer.
    expired: VecDeque<T>,
    next_seq: u64,
    closed: bool,
    admitted: u64,
    rejected: u64,
    inadmissible: u64,
    completed: u64,
    failed: u64,
    deadline_shed: u64,
    demoted: u64,
    requantized: u64,
    lazy_parsed: u64,
    fallback_parsed: u64,
    oversized: u64,
    parked: usize,
    batched: u64,
    solo: u64,
    fused_sessions: u64,
    fused_members: u64,
    latency: LatencyTracker,
}

/// Thread-safe bounded priority router.
pub struct Router<T = Job> {
    capacity: usize,
    inner: Mutex<Inner<T>>,
    /// Signalled on submit (work available) and close (shutdown).
    available: Condvar,
}

impl<T: Prioritized> Router<T> {
    pub fn new(capacity: usize) -> Self {
        Router {
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                queue: BTreeMap::new(),
                expired: VecDeque::new(),
                next_seq: 0,
                closed: false,
                admitted: 0,
                rejected: 0,
                inadmissible: 0,
                completed: 0,
                failed: 0,
                deadline_shed: 0,
                demoted: 0,
                requantized: 0,
                lazy_parsed: 0,
                fallback_parsed: 0,
                oversized: 0,
                parked: 0,
                batched: 0,
                solo: 0,
                fused_sessions: 0,
                fused_members: 0,
                latency: LatencyTracker::new(),
            }),
            available: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Move already-expired entries from the queue into the expiry
    /// pen, freeing their admission slots. Dequeue-only shedding left
    /// long-expired requests occupying router capacity during a storm
    /// (no worker reached them, so they blocked fresh admissions with
    /// `busy`); the sweep runs on every `submit`/`park`/`backlog` so
    /// capacity always reflects live demand. Returns how many moved.
    fn sweep_expired_locked(g: &mut Inner<T>) -> usize {
        let now = Instant::now();
        let stale: Vec<OrderKey> = g
            .queue
            .iter()
            .filter(|(k, _)| k.deadline.0.is_some_and(|d| d < now))
            .map(|(k, _)| *k)
            .collect();
        let n = stale.len();
        for key in stale {
            let item = g.queue.remove(&key).expect("key just seen");
            g.deadline_shed += 1;
            g.expired.push_back(item);
        }
        n
    }

    /// Admit an item, or reject with backpressure when full / closed.
    /// Expired entries are swept out of the queue first so they never
    /// hold admission slots against live traffic.
    pub fn submit(&self, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            g.rejected += 1;
            return Err(Error::Shutdown);
        }
        Self::sweep_expired_locked(&mut g);
        if g.queue.len() >= self.capacity {
            g.rejected += 1;
            return Err(Error::Busy { queue_depth: g.queue.len() });
        }
        g.admitted += 1;
        let key = OrderKey {
            rank_inv: u8::MAX - item.priority_rank(),
            deadline: DeadlineKey(item.deadline()),
            seq: g.next_seq,
        };
        g.next_seq += 1;
        g.queue.insert(key, item);
        self.available.notify_one();
        Ok(())
    }

    /// Block until an item is available (best order position first) or
    /// the router closes. Returns `None` only after `close()`. An item
    /// whose deadline passed while queued comes back as
    /// [`Dequeued::Expired`] — shed it, don't run it.
    pub fn pop(&self) -> Option<Dequeued<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            // Swept corpses first: their shed was counted at sweep
            // time, and answering them is cheaper than any live run.
            if let Some(item) = g.expired.pop_front() {
                return Some(Dequeued::Expired(item));
            }
            if let Some((key, item)) = g.queue.pop_first() {
                if key.deadline.0.is_some_and(|d| d < Instant::now()) {
                    g.deadline_shed += 1;
                    return Some(Dequeued::Expired(item));
                }
                return Some(Dequeued::Ready(item));
            }
            if g.closed {
                return None;
            }
            g = self.available.wait(g).unwrap();
        }
    }

    /// Non-blocking pop (tests / drain loops); no deadline check.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().queue.pop_first().map(|(_, t)| t)
    }

    /// Dequeue the best-positioned item satisfying `pred`, waiting for
    /// one to arrive until `until` (the batching admission window uses
    /// this to gather fuse-compatible peers for a leader it already
    /// holds). Returns `None` on window expiry or shutdown — both mean
    /// "stop gathering and run what you have", so they are not
    /// distinguished. Non-matching items are left queued, untouched, in
    /// their order positions. Deadline shedding applies exactly as in
    /// [`Router::pop`]: an expired match comes back as
    /// [`Dequeued::Expired`] and still consumes the caller's attention,
    /// not a batch slot.
    pub fn pop_match_timeout(
        &self,
        pred: impl Fn(&T) -> bool,
        until: Instant,
    ) -> Option<Dequeued<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            // Swept corpses consume the gatherer's attention exactly
            // like a dequeue-time expiry would (already shed-counted).
            if let Some(item) = g.expired.pop_front() {
                return Some(Dequeued::Expired(item));
            }
            let found =
                g.queue.iter().find(|(_, t)| pred(t)).map(|(k, _)| *k);
            if let Some(key) = found {
                let item = g.queue.remove(&key).expect("key just seen");
                if key.deadline.0.is_some_and(|d| d < Instant::now()) {
                    g.deadline_shed += 1;
                    return Some(Dequeued::Expired(item));
                }
                return Some(Dequeued::Ready(item));
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            let (guard, _) =
                self.available.wait_timeout(g, until - now).unwrap();
            g = guard;
        }
    }

    /// Close the router: wake every blocked `pop`, reject future
    /// submits, and hand back the still-queued items so the caller can
    /// answer their submitters (the server sends shutdown error lines
    /// rather than leaving clients waiting on a response that will
    /// never come). Items come back in queue order.
    pub fn drain_close(&self) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        // Penned expiries first (oldest debt), then queue order: every
        // submitter still waiting gets an answer.
        let mut drained: Vec<T> = std::mem::take(&mut g.expired).into();
        drained.extend(std::mem::take(&mut g.queue).into_values());
        self.available.notify_all();
        drained
    }

    /// Close and discard queued items; returns how many were dropped.
    pub fn close(&self) -> usize {
        self.drain_close().len()
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    pub fn queue_len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Mark `n` requests as parked in a batching admission window:
    /// popped off the queue by a gathering worker but not yet
    /// executing. Parked requests are invisible to `queue_len` (they
    /// left the queue) yet still represent waiting demand, so
    /// [`Router::backlog`] counts them.
    pub fn park(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        let swept = Self::sweep_expired_locked(&mut g);
        g.parked += n;
        if swept > 0 {
            self.available.notify_all();
        }
    }

    /// Un-park `n` requests (their fused session is dispatching, or
    /// they were shed). Saturates rather than panicking on unbalanced
    /// calls.
    pub fn unpark(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.parked = g.parked.saturating_sub(n);
    }

    /// Waiting demand: queued items plus those parked in admission
    /// windows. This — not `queue_len` — is the load signal gang
    /// policies should see, otherwise a full admission window looks
    /// like an idle server and the policy hands out oversized gangs.
    pub fn backlog(&self) -> usize {
        let mut g = self.inner.lock().unwrap();
        let swept = Self::sweep_expired_locked(&mut g);
        if swept > 0 {
            self.available.notify_all();
        }
        g.queue.len() + g.parked
    }

    /// Record the occupancy of one dispatched session: `size <= 1` is
    /// a solo run; larger sizes count every member as batched and the
    /// session once (so `mean_fused` = members / sessions).
    pub fn record_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        if size <= 1 {
            g.solo += 1;
        } else {
            g.batched += size as u64;
            g.fused_sessions += 1;
            g.fused_members += size as u64;
        }
    }

    /// Record a request the admission gate refused before it entered
    /// the queue (the connection reader calls this when
    /// `JobRunner::admit` errors).
    pub fn record_inadmissible(&self) {
        self.inner.lock().unwrap().inadmissible += 1;
    }

    /// Record graceful-degradation activity: requests demoted at
    /// admission and suffixes re-quantized mid-flight. Workers (or the
    /// runner, at shutdown) accumulate these into the stats snapshot.
    pub fn record_degrade(&self, demoted: u64, requantized: u64) {
        let mut g = self.inner.lock().unwrap();
        g.demoted += demoted;
        g.requantized += requantized;
    }

    /// Record one parsed request line: `lazy` says whether the
    /// in-place scanner handled it or it bailed to the full-tree
    /// parse. Connection readers call this per line; the ratio is the
    /// live measure of how much of the wire mix rides the hot path.
    pub fn record_parse(&self, lazy: bool) {
        let mut g = self.inner.lock().unwrap();
        if lazy {
            g.lazy_parsed += 1;
        } else {
            g.fallback_parsed += 1;
        }
    }

    /// Record a line that exceeded the event loop's length cap and
    /// was answered with a typed `bad_request` without buffering it.
    pub fn record_oversized(&self) {
        self.inner.lock().unwrap().oversized += 1;
    }

    /// Record the outcome of one executed item (workers call this).
    pub fn record_outcome(&self, ok: bool, latency_s: f64) {
        let mut g = self.inner.lock().unwrap();
        if ok {
            g.completed += 1;
            g.latency.record(latency_s);
        } else {
            g.failed += 1;
        }
    }

    pub fn stats(&self) -> RouterStats {
        let g = self.inner.lock().unwrap();
        RouterStats {
            admitted: g.admitted,
            rejected: g.rejected,
            inadmissible: g.inadmissible,
            completed: g.completed,
            failed: g.failed,
            deadline_shed: g.deadline_shed,
            demoted: g.demoted,
            requantized: g.requantized,
            lazy_parsed: g.lazy_parsed,
            fallback_parsed: g.fallback_parsed,
            oversized: g.oversized,
            queue_len: g.queue.len(),
            parked: g.parked,
            batched: g.batched,
            solo: g.solo,
            fused_sessions: g.fused_sessions,
            mean_fused: if g.fused_sessions == 0 {
                0.0
            } else {
                g.fused_members as f64 / g.fused_sessions as f64
            },
            latency_mean_s: g.latency.mean(),
            latency_p50_s: g.latency.p50(),
            latency_p95_s: g.latency.p95(),
            latency_summary: g.latency.summary(),
        }
    }

    pub fn mean_latency_s(&self) -> f64 {
        self.inner.lock().unwrap().latency.mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::Priority;
    use std::sync::Arc;
    use std::time::Duration;

    fn job(id: &str, seed: u64) -> Job {
        Job::seeded(id, seed)
    }

    /// `pop` for tests that expect a live item.
    fn pop_ready<T: Prioritized>(r: &Router<T>) -> T {
        match r.pop().expect("router closed") {
            Dequeued::Ready(t) => t,
            Dequeued::Expired(_) => panic!("unexpected expiry"),
        }
    }

    #[test]
    fn fifo_order_and_backpressure() {
        let r: Router<Job> = Router::new(2);
        r.submit(job("a", 1)).unwrap();
        r.submit(job("b", 2)).unwrap();
        let err = r.submit(job("c", 3)).unwrap_err();
        match err {
            Error::Busy { queue_depth } => assert_eq!(queue_depth, 2),
            other => panic!("expected Busy, got {other}"),
        }
        assert_eq!(r.queue_len(), 2);
        let s = r.stats();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.rejected, 1);
        // Equal priority, no deadlines: FIFO, front is "a".
        assert_eq!(pop_ready(&r).id, "a");
        assert_eq!(pop_ready(&r).id, "b");
    }

    #[test]
    fn higher_priority_jumps_the_queue() {
        let r: Router<Job> = Router::new(8);
        let mk = |id: &str, p: Priority| {
            Job::new(id, GenerationSpec::new().priority(p))
        };
        r.submit(mk("lo1", Priority::Low)).unwrap();
        r.submit(mk("n1", Priority::Normal)).unwrap();
        r.submit(mk("hi1", Priority::High)).unwrap();
        r.submit(mk("n2", Priority::Normal)).unwrap();
        r.submit(mk("hi2", Priority::High)).unwrap();
        let order: Vec<String> =
            (0..5).map(|_| pop_ready(&r).id).collect();
        assert_eq!(order, ["hi1", "hi2", "n1", "n2", "lo1"]);
    }

    #[test]
    fn earliest_deadline_first_within_a_priority() {
        let r: Router<Job> = Router::new(8);
        let mk = |id: &str, deadline_s: Option<f64>| {
            let mut spec = GenerationSpec::new();
            if let Some(d) = deadline_s {
                spec = spec.deadline_s(d);
            }
            Job::new(id, spec)
        };
        r.submit(mk("none1", None)).unwrap();
        r.submit(mk("late", Some(60.0))).unwrap();
        r.submit(mk("soon", Some(5.0))).unwrap();
        r.submit(mk("none2", None)).unwrap();
        let order: Vec<String> =
            (0..4).map(|_| pop_ready(&r).id).collect();
        // Deadlines first (earliest leading), then FIFO of the rest.
        assert_eq!(order, ["soon", "late", "none1", "none2"]);
    }

    #[test]
    fn priority_beats_deadline_beats_fifo() {
        let r: Router<Job> = Router::new(8);
        r.submit(Job::new("lo-soon", GenerationSpec::new()
            .priority(Priority::Low)
            .deadline_s(0.5)))
            .unwrap();
        r.submit(Job::new("hi-late", GenerationSpec::new()
            .priority(Priority::High)
            .deadline_s(60.0)))
            .unwrap();
        r.submit(Job::new("hi-none", GenerationSpec::new()
            .priority(Priority::High)))
            .unwrap();
        let order: Vec<String> =
            (0..3).map(|_| pop_ready(&r).id).collect();
        assert_eq!(order, ["hi-late", "hi-none", "lo-soon"]);
    }

    #[test]
    fn expired_jobs_are_shed_on_dequeue() {
        let r: Router<Job> = Router::new(8);
        r.submit(Job::new(
            "gone",
            GenerationSpec::new().deadline_s(0.005),
        ))
        .unwrap();
        r.submit(job("fine", 1)).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        match r.pop().unwrap() {
            Dequeued::Expired(j) => {
                assert_eq!(j.id, "gone");
                assert!(j.deadline_slack_s().unwrap() < 0.0);
            }
            Dequeued::Ready(j) => panic!("{} should have expired", j.id),
        }
        match r.pop().unwrap() {
            Dequeued::Ready(j) => assert_eq!(j.id, "fine"),
            Dequeued::Expired(j) => panic!("{} wrongly shed", j.id),
        }
        assert_eq!(r.stats().deadline_shed, 1);
    }

    #[test]
    fn expiry_sweep_frees_router_slots() {
        // Satellite fix pin: dequeue-only shedding let long-expired
        // requests occupy router slots during a storm — a full queue
        // of corpses bounced every fresh admission with `busy` until a
        // worker happened by. The sweep must free ALL such slots.
        let r: Router<Job> = Router::new(4);
        for i in 0..4 {
            r.submit(Job::new(
                format!("stale{i}"),
                GenerationSpec::new().deadline_s(0.005),
            ))
            .unwrap();
        }
        std::thread::sleep(Duration::from_millis(20));
        // Sweep (here via the backlog probe every worker loop makes):
        // all four slots freed, all four shed-counted.
        assert_eq!(r.backlog(), 0);
        assert_eq!(r.queue_len(), 0);
        assert_eq!(r.stats().deadline_shed, 4);
        // The freed-slot count is exactly the capacity: four fresh
        // submissions all admit where previously all four bounced.
        for i in 0..4u64 {
            r.submit(job(&format!("fresh{i}"), i)).unwrap();
        }
        assert_eq!(r.queue_len(), 4);
        let s = r.stats();
        assert_eq!(s.admitted, 8);
        assert_eq!(s.rejected, 0);
        // Swept corpses still reach workers (ahead of live work) as
        // Expired so their clients get the typed deadline answer —
        // and never double-count the shed stat.
        for i in 0..4 {
            match r.pop().unwrap() {
                Dequeued::Expired(j) => {
                    assert_eq!(j.id, format!("stale{i}"))
                }
                Dequeued::Ready(j) => {
                    panic!("{} should have expired", j.id)
                }
            }
        }
        assert_eq!(pop_ready(&r).id, "fresh0");
        assert_eq!(r.stats().deadline_shed, 4, "no double count");
    }

    #[test]
    fn degrade_counters_accumulate_into_stats() {
        let r: Router<u64> = Router::new(4);
        let s = r.stats();
        assert_eq!((s.demoted, s.requantized), (0, 0));
        r.record_degrade(2, 1);
        r.record_degrade(1, 0);
        let s = r.stats();
        assert_eq!(s.demoted, 3);
        assert_eq!(s.requantized, 1);
    }

    #[test]
    fn parse_counters_accumulate_into_stats() {
        let r: Router<u64> = Router::new(4);
        let s = r.stats();
        assert_eq!((s.lazy_parsed, s.fallback_parsed, s.oversized), (0, 0, 0));
        r.record_parse(true);
        r.record_parse(true);
        r.record_parse(false);
        r.record_oversized();
        let s = r.stats();
        assert_eq!(s.lazy_parsed, 2);
        assert_eq!(s.fallback_parsed, 1);
        assert_eq!(s.oversized, 1);
    }

    #[test]
    fn close_wakes_blocked_pop_and_discards_queue() {
        let r: Arc<Router<Job>> = Arc::new(Router::new(4));
        let waiter = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || r.pop())
        };
        r.submit(job("x", 1)).unwrap();
        // `pop` blocks until work or close, so the waiter is
        // guaranteed to drain the item eventually; spin (no timing
        // assumptions) until it has.
        while r.queue_len() > 0 {
            std::thread::yield_now();
        }
        assert!(waiter.join().unwrap().is_some());
        // A second waiter blocks on the now-empty queue: close() must
        // wake it (no item will ever arrive) and make it return None.
        let blocked = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || r.pop())
        };
        // Best-effort pause so the waiter actually blocks in `wait`
        // (the assertion holds either way: pop on a closed empty
        // router returns None immediately).
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(r.close(), 0, "queue already drained");
        assert!(blocked.join().unwrap().is_none());
        // After close: pops return None, submits are rejected with the
        // typed shutdown error (wire code `shutdown`).
        assert!(r.is_closed());
        assert!(r.pop().is_none());
        let e = r.submit(job("y", 2)).unwrap_err();
        assert!(matches!(e, Error::Shutdown));
    }

    #[test]
    fn concurrent_producers_consumers_account_exactly() {
        let r: Arc<Router<u64>> = Arc::new(Router::new(8));
        let n_producers = 4;
        let per_producer = 50u64;
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let mut got = 0u64;
                    while r.pop().is_some() {
                        got += 1;
                    }
                    got
                })
            })
            .collect();
        let producers: Vec<_> = (0..n_producers)
            .map(|p| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let mut accepted = 0u64;
                    for i in 0..per_producer {
                        loop {
                            match r.submit(p * 1000 + i) {
                                Ok(()) => break,
                                Err(Error::Busy { .. }) => {
                                    std::thread::yield_now()
                                }
                                Err(_) => return accepted,
                            }
                        }
                        accepted += 1;
                    }
                    accepted
                })
            })
            .collect();
        let sent: u64 =
            producers.into_iter().map(|h| h.join().unwrap()).sum();
        // All producers retried until accepted.
        assert_eq!(sent, n_producers * per_producer);
        // Let consumers drain before closing — close() discards
        // whatever is still queued.
        while r.queue_len() > 0 {
            std::thread::yield_now();
        }
        r.close();
        let got: u64 = consumers.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(got, sent);
        let s = r.stats();
        assert_eq!(s.admitted, sent);
        assert_eq!(s.queue_len, 0);
    }

    #[test]
    fn stats_expose_latency_percentiles() {
        let r: Router<u64> = Router::new(4);
        for i in 1..=100 {
            r.record_outcome(true, i as f64 / 100.0);
        }
        // Failures count, but never pollute the latency distribution.
        r.record_outcome(false, 9.9);
        let s = r.stats();
        assert_eq!(s.completed, 100);
        assert_eq!(s.failed, 1);
        assert!((s.latency_mean_s - 0.505).abs() < 1e-9);
        assert!((s.latency_p50_s - 0.505).abs() < 0.02);
        assert!((s.latency_p95_s - 0.955).abs() < 0.02);
        assert!(s.latency_p95_s < 2.0, "failure latency leaked in");
    }

    #[test]
    fn batch_occupancy_stats_and_parked_backlog() {
        let r: Router<Job> = Router::new(8);
        // Empty router: all occupancy fields at rest.
        let s = r.stats();
        assert_eq!((s.batched, s.solo, s.fused_sessions), (0, 0, 0));
        assert_eq!(s.mean_fused, 0.0);
        assert_eq!(s.parked, 0);
        // Two fused sessions (3 + 2 members) and two solo runs.
        r.record_batch(3);
        r.record_batch(1);
        r.record_batch(2);
        r.record_batch(0); // degenerate: counts as solo
        let s = r.stats();
        assert_eq!(s.batched, 5);
        assert_eq!(s.solo, 2);
        assert_eq!(s.fused_sessions, 2);
        assert!((s.mean_fused - 2.5).abs() < 1e-12);
        // Parked requests left the queue but still count as backlog.
        r.submit(job("q", 1)).unwrap();
        r.park(2);
        assert_eq!(r.queue_len(), 1);
        assert_eq!(r.stats().parked, 2);
        assert_eq!(r.backlog(), 3);
        r.unpark(1);
        assert_eq!(r.backlog(), 2);
        // Unbalanced unpark saturates to zero, never panics.
        r.unpark(10);
        assert_eq!(r.backlog(), 1);
    }

    #[test]
    fn pop_match_skips_incompatible_and_respects_window() {
        let r: Arc<Router<Job>> = Arc::new(Router::new(8));
        r.submit(job("odd1", 1)).unwrap();
        r.submit(job("even1", 2)).unwrap();
        r.submit(job("odd2", 3)).unwrap();
        let until = Instant::now() + Duration::from_millis(200);
        let even = |j: &Job| j.seed() % 2 == 0;
        // Matches the best-ordered even job, leaving odd ones queued
        // in place.
        let got = r.pop_match_timeout(even, until).unwrap();
        match got {
            Dequeued::Ready(j) => assert_eq!(j.id, "even1"),
            Dequeued::Expired(j) => panic!("{} wrongly expired", j.id),
        }
        assert_eq!(r.queue_len(), 2);
        // No even job left: a short window expires with None and the
        // queue is untouched.
        let t0 = Instant::now();
        let miss = r
            .pop_match_timeout(even, Instant::now() + Duration::from_millis(30));
        assert!(miss.is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert_eq!(r.queue_len(), 2);
        // A matching submit from another thread wakes the waiter
        // before the window closes.
        let waiter = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                r.pop_match_timeout(
                    |j: &Job| j.seed() % 2 == 0,
                    Instant::now() + Duration::from_secs(5),
                )
            })
        };
        std::thread::sleep(Duration::from_millis(10));
        r.submit(job("even2", 4)).unwrap();
        match waiter.join().unwrap().expect("waiter should match") {
            Dequeued::Ready(j) => assert_eq!(j.id, "even2"),
            Dequeued::Expired(j) => panic!("{} wrongly expired", j.id),
        }
        // Ordinary pops drain the untouched odd jobs in order.
        assert_eq!(pop_ready(&r).id, "odd1");
        assert_eq!(pop_ready(&r).id, "odd2");
        // Shutdown wakes a match-waiter with None.
        let blocked = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                r.pop_match_timeout(
                    |_: &Job| true,
                    Instant::now() + Duration::from_secs(30),
                )
            })
        };
        std::thread::sleep(Duration::from_millis(10));
        r.close();
        assert!(blocked.join().unwrap().is_none());
    }

    #[test]
    fn pop_match_sheds_expired_matches() {
        let r: Router<Job> = Router::new(8);
        r.submit(Job::new(
            "stale",
            GenerationSpec::new().deadline_s(0.005),
        ))
        .unwrap();
        std::thread::sleep(Duration::from_millis(20));
        let got = r
            .pop_match_timeout(|_: &Job| true, Instant::now())
            .unwrap();
        assert!(matches!(got, Dequeued::Expired(_)));
        assert_eq!(r.stats().deadline_shed, 1);
    }

    #[test]
    fn property_queue_never_exceeds_capacity() {
        use crate::util::proptest::{ensure, forall};
        forall(
            7,
            100,
            |rng| {
                (0..rng.below(40))
                    .map(|_| rng.below(2) as usize)
                    .collect::<Vec<usize>>()
            },
            |ops| {
                // op 0 = submit, op 1 = pop.
                let r: Router<u64> = Router::new(4);
                let mut next = 0u64;
                for &op in ops {
                    if op == 0 {
                        next += 1;
                        let _ = r.submit(next);
                    } else {
                        r.try_pop();
                    }
                    ensure(r.queue_len() <= 4, "capacity exceeded")?;
                }
                let s = r.stats();
                ensure(
                    s.admitted + s.rejected == next,
                    "admission accounting broken",
                )?;
                Ok(())
            },
        );
    }

    #[test]
    fn property_dequeue_order_matches_discipline() {
        use crate::util::proptest::{ensure, forall};
        // For random (rank, has_deadline, deadline_offset) batches,
        // drain order must be sorted by (rank desc, deadline asc with
        // None last, submission seq).
        forall(
            13,
            150,
            |rng| {
                (0..1 + rng.below(12))
                    .map(|_| {
                        (
                            rng.below(3) as usize, // rank
                            (
                                rng.below(2) as usize,          // has dl
                                10 + rng.below(1000) as usize, // offset
                            ),
                        )
                    })
                    .collect::<Vec<(usize, (usize, usize))>>()
            },
            |items| {
                let r: Router<Job> = Router::new(64);
                for (i, &(rank, (has_dl, off_ms))) in
                    items.iter().enumerate()
                {
                    let mut spec = GenerationSpec::new().priority(
                        match rank {
                            0 => Priority::Low,
                            1 => Priority::Normal,
                            _ => Priority::High,
                        },
                    );
                    if has_dl == 1 {
                        // Far-future deadlines: ordering only, no
                        // accidental expiry during the test.
                        spec = spec.deadline_s(3600.0 + off_ms as f64);
                    }
                    r.submit(Job::new(format!("j{i}"), spec)).unwrap();
                }
                let mut last: Option<(u8, Option<Instant>, usize)> = None;
                for _ in 0..items.len() {
                    let j = match r.pop().unwrap() {
                        Dequeued::Ready(j) => j,
                        Dequeued::Expired(j) => {
                            return Err(format!(
                                "{} expired with an hour of slack",
                                j.id
                            ))
                        }
                    };
                    let idx: usize = j.id[1..].parse().unwrap();
                    let cur =
                        (j.priority_rank(), j.deadline(), idx);
                    if let Some(prev) = last {
                        ensure(
                            prev.0 >= cur.0,
                            "rank order violated",
                        )?;
                        if prev.0 == cur.0 {
                            let ok = match (prev.1, cur.1) {
                                (Some(a), Some(b)) => a <= b,
                                (Some(_), None) => true,
                                (None, Some(_)) => false,
                                (None, None) => prev.2 < cur.2,
                            };
                            ensure(ok, "deadline/FIFO order violated")?;
                        }
                    }
                    last = Some(cur);
                }
                ensure(r.queue_len() == 0, "items left behind")?;
                Ok(())
            },
        );
    }
}
