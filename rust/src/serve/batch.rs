//! Cross-request batching: compatibility keys, admission-window
//! grouping, and the join-at-barrier matchmaking registry.
//!
//! STADI's Eq. 4 step grid is a pure function of (rows, cols, step
//! count, warmup length, halo budget) — the *grid-alignment property*
//! pinned in `sched::temporal`. Two requests whose [`FuseKey`]s are
//! equal therefore plan to the *same* lockstep schedule on any gang
//! (see [`Plan::fuses_with`](crate::sched::plan::Plan::fuses_with)),
//! which is what makes fusing them into one session safe: the fused
//! session runs each member's own latents through the *identical*
//! plan, so every member's output stays byte-identical to its solo
//! run. Batching changes *when* work runs and what it costs — never
//! what it computes.
//!
//! Three layers live here:
//!
//! * [`FuseKey`] — the compatibility signature (wraps
//!   [`EngineCore::fuse_signature`](crate::coordinator::EngineCore::fuse_signature)).
//! * [`group_compatible`] — the *pure* admission-window grouping rule,
//!   shared by the serve worker's gather loop, the discrete-event
//!   frontier sweep in [`serve::sim`](crate::serve::sim), and the
//!   property tests — one definition, three consumers, no drift.
//! * [`BatchGates`] — the live matchmaking registry for
//!   **join-at-barrier**: a worker running a fused session registers a
//!   gate keyed by its `FuseKey`; a later worker holding a compatible
//!   request first claims a fleet slot on the gate's devices
//!   ([`FleetManager::try_join`]) and then parks an [`Offer`] that the
//!   running session adopts at its next sync barrier
//!   (`Session::execute_fused_seeded`'s poll hook). Offers are never
//!   silently dropped: the session's closing handshake adopts
//!   stragglers, and a gate that closes without adopting declines its
//!   offers so their workers fall back to founding their own sessions.

use std::sync::mpsc;
use std::sync::Mutex;

use crate::coordinator::Generation;
use crate::error::Error;
use crate::fleet::{FleetManager, SlotJoin};

/// Batch-compatibility signature. Equal keys ⇒ identical Eq. 4/Eq. 5
/// plans on any gang ⇒ safe to fuse. The fields mirror
/// `EngineCore::fuse_signature`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FuseKey {
    /// Latent rows (after any per-request resolution override).
    pub rows: usize,
    /// Latent cols.
    pub cols: usize,
    /// Base denoising step count (Eq. 4 `m`).
    pub steps: usize,
    /// Warmup steps executed at full sync.
    pub warmup: usize,
    /// Effective halo staleness budget (0 = fully synchronous).
    pub halo_budget: usize,
}

impl FuseKey {
    /// Build from the `(rows, cols, steps, warmup, halo_budget)` tuple
    /// `EngineCore::fuse_signature` returns.
    pub fn from_signature(sig: (usize, usize, usize, usize, usize)) -> Self {
        FuseKey {
            rows: sig.0,
            cols: sig.1,
            steps: sig.2,
            warmup: sig.3,
            halo_budget: sig.4,
        }
    }
}

/// Pure admission-window grouping: partition arrivals (time-sorted or
/// not — they are processed in arrival order as given) into fused
/// groups of at most `max_batch`, where a group's *leader* (its first
/// member) holds the window open for `window_s` and every later
/// arrival with the same key inside that window joins.
///
/// Returns groups as index lists into `arrivals`, in leader order.
/// Invariants (property-tested, and relied on by the DES sweep):
///
/// * every group is key-homogeneous;
/// * `1 <= group.len() <= max_batch`;
/// * no member waits past the leader's window: a member arriving at
///   `t` joins a leader arriving at `t0 >= t - window_s`, and the
///   group dispatches no later than `t0 + window_s`, so every member's
///   extra queueing delay is `<= window_s`;
/// * every index appears in exactly one group (nothing starves).
pub fn group_compatible(
    arrivals: &[(f64, FuseKey)],
    window_s: f64,
    max_batch: usize,
) -> Vec<Vec<usize>> {
    let max_batch = max_batch.max(1);
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut taken = vec![false; arrivals.len()];
    for i in 0..arrivals.len() {
        if taken[i] {
            continue;
        }
        taken[i] = true;
        let (t0, key) = arrivals[i];
        let mut group = vec![i];
        for (j, &(t, k)) in
            arrivals.iter().enumerate().skip(i + 1)
        {
            if group.len() >= max_batch {
                break;
            }
            if taken[j] || k != key {
                continue;
            }
            if t > t0 + window_s {
                // Arrivals are processed in order; a later index can
                // still be earlier in time if the caller passed an
                // unsorted trace, so `continue` rather than `break`.
                continue;
            }
            taken[j] = true;
            group.push(j);
        }
        groups.push(group);
    }
    groups
}

/// How a parked joiner's request resolved.
#[derive(Debug)]
pub enum JoinReply {
    /// Adopted at a barrier and executed; here is its generation.
    Done(Box<Generation>),
    /// The gate closed without adopting this offer (session finished
    /// its last barrier first, or tore down). Nothing ran — the
    /// joiner's worker should fall back to founding its own session.
    Declined,
    /// The fused session adopted the offer but then failed; the
    /// joiner's client is owed this error, same as the founders'.
    Failed(Error),
}

/// A parked join request: the joiner's worker blocks on the paired
/// receiver while the running session holds this end. The embedded
/// [`SlotJoin`] keeps the fleet slot claimed from offer time until the
/// reply is sent (dropping the offer releases it).
pub struct Offer {
    /// Correlates this offer with the generation the session hands
    /// back (`FusedOutcome::joined` carries the token).
    pub token: u64,
    pub seed: u64,
    reply: mpsc::Sender<JoinReply>,
    /// Held, not read: the slot frees on drop.
    _slot: SlotJoin,
}

impl Offer {
    /// Send the joiner's result. Errors (receiver gone — its worker
    /// died) are ignored: the slot still frees on drop.
    pub fn resolve(self, reply: JoinReply) {
        let _ = self.reply.send(reply);
    }
}

struct Gate {
    id: u64,
    key: FuseKey,
    devices: Vec<usize>,
    /// Cleared by [`GateHandle::close`]; offers check it under the
    /// registry lock, so after `close` returns no new offer can land.
    accepting: bool,
    pending: Vec<Offer>,
}

#[derive(Default)]
struct State {
    next_gate: u64,
    next_token: u64,
    gates: Vec<Gate>,
}

/// Matchmaking registry: open gates (fused sessions willing to adopt
/// joiners at their next barrier) keyed by [`FuseKey`]. One per
/// serving runner, shared by all workers.
#[derive(Default)]
pub struct BatchGates {
    inner: Mutex<State>,
}

impl BatchGates {
    pub fn new() -> Self {
        BatchGates::default()
    }

    /// Open a gate for a session about to run on `devices` with
    /// compatibility `key`. The handle drains offers at barriers and
    /// unregisters (declining leftovers) on drop.
    pub fn register(
        &self,
        key: FuseKey,
        devices: Vec<usize>,
    ) -> GateHandle<'_> {
        let mut g = self.inner.lock().unwrap();
        let id = g.next_gate;
        g.next_gate += 1;
        g.gates.push(Gate {
            id,
            key,
            devices,
            accepting: true,
            pending: Vec::new(),
        });
        GateHandle { gates: self, id }
    }

    /// Try to park `seed` on an open gate with this `key`. Claims a
    /// fleet slot on the gate's devices first — a gate whose lease has
    /// no free slots (or closed them) is skipped. On success the
    /// joiner's worker blocks on the returned receiver until the
    /// session [`Offer::resolve`]s it (a dropped sender — session
    /// panicked — reads as `Declined`: nothing ran).
    pub fn offer(
        &self,
        key: FuseKey,
        fleet: &FleetManager,
        seed: u64,
    ) -> Option<mpsc::Receiver<JoinReply>> {
        let mut g = self.inner.lock().unwrap();
        let idx = {
            let gates = &g.gates;
            let mut found = None;
            for (i, gate) in gates.iter().enumerate() {
                if !gate.accepting || gate.key != key {
                    continue;
                }
                if let Ok(Some(slot)) = fleet.try_join(&gate.devices) {
                    found = Some((i, slot));
                    break;
                }
            }
            found
        };
        let (i, slot) = idx?;
        let token = g.next_token;
        g.next_token += 1;
        let (tx, rx) = mpsc::channel();
        g.gates[i].pending.push(Offer {
            token,
            seed,
            reply: tx,
            _slot: slot,
        });
        Some(rx)
    }

    #[cfg(test)]
    fn open_gates(&self) -> usize {
        self.inner.lock().unwrap().gates.len()
    }
}

/// RAII handle on one open gate. The owning worker drains offers at
/// sync barriers and must resolve every drained offer; undrained
/// offers are declined when the handle drops.
pub struct GateHandle<'a> {
    gates: &'a BatchGates,
    id: u64,
}

impl GateHandle<'_> {
    /// Take every offer parked since the last drain. The caller now
    /// owns them: adopt their seeds into the session and
    /// [`Offer::resolve`] each when its generation (or the session's
    /// error) is known.
    pub fn drain(&self) -> Vec<Offer> {
        let mut g = self.gates.inner.lock().unwrap();
        match g.gates.iter_mut().find(|gate| gate.id == self.id) {
            Some(gate) => std::mem::take(&mut gate.pending),
            None => Vec::new(),
        }
    }

    /// Stop accepting new offers (the session is past its last
    /// adoption barrier). After this returns, no offer can land, so a
    /// final [`GateHandle::drain`] observes the complete set — the
    /// close-then-drain pair is the session's closing handshake.
    pub fn close(&self) {
        let mut g = self.gates.inner.lock().unwrap();
        if let Some(gate) =
            g.gates.iter_mut().find(|gate| gate.id == self.id)
        {
            gate.accepting = false;
        }
    }
}

impl Drop for GateHandle<'_> {
    fn drop(&mut self) {
        let mut g = self.gates.inner.lock().unwrap();
        if let Some(pos) =
            g.gates.iter().position(|gate| gate.id == self.id)
        {
            let gate = g.gates.swap_remove(pos);
            for offer in gate.pending {
                offer.resolve(JoinReply::Declined);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(rows: usize, steps: usize) -> FuseKey {
        FuseKey { rows, cols: 32, steps, warmup: 2, halo_budget: 0 }
    }

    #[test]
    fn fuse_key_roundtrips_signature_tuple() {
        let k = FuseKey::from_signature((32, 48, 20, 2, 1));
        assert_eq!(
            k,
            FuseKey { rows: 32, cols: 48, steps: 20, warmup: 2, halo_budget: 1 }
        );
        assert_ne!(k, FuseKey::from_signature((32, 48, 20, 2, 0)));
    }

    #[test]
    fn grouping_fuses_within_window_and_splits_keys() {
        let a = key(32, 20);
        let b = key(64, 20);
        let arrivals = vec![
            (0.0, a),   // leader of group 1
            (0.001, b), // different key: own group
            (0.002, a), // joins group 1
            (0.004, a), // joins group 1 (window 5 ms)
            (0.010, a), // outside leader's window: new group
        ];
        let groups = group_compatible(&arrivals, 0.005, 8);
        assert_eq!(groups, vec![vec![0, 2, 3], vec![1], vec![4]]);
    }

    #[test]
    fn grouping_respects_max_batch_and_covers_everything() {
        let a = key(32, 20);
        let arrivals: Vec<_> = (0..7).map(|i| (i as f64 * 1e-4, a)).collect();
        let groups = group_compatible(&arrivals, 1.0, 3);
        assert_eq!(groups, vec![vec![0, 1, 2], vec![3, 4, 5], vec![6]]);
        // max_batch 0 is clamped to 1 (everyone solo), not a panic.
        let solo = group_compatible(&arrivals, 1.0, 0);
        assert_eq!(solo.len(), 7);
        assert!(solo.iter().all(|grp| grp.len() == 1));
    }

    // Keys shrink to nothing (they are categorical, not ordered);
    // the interesting shrinking happens on the arrival vector.
    impl crate::util::proptest::Shrink for FuseKey {}

    #[test]
    fn property_grouping_is_homogeneous_bounded_and_starvation_free() {
        use crate::util::proptest::{ensure, forall};
        forall(
            29,
            200,
            |rng| {
                let n = rng.below(24) as usize;
                let window = 0.001 + rng.below(20) as f64 * 0.001;
                let max_batch = 1 + rng.below(6) as usize;
                let mut t = 0.0f64;
                let arrivals: Vec<(f64, FuseKey)> = (0..n)
                    .map(|_| {
                        t += rng.below(8) as f64 * 0.001;
                        let k = match rng.below(3) {
                            0 => key(32, 20),
                            1 => key(64, 20),
                            _ => key(32, 28),
                        };
                        (t, k)
                    })
                    .collect();
                (arrivals, (window, max_batch))
            },
            |(arrivals, (window, max_batch))| {
                let groups =
                    group_compatible(arrivals, *window, *max_batch);
                let mut seen = vec![0usize; arrivals.len()];
                for grp in &groups {
                    ensure(!grp.is_empty(), "empty group")?;
                    ensure(
                        grp.len() <= *max_batch,
                        "group exceeds max_batch",
                    )?;
                    let (t0, k0) = arrivals[grp[0]];
                    for &i in grp {
                        seen[i] += 1;
                        ensure(
                            arrivals[i].1 == k0,
                            "mixed keys fused",
                        )?;
                        // Dispatch happens by t0 + window, and members
                        // arrive at or after the leader, so nobody
                        // waits past one window.
                        ensure(
                            arrivals[i].0 >= t0
                                && arrivals[i].0 <= t0 + window + 1e-12,
                            "member outside leader window",
                        )?;
                    }
                }
                ensure(
                    seen.iter().all(|&c| c == 1),
                    "request starved or double-served",
                )?;
                Ok(())
            },
        );
    }

    #[test]
    fn gates_matchmake_only_compatible_sessions_with_free_slots() {
        let gates = BatchGates::new();
        let fleet = FleetManager::new(4);
        let lease = fleet.try_acquire(&[0, 1]).unwrap().unwrap();
        lease.open_slots(3); // owner + 2 joiners
        let k = key(32, 20);
        let handle = gates.register(k, vec![0, 1]);

        // Wrong key: no match even though slots are free.
        assert!(gates.offer(key(64, 20), &fleet, 7).is_none());
        // Two joiners fit, the third finds the slots exhausted.
        let rx1 = gates.offer(k, &fleet, 11).expect("slot 1");
        let _rx2 = gates.offer(k, &fleet, 12).expect("slot 2");
        assert!(gates.offer(k, &fleet, 13).is_none());

        // The session drains both offers at a barrier…
        let offers = handle.drain();
        assert_eq!(offers.len(), 2);
        assert_eq!(
            offers.iter().map(|o| o.seed).collect::<Vec<_>>(),
            vec![11, 12]
        );
        // …and a second drain sees nothing new.
        assert!(handle.drain().is_empty());

        // Resolving an offer releases its slot: a new joiner fits.
        let (o1, o2) = {
            let mut it = offers.into_iter();
            (it.next().unwrap(), it.next().unwrap())
        };
        o1.resolve(JoinReply::Declined);
        assert!(matches!(rx1.recv().unwrap(), JoinReply::Declined));
        let _rx3 = gates.offer(k, &fleet, 14).expect("freed slot");

        // close() stops new offers; drop declines what's still parked.
        handle.close();
        assert!(gates.offer(k, &fleet, 15).is_none());
        let leftovers = handle.drain();
        assert_eq!(leftovers.len(), 1); // seed 14
        for o in leftovers {
            o.resolve(JoinReply::Declined);
        }
        drop(handle);
        assert_eq!(gates.open_gates(), 0);
        drop(o2);
    }

    #[test]
    fn dropped_gate_declines_parked_offers() {
        let gates = BatchGates::new();
        let fleet = FleetManager::new(2);
        let lease = fleet.try_acquire(&[0]).unwrap().unwrap();
        lease.open_slots(2);
        let k = key(32, 20);
        let handle = gates.register(k, vec![0]);
        let rx = gates.offer(k, &fleet, 5).expect("slot");
        drop(handle); // session tore down without draining
        assert!(matches!(rx.recv().unwrap(), JoinReply::Declined));
        // The slot freed with the offer: the lease owner is alone again
        // and a fresh gate can matchmake anew.
        let handle2 = gates.register(k, vec![0]);
        assert!(gates.offer(k, &fleet, 6).is_some());
        drop(handle2);
    }
}
