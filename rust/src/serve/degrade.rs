//! Pressure-driven graceful degradation: shed quality, not requests.
//!
//! Under backlog the router can only reject (`busy`) or expire
//! (`deadline`). This module composes the existing levers into a
//! *demotion ladder* instead (the quality-for-latency trade
//! DistriFusion makes spatially with stale activations): a pure
//! pressure signal derived from [`Router::backlog()`]
//! (crate::serve::router::Router::backlog) and the latency
//! predictor's deadline-budget deficit arms ladder rungs against the
//! [`DegradeConfig::pressure_thresholds`], and each armed rung
//! demotes the request one quality tier
//! (high → standard → draft, re-keying the plan through the
//! `GenerationSpec` path) — unless the request is already at the
//! configured floor, or its predicted latency already fits the
//! remaining deadline budget (a request that makes its SLO is never
//! degraded). Past the *top* threshold the serve path additionally
//! re-quantizes the running step suffix at the next sync barrier
//! (`temporal::requantize_suffix` driven by queueing pressure instead
//! of drift — see `Session::execute_degraded_seeded`).
//!
//! Everything here is a pure function of its snapshot — no clocks, no
//! locks — so the ladder is property-testable and the DES in
//! [`crate::serve::sim`] replays the identical arithmetic.

use crate::config::DegradeConfig;
use crate::spec::Quality;

/// Safety margin applied when pricing a tier against the remaining
/// deadline budget — the same 1.2x slack the `Deadline` gang policy
/// uses, so "fits" means the same thing at admission and gang sizing.
pub const PRICE_SLACK: f64 = 1.2;

/// Numeric rank of a quality tier on the ladder (draft lowest). The
/// ladder only ever moves *down* this rank, never up.
pub fn tier_rank(q: Quality) -> u8 {
    match q {
        Quality::Draft => 0,
        Quality::Standard => 1,
        Quality::High => 2,
    }
}

/// One rung down the ladder; draft is the bottom and maps to itself.
pub fn demote_once(q: Quality) -> Quality {
    match q {
        Quality::High => Quality::Standard,
        Quality::Standard => Quality::Draft,
        Quality::Draft => Quality::Draft,
    }
}

/// The backlog-pressure signal. Dimensionless, 0 when idle:
///
/// * queue term — `backlog / capacity`, the fraction of the router's
///   admission budget already consumed (parked batch companions
///   included, matching what gang policies see);
/// * deficit term — how far the predicted latency overshoots the
///   request's remaining deadline budget, relative to that budget
///   (`max(0, (predicted - budget) / budget)`); 0 when either side is
///   unknown, so deadline-less requests see pure queue pressure.
///
/// Both terms are snapshots; the signal is a pure function of them.
pub fn pressure_signal(
    backlog: usize,
    capacity: usize,
    predicted_s: Option<f64>,
    budget_s: Option<f64>,
) -> f64 {
    let queue = if capacity == 0 {
        0.0
    } else {
        backlog as f64 / capacity as f64
    };
    let deficit = match (predicted_s, budget_s) {
        (Some(p), Some(b)) if b > 0.0 && p.is_finite() => {
            ((p - b) / b).max(0.0)
        }
        // A deadline with no remaining budget is an unbounded deficit;
        // cap it at one full rung worth so the signal stays finite.
        (_, Some(b)) if b <= 0.0 => 1.0,
        _ => 0.0,
    };
    queue + deficit
}

/// Number of ladder rungs the signal arms: how many thresholds the
/// pressure has crossed. Monotone in `pressure` by construction.
pub fn rungs(pressure: f64, thresholds: &[f64]) -> usize {
    thresholds.iter().filter(|&&t| pressure >= t).count()
}

/// True when the pressure has crossed the *top* threshold — the level
/// at which the serve path also re-quantizes the running suffix at
/// the next sync barrier (mid-flight lever).
pub fn wants_requantize(pressure: f64, thresholds: &[f64]) -> bool {
    thresholds.last().is_some_and(|&top| pressure >= top)
}

/// Admission-time ladder walk: demote `quality` one tier per armed
/// rung, stopping early when
///
/// * the tier has reached the configured floor, or
/// * the request carries a deadline and `predict(tier)` (the
///   planner-backed latency for the demoted spec) fits the remaining
///   budget with [`PRICE_SLACK`] — degradation is priced, not free.
///
/// `predict` may return `None` (degraded/offline mode): the ladder
/// then walks on queue pressure alone, exactly like a deadline-less
/// request. The result is monotone non-increasing in `pressure` for a
/// fixed snapshot, and `pressure` below the first threshold returns
/// `quality` unchanged — both pinned by the property tests.
pub fn admission_demotion(
    quality: Quality,
    pressure: f64,
    cfg: &DegradeConfig,
    budget_s: Option<f64>,
    predict: &mut dyn FnMut(Quality) -> Option<f64>,
) -> Quality {
    if !cfg.enabled {
        return quality;
    }
    let mut q = quality;
    for _ in 0..rungs(pressure, &cfg.pressure_thresholds) {
        if tier_rank(q) <= tier_rank(cfg.floor) {
            break;
        }
        if let (Some(b), Some(p)) = (budget_s, predict(q)) {
            if p * PRICE_SLACK <= b {
                break; // this tier already makes the SLO: stop here
            }
        }
        q = demote_once(q);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(thresholds: &[f64], floor: Quality) -> DegradeConfig {
        DegradeConfig {
            enabled: true,
            pressure_thresholds: thresholds.to_vec(),
            floor,
        }
    }

    #[test]
    fn pressure_terms_compose() {
        assert_eq!(pressure_signal(0, 8, None, None), 0.0);
        assert!((pressure_signal(4, 8, None, None) - 0.5).abs() < 1e-12);
        // Deficit: predicted 3s against a 2s budget = 0.5 extra.
        let p = pressure_signal(4, 8, Some(3.0), Some(2.0));
        assert!((p - 1.0).abs() < 1e-12);
        // Fits budget: no deficit term.
        let p = pressure_signal(4, 8, Some(1.0), Some(2.0));
        assert!((p - 0.5).abs() < 1e-12);
        // Expired budget: capped one-rung deficit, still finite.
        let p = pressure_signal(0, 8, Some(1.0), Some(0.0));
        assert!((p - 1.0).abs() < 1e-12);
        assert_eq!(pressure_signal(5, 0, None, None), 0.0);
    }

    #[test]
    fn rungs_monotone_and_top_threshold_requantizes() {
        let th = [1.0, 2.0];
        assert_eq!(rungs(0.0, &th), 0);
        assert_eq!(rungs(1.0, &th), 1);
        assert_eq!(rungs(1.5, &th), 1);
        assert_eq!(rungs(2.5, &th), 2);
        assert!(!wants_requantize(1.5, &th));
        assert!(wants_requantize(2.0, &th));
        assert!(!wants_requantize(1.0, &[]));
    }

    #[test]
    fn ladder_respects_floor_and_pricing() {
        let c = cfg(&[1.0, 2.0], Quality::Draft);
        let mut no_predict = |_q: Quality| None;
        // Zero pressure: untouched at every tier.
        for q in [Quality::Draft, Quality::Standard, Quality::High] {
            assert_eq!(
                admission_demotion(q, 0.5, &c, None, &mut no_predict),
                q
            );
        }
        // Two rungs armed: high drops two tiers to the draft floor.
        assert_eq!(
            admission_demotion(
                Quality::High,
                2.5,
                &c,
                None,
                &mut no_predict
            ),
            Quality::Draft
        );
        // A standard floor stops the ladder one rung up.
        let c_std = cfg(&[1.0, 2.0], Quality::Standard);
        assert_eq!(
            admission_demotion(
                Quality::High,
                9.0,
                &c_std,
                None,
                &mut no_predict
            ),
            Quality::Standard
        );
        // Pricing: a tier that fits the budget is never demoted.
        let mut fits = |_q: Quality| Some(1.0);
        assert_eq!(
            admission_demotion(
                Quality::High,
                9.0,
                &c,
                Some(2.0),
                &mut fits
            ),
            Quality::High
        );
        // ... but a tier that blows the budget walks down.
        let mut blows = |_q: Quality| Some(10.0);
        assert_eq!(
            admission_demotion(
                Quality::High,
                1.5,
                &c,
                Some(2.0),
                &mut blows
            ),
            Quality::Standard
        );
        // Disabled config is the identity regardless of pressure.
        let mut off = c.clone();
        off.enabled = false;
        assert_eq!(
            admission_demotion(
                Quality::High,
                9.0,
                &off,
                None,
                &mut no_predict
            ),
            Quality::High
        );
    }
}
