//! JSON-lines wire protocol for the serving front-end.
//!
//! **v2** — one request per line, parameters in a typed spec object:
//!   {"id": "r1", "spec": {"seed": 9, "steps": 50, "height": 256,
//!    "width": 256, "quality": "standard", "priority": "high",
//!    "deadline_s": 2.5}}
//! Every spec field is optional; omitted fields take the engine's
//! defaults. Responses echo the full resolved spec:
//!   {"id": "r1", "ok": true, "spec": {...}, "latency_s": ...,
//!    "sim_latency_s": ..., "latent_sum": ..., "latent_first8": [...],
//!    "plan": {...}}
//!
//! **v1** — `{"id": "r1", "seed": 1234}` lines keep parsing as
//! default-spec requests and produce byte-identical numeric results to
//! the pre-spec engine (the backcompat golden test pins this).
//!
//! Error lines carry a stable machine-readable `code`
//! ([`Error::wire_code`]): `busy` (backpressure, with `queue_depth`),
//! `bad_request` (malformed line), `bad_spec` (invalid spec fields —
//! including negative seeds, which v1 used to silently cast through
//! `as u64`), `deadline` (shed after its deadline passed, with
//! `deadline_s`/`late_by_s`), `shutdown`, and `error` (everything
//! else). Clients dispatch on the code, never on the message text.
//!
//! The latent itself is summarized (sum + first values) rather than
//! shipped — clients needing pixels use the library API; the server
//! exists to exercise routing/queueing on the request path.
//!
//! **Lazy hot path.** [`parse_lazy`] scans the common request shape
//! in place (one pass, zero allocations beyond the id) and bails to
//! [`WireRequest::parse`] on *anything* unusual — escape sequences,
//! unknown or duplicated fields, type surprises, trailing bytes — so
//! the two paths are equivalent by construction: the fast scan only
//! ever succeeds, and every error (and every odd-but-valid line) is
//! produced by the one full-tree parser. A `QUICKCHECK_SEED` property
//! below pins the equivalence over randomized lines.

use crate::coordinator::Generation;
use crate::error::{Error, Result};
use crate::spec::{self, GenerationSpec, Priority, Quality};
use crate::util::json::{self, Object, Scanner, Value};

/// A parsed client request: id + typed generation spec.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub id: String,
    pub spec: GenerationSpec,
}

impl WireRequest {
    /// Parse one request line, v2 (`"spec"` object) or v1 (bare
    /// `"seed"`). A line carrying *both* is rejected as ambiguous.
    pub fn parse(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        let id = v
            .get_opt("id")
            .ok_or_else(|| Error::Protocol("missing \"id\"".into()))?
            .as_str()
            .map_err(|_| Error::Protocol("\"id\" must be a string".into()))?
            .to_string();
        let spec = match (v.get_opt("spec"), v.get_opt("seed")) {
            (Some(_), Some(_)) => {
                return Err(Error::Protocol(
                    "request has both \"spec\" (v2) and \"seed\" (v1)"
                        .into(),
                ))
            }
            (Some(s), None) => GenerationSpec::from_json(s)?,
            (None, Some(seed)) => {
                // v1 compat: a bare seed is a default-spec request.
                GenerationSpec::new().seed(spec::parse_seed(seed)?)
            }
            (None, None) => {
                return Err(Error::Protocol(
                    "request needs \"spec\" (v2) or \"seed\" (v1)".into(),
                ))
            }
        };
        Ok(WireRequest { id, spec })
    }

    /// Serialize as a v2 line (full spec object).
    pub fn to_line(&self) -> String {
        let mut o = Object::new();
        o.insert("id", Value::Str(self.id.clone()));
        o.insert("spec", self.spec.to_json());
        json::to_string(&Value::Obj(o))
    }

    /// Serialize as a v1 line (`{"id", "seed"}`) — the backcompat
    /// client shape. Only the seed survives; other spec fields are
    /// not expressible in v1.
    pub fn to_line_v1(&self) -> String {
        let mut o = Object::new();
        o.insert("id", Value::Str(self.id.clone()));
        o.insert("seed", Value::Num(self.spec.seed as f64));
        json::to_string(&Value::Obj(o))
    }
}

/// Parse one request line on the lazy hot path: a single in-place
/// scan over the common v1/v2 shape that never builds a JSON tree.
/// Result-equivalent to [`WireRequest::parse`] (including the error
/// and its wire code) — see [`parse_lazy_tracked`] for how.
pub fn parse_lazy(line: &str) -> Result<WireRequest> {
    parse_lazy_tracked(line).0
}

/// [`parse_lazy`] plus whether the in-place scan handled the line
/// (`true`) or bailed to the full tree parse (`false`) — the server
/// feeds the flag into `RouterStats`. Equivalence is by construction:
/// the fast scan only ever *succeeds* (on the exact common shape,
/// converted and validated through the same `spec` helpers the tree
/// path uses), and everything else — errors included — re-parses
/// through the one authoritative [`WireRequest::parse`].
pub fn parse_lazy_tracked(line: &str) -> (Result<WireRequest>, bool) {
    match fast_scan(line) {
        Some(req) => (Ok(req), true),
        None => (WireRequest::parse(line), false),
    }
}

/// The conservative single-pass scan. `None` means "bail to the full
/// parse" — taken on anything but a flat object of known keys (`id`
/// plus either `seed` or a flat `spec` object of known spec keys)
/// with no escapes, no duplicates and no trailing bytes.
fn fast_scan(line: &str) -> Option<WireRequest> {
    let mut sc = Scanner::new(line);
    if !sc.eat(b'{') {
        return None;
    }
    let mut id: Option<&str> = None;
    let mut seed: Option<f64> = None;
    let mut spec: Option<GenerationSpec> = None;
    if sc.eat(b'}') {
        return None; // empty object: the tree path reports missing id
    }
    loop {
        let key = sc.raw_string()?;
        if !sc.eat(b':') {
            return None;
        }
        match key {
            "id" if id.is_none() => id = Some(sc.raw_string()?),
            "seed" if seed.is_none() && spec.is_none() => {
                seed = Some(sc.number()?);
            }
            "spec" if spec.is_none() && seed.is_none() => {
                spec = Some(scan_spec(&mut sc)?);
            }
            // Unknown key (the tree path tolerates it), duplicate
            // (tree path is last-wins), or a v1+v2 mix (typed
            // rejection): all routed through the full parse.
            _ => return None,
        }
        if sc.eat(b',') {
            continue;
        }
        if sc.eat(b'}') {
            break;
        }
        return None;
    }
    if !sc.at_end() {
        return None; // tree path rejects trailing characters
    }
    let spec = match (spec, seed) {
        (Some(s), None) => s,
        (None, Some(n)) => GenerationSpec::new()
            .seed(spec::parse_seed(&Value::Num(n)).ok()?),
        _ => return None, // neither: tree path reports the miss
    };
    Some(WireRequest { id: id?.to_string(), spec })
}

/// Scan the flat v2 `"spec"` object. Field conversion goes through
/// the exact helpers the tree path uses (`spec::parse_seed`,
/// `Value::as_usize`, `Quality::parse`, …) and ends with the same
/// `validate()`, so an accepted spec is equal by construction and any
/// rejection bails for the identical typed error.
fn scan_spec(sc: &mut Scanner) -> Option<GenerationSpec> {
    if !sc.eat(b'{') {
        return None;
    }
    let mut spec = GenerationSpec::new();
    if sc.eat(b'}') {
        return Some(spec); // {} is a valid all-defaults spec
    }
    let mut seen_seed = false;
    let mut seen_quality = false;
    let mut seen_priority = false;
    loop {
        let key = sc.raw_string()?;
        if !sc.eat(b':') {
            return None;
        }
        match key {
            "seed" if !seen_seed => {
                seen_seed = true;
                spec.seed =
                    spec::parse_seed(&Value::Num(sc.number()?)).ok()?;
            }
            "steps" if spec.steps.is_none() => {
                spec.steps =
                    Some(Value::Num(sc.number()?).as_usize().ok()?);
            }
            "height" if spec.height_px.is_none() => {
                spec.height_px =
                    Some(Value::Num(sc.number()?).as_usize().ok()?);
            }
            "width" if spec.width_px.is_none() => {
                spec.width_px =
                    Some(Value::Num(sc.number()?).as_usize().ok()?);
            }
            "quality" if !seen_quality => {
                seen_quality = true;
                spec.quality = Quality::parse(sc.raw_string()?).ok()?;
            }
            "priority" if !seen_priority => {
                seen_priority = true;
                spec.priority = Priority::parse(sc.raw_string()?).ok()?;
            }
            "deadline_s" if spec.deadline_s.is_none() => {
                spec.deadline_s = Some(sc.number()?);
            }
            _ => return None, // unknown or duplicated spec key
        }
        if sc.eat(b',') {
            continue;
        }
        if sc.eat(b'}') {
            break;
        }
        return None;
    }
    spec.validate().ok()?;
    Some(spec)
}

/// Serialize a successful generation, echoing the resolved spec.
pub fn response_line(
    id: &str,
    spec: &GenerationSpec,
    gen: &Generation,
    wall_latency_s: f64,
) -> String {
    let mut plan = Object::new();
    for d in &gen.plan.devices {
        let mut dd = Object::new();
        dd.insert("steps", Value::Num(d.steps.len() as f64));
        dd.insert("rows", Value::Num(d.rows.rows as f64));
        dd.insert("speed", Value::Num(d.speed));
        plan.insert(d.name.clone(), Value::Obj(dd));
    }
    let mut o = Object::new();
    o.insert("id", Value::Str(id.to_string()));
    o.insert("ok", Value::Bool(true));
    o.insert("spec", spec.to_json());
    o.insert("latency_s", Value::Num(wall_latency_s));
    o.insert("sim_latency_s", Value::Num(gen.timeline.total_s));
    o.insert("utilization", Value::Num(gen.timeline.utilization));
    o.insert("latent_sum", Value::Num(gen.latent.sum()));
    o.insert(
        "latent_first8",
        Value::from_f32_slice(&gen.latent.data[..8.min(gen.latent.len())]),
    );
    o.insert("plan", Value::Obj(plan));
    json::to_string(&Value::Obj(o))
}

/// Serialize an error response. Every error line carries a stable
/// machine-readable `code` ([`Error::wire_code`]); structured variants
/// additionally expose their payload as dedicated fields (never baked
/// into the message string): `busy` carries `queue_depth`, `deadline`
/// carries `deadline_s` and `late_by_s`.
pub fn error_line(id: &str, err: &Error) -> String {
    if let Error::Busy { queue_depth } = err {
        return busy_line(id, *queue_depth);
    }
    let mut o = Object::new();
    o.insert("id", Value::Str(id.to_string()));
    o.insert("ok", Value::Bool(false));
    o.insert("code", Value::Str(err.wire_code().into()));
    o.insert("error", Value::Str(err.to_string()));
    if let Error::DeadlineExceeded { deadline_s, late_by_s } = err {
        o.insert("deadline_s", Value::Num(*deadline_s));
        o.insert("late_by_s", Value::Num(*late_by_s));
    }
    json::to_string(&Value::Obj(o))
}

/// Serialize a backpressure rejection: `code: "busy"` plus the queue
/// depth observed at rejection as a structured field.
pub fn busy_line(id: &str, queue_depth: usize) -> String {
    let mut o = Object::new();
    o.insert("id", Value::Str(id.to_string()));
    o.insert("ok", Value::Bool(false));
    o.insert("code", Value::Str("busy".into()));
    o.insert("error", Value::Str("queue full, retry later".into()));
    o.insert("queue_depth", Value::Num(queue_depth as f64));
    json::to_string(&Value::Obj(o))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::{Priority, Quality};
    use crate::util::proptest::{ensure, forall};
    use crate::util::rng::Pcg32;

    #[test]
    fn v1_request_parses_as_default_spec() {
        let r = WireRequest::parse("{\"id\": \"r7\", \"seed\": 99}").unwrap();
        assert_eq!(r.id, "r7");
        assert_eq!(r.spec, GenerationSpec::new().seed(99));
        // And the v1 serializer round-trips it.
        let back = WireRequest::parse(&r.to_line_v1()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn v2_request_roundtrip() {
        let r = WireRequest {
            id: "r7".into(),
            spec: GenerationSpec::new()
                .seed(99)
                .steps(50)
                .size(128, 256)
                .quality(Quality::Draft)
                .priority(Priority::High)
                .deadline_s(0.75),
        };
        let back = WireRequest::parse(&r.to_line()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn negative_seed_is_a_typed_rejection_not_a_cast() {
        // v1: `{"seed": -1}` used to become seed 2^64-1 via `as u64`.
        for line in [
            "{\"id\": \"x\", \"seed\": -1}",
            "{\"id\": \"x\", \"spec\": {\"seed\": -7}}",
        ] {
            let e = WireRequest::parse(line).unwrap_err();
            assert!(matches!(e, Error::Spec(_)), "{line} -> {e:?}");
            assert_eq!(e.wire_code(), "bad_spec");
            assert!(e.to_string().contains("non-negative"), "{e}");
        }
    }

    #[test]
    fn rejects_malformed() {
        for line in [
            "{}",
            "{\"id\": 3, \"seed\": 1}",
            "{\"id\": \"x\"}",
            "{\"seed\": 4}",
            "{\"id\": \"x\", \"seed\": 1, \"spec\": {}}",
            "{\"id\": \"x\", \"spec\": 5}",
        ] {
            let e = WireRequest::parse(line).unwrap_err();
            assert!(
                matches!(e, Error::Protocol(_) | Error::Spec(_)),
                "{line} -> {e:?}"
            );
        }
        assert!(matches!(
            WireRequest::parse("not json").unwrap_err(),
            Error::Json { .. }
        ));
    }

    #[test]
    fn invalid_spec_fields_get_bad_spec_code() {
        for line in [
            "{\"id\": \"x\", \"spec\": {\"steps\": 1}}",
            "{\"id\": \"x\", \"spec\": {\"quality\": \"ultra\"}}",
            "{\"id\": \"x\", \"spec\": {\"height\": 100}}",
            "{\"id\": \"x\", \"spec\": {\"deadline_s\": 0}}",
        ] {
            let e = WireRequest::parse(line).unwrap_err();
            assert_eq!(e.wire_code(), "bad_spec", "{line} -> {e:?}");
        }
    }

    #[test]
    fn error_line_is_json_with_stable_codes() {
        let line = error_line("x", &Error::msg("boom"));
        let v = json::parse(&line).unwrap();
        assert!(!v.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "error");
        assert!(v.get("error").unwrap().as_str().unwrap().contains("boom"));

        let line = error_line("x", &Error::Spec("bad steps".into()));
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "bad_spec");

        let line = error_line("x", &Error::Shutdown);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "shutdown");
    }

    #[test]
    fn deadline_line_carries_structured_fields() {
        let line = error_line(
            "r1",
            &Error::DeadlineExceeded { deadline_s: 0.5, late_by_s: 0.125 },
        );
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "deadline");
        assert_eq!(v.get("deadline_s").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(v.get("late_by_s").unwrap().as_f64().unwrap(), 0.125);
    }

    #[test]
    fn busy_line_is_structured() {
        // Both the direct constructor and the Error::Busy route must
        // produce code=busy with the depth as a separate field — and
        // must NOT serialize internal state into the message.
        for line in [
            busy_line("r1", 5),
            error_line("r1", &Error::Busy { queue_depth: 5 }),
        ] {
            let v = json::parse(&line).unwrap();
            assert!(!v.get("ok").unwrap().as_bool().unwrap());
            assert_eq!(v.get("code").unwrap().as_str().unwrap(), "busy");
            assert_eq!(v.get("queue_depth").unwrap().as_usize().unwrap(), 5);
            assert!(!v
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains('5'));
        }
    }

    /// One randomized round-trip case (no shrinking — the spec space
    /// is flat enough that the raw counterexample is already minimal).
    #[derive(Debug, Clone)]
    struct Case {
        spec: GenerationSpec,
        corrupt: bool,
        which: u8,
    }

    impl crate::util::proptest::Shrink for Case {}

    /// Satellite: builder validation + wire round-trip over randomized
    /// specs. Valid specs must survive `parse(to_line(spec))` exactly;
    /// invalid ones must be rejected with the `bad_spec` code.
    #[test]
    fn property_spec_wire_roundtrip() {
        forall(
            41,
            300,
            |rng| {
                // Seeds capped at MAX_SEED: JSON numbers are f64.
                let mut spec = GenerationSpec::new()
                    .seed(rng.next_u64() % (crate::spec::MAX_SEED + 1));
                // Each optional field present with probability ~1/2.
                if rng.below(2) == 0 {
                    spec = spec.steps(2 + rng.below(200) as usize);
                }
                if rng.below(2) == 0 {
                    let h = 8 * (1 + rng.below(64) as usize);
                    let w = 8 * (1 + rng.below(64) as usize);
                    spec = spec.size(h, w);
                }
                spec = spec.quality(match rng.below(3) {
                    0 => Quality::Draft,
                    1 => Quality::Standard,
                    _ => Quality::High,
                });
                spec = spec.priority(match rng.below(3) {
                    0 => Priority::Low,
                    1 => Priority::Normal,
                    _ => Priority::High,
                });
                if rng.below(2) == 0 {
                    spec = spec.deadline_s(
                        0.01 + 10.0 * rng.next_f64(),
                    );
                }
                // Corrupt ~1/4 of the samples with one invalid field.
                let corrupt = rng.below(4) == 0;
                let which = rng.below(3) as u8;
                Case { spec, corrupt, which }
            },
            |Case { spec, corrupt, which }| {
                if *corrupt {
                    let mut bad = spec.clone();
                    match which {
                        0 => bad.steps = Some(1),
                        1 => bad.height_px = Some(12), // not 8-aligned
                        _ => bad.deadline_s = Some(-1.0),
                    }
                    let req =
                        WireRequest { id: "p".into(), spec: bad.clone() };
                    let e = match WireRequest::parse(&req.to_line()) {
                        Err(e) => e,
                        Ok(_) => {
                            return Err(format!(
                                "invalid spec accepted: {bad:?}"
                            ))
                        }
                    };
                    ensure(
                        e.wire_code() == "bad_spec",
                        format!("wrong code {} for {bad:?}", e.wire_code()),
                    )?;
                    return Ok(());
                }
                ensure(
                    spec.validate().is_ok(),
                    format!("generator produced invalid spec {spec:?}"),
                )?;
                let req = WireRequest { id: "p".into(), spec: spec.clone() };
                let back = WireRequest::parse(&req.to_line())
                    .map_err(|e| format!("roundtrip failed: {e}"))?;
                ensure(
                    back.spec == *spec,
                    format!("roundtrip drift: {spec:?} -> {:?}", back.spec),
                )?;
                Ok(())
            },
        );
    }

    #[test]
    fn lazy_fast_path_covers_common_lines_and_bails_on_odd_ones() {
        // The canonical v1 and v2 shapes take the in-place scan.
        for line in [
            r#"{"id":"r1","seed":42}"#,
            r#"{"id": "r1", "seed": 42}"#,
            concat!(
                r#"{"id":"r1","spec":{"seed":9,"steps":28,"#,
                r#""height":256,"width":256,"quality":"standard","#,
                r#""priority":"normal","deadline_s":2.5}}"#,
            ),
            r#"{"id":"r1","spec":{}}"#,
        ] {
            let (r, fast) = parse_lazy_tracked(line);
            assert!(fast, "expected fast path for {line}");
            assert_eq!(r.unwrap(), WireRequest::parse(line).unwrap());
        }
        // Odd-but-valid lines fall back (and still parse identically);
        // invalid ones fall back for the identical typed error.
        for line in [
            r#"{"id":"a\nb","seed":1}"#,          // escape in id
            r#"{"id":"r1","seed":1,"zzz":2}"#,    // unknown field
            r#"{"id":"r1","spec":{"seed":1,"future_knob":true}}"#,
            r#"{"id":"a","id":"b","seed":1}"#,    // duplicate key
            r#"{"id":"x","seed":1,"spec":{}}"#,   // v1+v2 mix
            r#"{"id":"x","seed":-1}"#,            // typed bad_spec
            "not json",
        ] {
            let (r, fast) = parse_lazy_tracked(line);
            assert!(!fast, "expected fallback for {line}");
            match (r, WireRequest::parse(line)) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "{line}"),
                (Err(a), Err(b)) => {
                    assert_eq!(a.wire_code(), b.wire_code(), "{line}");
                }
                (a, b) => panic!("drift on {line}: {a:?} vs {b:?}"),
            }
        }
    }

    /// One randomized wire line (no shrinking — the reproducing line
    /// is printed verbatim, which is already the minimal artifact).
    #[derive(Debug, Clone)]
    struct LineCase {
        line: String,
    }

    impl crate::util::proptest::Shrink for LineCase {}

    /// ASCII-only line soup spanning both versions and every bail
    /// trigger: clean v1/v2, escaped ids, huge/negative/float seeds,
    /// unknown and duplicated fields, v1+v2 mixes, stray whitespace,
    /// truncated prefixes and plain garbage. ASCII-only keeps byte
    /// truncation valid UTF-8.
    fn random_wire_line(rng: &mut Pcg32) -> String {
        let id: String = (0..1 + rng.below(8))
            .map(|_| char::from(b'a' + rng.below(26) as u8))
            .collect();
        let seed_lit = match rng.below(10) {
            0 => "2.5".to_string(),
            1 => "1e3".to_string(),
            2 => format!("{}", crate::spec::MAX_SEED + rng.below(3) as u64),
            3 => format!("-{}", 1 + rng.below(100)),
            4 => format!("{}", 1u64 << (40 + rng.below(23))),
            _ => format!("{}", rng.below(100_000)),
        };
        match rng.below(10) {
            0 => format!("{{\"id\":\"{id}\",\"seed\":{seed_lit}}}"),
            1 | 2 => {
                // v2 with a random field subset; some values invalid
                // (steps 0/1, heights off the VAE grid, deadlines
                // <= 0) so typed bad_spec errors are exercised too.
                let mut parts = vec![format!("\"seed\":{seed_lit}")];
                if rng.below(2) == 0 {
                    parts.push(format!("\"steps\":{}", rng.below(60)));
                }
                if rng.below(2) == 0 {
                    parts.push(format!("\"height\":{}", 4 * rng.below(80)));
                }
                if rng.below(2) == 0 {
                    parts.push(format!("\"width\":{}", 8 * rng.below(40)));
                }
                if rng.below(2) == 0 {
                    let q = ["draft", "standard", "high", "ultra"]
                        [rng.below(4) as usize];
                    parts.push(format!("\"quality\":\"{q}\""));
                }
                if rng.below(2) == 0 {
                    let p = ["low", "normal", "high", "urgent"]
                        [rng.below(4) as usize];
                    parts.push(format!("\"priority\":\"{p}\""));
                }
                if rng.below(2) == 0 {
                    parts.push(format!(
                        "\"deadline_s\":{}",
                        rng.below(40) as f64 / 8.0 - 1.0
                    ));
                }
                format!(
                    "{{\"id\":\"{id}\",\"spec\":{{{}}}}}",
                    parts.join(",")
                )
            }
            3 => format!("{{\"id\":\"a\\n{id}\",\"seed\":{seed_lit}}}"),
            4 => format!(
                "{{\"id\":\"{id}\",\"seed\":{seed_lit},\
                 \"extra\":[1,{{\"z\":null}}]}}"
            ),
            5 => format!(
                "{{\"id\":\"{id}\",\"spec\":{{\"seed\":{seed_lit},\
                 \"future_knob\":true}}}}"
            ),
            6 => format!(
                "{{\"id\":\"{id}\",\"id\":\"dup\",\"seed\":{seed_lit}}}"
            ),
            7 => format!(
                "{{\"id\":\"{id}\",\"seed\":{seed_lit},\"spec\":{{}}}}"
            ),
            8 => format!(
                " {{ \"id\" : \"{id}\" ,\t\"seed\" : {seed_lit} }} "
            ),
            _ => {
                let base = format!("{{\"id\":\"{id}\",\"seed\":{seed_lit}}}");
                match rng.below(3) {
                    0 => base[..rng.below(base.len() as u32 + 1) as usize]
                        .to_string(),
                    1 => format!("{base} trailing"),
                    _ => ["", "not json", "{", "[1,2]", "{\"seed\":}"]
                        [rng.below(5) as usize]
                        .to_string(),
                }
            }
        }
    }

    /// Satellite: `parse_lazy` is equivalent to the full-tree parse —
    /// identical structs and re-serialized bytes on success, identical
    /// wire code and error line on failure — over randomized lines.
    /// Any divergence prints the reproducing line verbatim.
    #[test]
    fn property_lazy_parse_matches_full_parse() {
        forall(
            59,
            500,
            |rng| LineCase { line: random_wire_line(rng) },
            |LineCase { line }| {
                let full = WireRequest::parse(line);
                let (lazy, _fast) = parse_lazy_tracked(line);
                match (&full, &lazy) {
                    (Ok(a), Ok(b)) => {
                        ensure(
                            a == b,
                            format!(
                                "struct drift on {line:?}: {a:?} vs {b:?}"
                            ),
                        )?;
                        ensure(
                            a.to_line() == b.to_line(),
                            format!("byte drift on {line:?}"),
                        )
                    }
                    (Err(a), Err(b)) => {
                        ensure(
                            a.wire_code() == b.wire_code(),
                            format!(
                                "code drift on {line:?}: {} vs {}",
                                a.wire_code(),
                                b.wire_code()
                            ),
                        )?;
                        ensure(
                            error_line("p", a) == error_line("p", b),
                            format!(
                                "error-line drift on {line:?}: \
                                 {a:?} vs {b:?}"
                            ),
                        )
                    }
                    _ => Err(format!(
                        "ok/err drift on {line:?}: full={full:?} \
                         lazy={lazy:?}"
                    )),
                }
            },
        );
    }
}
