//! JSON-lines wire protocol for the serving front-end.
//!
//! One request per line:
//!   {"id": "r1", "seed": 1234}
//! One response per line:
//!   {"id": "r1", "ok": true, "latency_s": ..., "sim_latency_s": ...,
//!    "latent_sum": ..., "latent_first8": [...], "plan": {...}}
//!
//! The latent itself is summarized (sum + first values) rather than
//! shipped — clients needing pixels use the library API; the server
//! exists to exercise routing/queueing on the request path.

use crate::coordinator::Generation;
use crate::error::{Error, Result};
use crate::util::json::{self, Object, Value};

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub id: String,
    pub seed: u64,
}

impl WireRequest {
    pub fn parse(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        Ok(WireRequest {
            id: v.get("id")?.as_str()?.to_string(),
            seed: v.get("seed")?.as_i64().map(|x| x as u64)?,
        })
    }

    pub fn to_line(&self) -> String {
        let mut o = Object::new();
        o.insert("id", Value::Str(self.id.clone()));
        o.insert("seed", Value::Num(self.seed as f64));
        json::to_string(&Value::Obj(o))
    }
}

/// Serialize a successful generation.
pub fn response_line(
    id: &str,
    gen: &Generation,
    wall_latency_s: f64,
) -> String {
    let mut plan = Object::new();
    for d in &gen.plan.devices {
        let mut dd = Object::new();
        dd.insert("steps", Value::Num(d.steps.len() as f64));
        dd.insert("rows", Value::Num(d.rows.rows as f64));
        dd.insert("speed", Value::Num(d.speed));
        plan.insert(d.name.clone(), Value::Obj(dd));
    }
    let mut o = Object::new();
    o.insert("id", Value::Str(id.to_string()));
    o.insert("ok", Value::Bool(true));
    o.insert("latency_s", Value::Num(wall_latency_s));
    o.insert("sim_latency_s", Value::Num(gen.timeline.total_s));
    o.insert("utilization", Value::Num(gen.timeline.utilization));
    o.insert("latent_sum", Value::Num(gen.latent.sum()));
    o.insert(
        "latent_first8",
        Value::from_f32_slice(&gen.latent.data[..8.min(gen.latent.len())]),
    );
    o.insert("plan", Value::Obj(plan));
    json::to_string(&Value::Obj(o))
}

/// Serialize an error response. Every error line carries a stable
/// machine-readable `code`; backpressure rejections get the dedicated
/// `busy` shape (queue depth as its own field, never leaked into the
/// message string).
pub fn error_line(id: &str, err: &Error) -> String {
    if let Error::Busy { queue_depth } = err {
        return busy_line(id, *queue_depth);
    }
    let mut o = Object::new();
    o.insert("id", Value::Str(id.to_string()));
    o.insert("ok", Value::Bool(false));
    o.insert("code", Value::Str("error".into()));
    o.insert("error", Value::Str(err.to_string()));
    json::to_string(&Value::Obj(o))
}

/// Serialize a backpressure rejection: `code: "busy"` plus the queue
/// depth observed at rejection as a structured field.
pub fn busy_line(id: &str, queue_depth: usize) -> String {
    let mut o = Object::new();
    o.insert("id", Value::Str(id.to_string()));
    o.insert("ok", Value::Bool(false));
    o.insert("code", Value::Str("busy".into()));
    o.insert("error", Value::Str("queue full, retry later".into()));
    o.insert("queue_depth", Value::Num(queue_depth as f64));
    json::to_string(&Value::Obj(o))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = WireRequest { id: "r7".into(), seed: 99 };
        let back = WireRequest::parse(&r.to_line()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn rejects_malformed() {
        assert!(WireRequest::parse("{}").is_err());
        assert!(WireRequest::parse("{\"id\": 3, \"seed\": 1}").is_err());
        assert!(WireRequest::parse("not json").is_err());
    }

    #[test]
    fn error_line_is_json() {
        let line = error_line("x", &Error::msg("boom"));
        let v = json::parse(&line).unwrap();
        assert!(!v.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "error");
        assert!(v.get("error").unwrap().as_str().unwrap().contains("boom"));
    }

    #[test]
    fn busy_line_is_structured() {
        // Both the direct constructor and the Error::Busy route must
        // produce code=busy with the depth as a separate field — and
        // must NOT serialize internal state into the message.
        for line in [
            busy_line("r1", 5),
            error_line("r1", &Error::Busy { queue_depth: 5 }),
        ] {
            let v = json::parse(&line).unwrap();
            assert!(!v.get("ok").unwrap().as_bool().unwrap());
            assert_eq!(v.get("code").unwrap().as_str().unwrap(), "busy");
            assert_eq!(v.get("queue_depth").unwrap().as_usize().unwrap(), 5);
            assert!(!v
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains('5'));
        }
    }
}
