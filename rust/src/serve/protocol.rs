//! JSON-lines wire protocol for the serving front-end.
//!
//! **v2** — one request per line, parameters in a typed spec object:
//!   {"id": "r1", "spec": {"seed": 9, "steps": 50, "height": 256,
//!    "width": 256, "quality": "standard", "priority": "high",
//!    "deadline_s": 2.5}}
//! Every spec field is optional; omitted fields take the engine's
//! defaults. Responses echo the full resolved spec:
//!   {"id": "r1", "ok": true, "spec": {...}, "latency_s": ...,
//!    "sim_latency_s": ..., "latent_sum": ..., "latent_first8": [...],
//!    "plan": {...}}
//!
//! **v1** — `{"id": "r1", "seed": 1234}` lines keep parsing as
//! default-spec requests and produce byte-identical numeric results to
//! the pre-spec engine (the backcompat golden test pins this).
//!
//! Error lines carry a stable machine-readable `code`
//! ([`Error::wire_code`]): `busy` (backpressure, with `queue_depth`),
//! `bad_request` (malformed line), `bad_spec` (invalid spec fields —
//! including negative seeds, which v1 used to silently cast through
//! `as u64`), `deadline` (shed after its deadline passed, with
//! `deadline_s`/`late_by_s`), `shutdown`, and `error` (everything
//! else). Clients dispatch on the code, never on the message text.
//!
//! The latent itself is summarized (sum + first values) rather than
//! shipped — clients needing pixels use the library API; the server
//! exists to exercise routing/queueing on the request path.

use crate::coordinator::Generation;
use crate::error::{Error, Result};
use crate::spec::{self, GenerationSpec};
use crate::util::json::{self, Object, Value};

/// A parsed client request: id + typed generation spec.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub id: String,
    pub spec: GenerationSpec,
}

impl WireRequest {
    /// Parse one request line, v2 (`"spec"` object) or v1 (bare
    /// `"seed"`). A line carrying *both* is rejected as ambiguous.
    pub fn parse(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        let id = v
            .get_opt("id")
            .ok_or_else(|| Error::Protocol("missing \"id\"".into()))?
            .as_str()
            .map_err(|_| Error::Protocol("\"id\" must be a string".into()))?
            .to_string();
        let spec = match (v.get_opt("spec"), v.get_opt("seed")) {
            (Some(_), Some(_)) => {
                return Err(Error::Protocol(
                    "request has both \"spec\" (v2) and \"seed\" (v1)"
                        .into(),
                ))
            }
            (Some(s), None) => GenerationSpec::from_json(s)?,
            (None, Some(seed)) => {
                // v1 compat: a bare seed is a default-spec request.
                GenerationSpec::new().seed(spec::parse_seed(seed)?)
            }
            (None, None) => {
                return Err(Error::Protocol(
                    "request needs \"spec\" (v2) or \"seed\" (v1)".into(),
                ))
            }
        };
        Ok(WireRequest { id, spec })
    }

    /// Serialize as a v2 line (full spec object).
    pub fn to_line(&self) -> String {
        let mut o = Object::new();
        o.insert("id", Value::Str(self.id.clone()));
        o.insert("spec", self.spec.to_json());
        json::to_string(&Value::Obj(o))
    }

    /// Serialize as a v1 line (`{"id", "seed"}`) — the backcompat
    /// client shape. Only the seed survives; other spec fields are
    /// not expressible in v1.
    pub fn to_line_v1(&self) -> String {
        let mut o = Object::new();
        o.insert("id", Value::Str(self.id.clone()));
        o.insert("seed", Value::Num(self.spec.seed as f64));
        json::to_string(&Value::Obj(o))
    }
}

/// Serialize a successful generation, echoing the resolved spec.
pub fn response_line(
    id: &str,
    spec: &GenerationSpec,
    gen: &Generation,
    wall_latency_s: f64,
) -> String {
    let mut plan = Object::new();
    for d in &gen.plan.devices {
        let mut dd = Object::new();
        dd.insert("steps", Value::Num(d.steps.len() as f64));
        dd.insert("rows", Value::Num(d.rows.rows as f64));
        dd.insert("speed", Value::Num(d.speed));
        plan.insert(d.name.clone(), Value::Obj(dd));
    }
    let mut o = Object::new();
    o.insert("id", Value::Str(id.to_string()));
    o.insert("ok", Value::Bool(true));
    o.insert("spec", spec.to_json());
    o.insert("latency_s", Value::Num(wall_latency_s));
    o.insert("sim_latency_s", Value::Num(gen.timeline.total_s));
    o.insert("utilization", Value::Num(gen.timeline.utilization));
    o.insert("latent_sum", Value::Num(gen.latent.sum()));
    o.insert(
        "latent_first8",
        Value::from_f32_slice(&gen.latent.data[..8.min(gen.latent.len())]),
    );
    o.insert("plan", Value::Obj(plan));
    json::to_string(&Value::Obj(o))
}

/// Serialize an error response. Every error line carries a stable
/// machine-readable `code` ([`Error::wire_code`]); structured variants
/// additionally expose their payload as dedicated fields (never baked
/// into the message string): `busy` carries `queue_depth`, `deadline`
/// carries `deadline_s` and `late_by_s`.
pub fn error_line(id: &str, err: &Error) -> String {
    if let Error::Busy { queue_depth } = err {
        return busy_line(id, *queue_depth);
    }
    let mut o = Object::new();
    o.insert("id", Value::Str(id.to_string()));
    o.insert("ok", Value::Bool(false));
    o.insert("code", Value::Str(err.wire_code().into()));
    o.insert("error", Value::Str(err.to_string()));
    if let Error::DeadlineExceeded { deadline_s, late_by_s } = err {
        o.insert("deadline_s", Value::Num(*deadline_s));
        o.insert("late_by_s", Value::Num(*late_by_s));
    }
    json::to_string(&Value::Obj(o))
}

/// Serialize a backpressure rejection: `code: "busy"` plus the queue
/// depth observed at rejection as a structured field.
pub fn busy_line(id: &str, queue_depth: usize) -> String {
    let mut o = Object::new();
    o.insert("id", Value::Str(id.to_string()));
    o.insert("ok", Value::Bool(false));
    o.insert("code", Value::Str("busy".into()));
    o.insert("error", Value::Str("queue full, retry later".into()));
    o.insert("queue_depth", Value::Num(queue_depth as f64));
    json::to_string(&Value::Obj(o))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::{Priority, Quality};
    use crate::util::proptest::{ensure, forall};

    #[test]
    fn v1_request_parses_as_default_spec() {
        let r = WireRequest::parse("{\"id\": \"r7\", \"seed\": 99}").unwrap();
        assert_eq!(r.id, "r7");
        assert_eq!(r.spec, GenerationSpec::new().seed(99));
        // And the v1 serializer round-trips it.
        let back = WireRequest::parse(&r.to_line_v1()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn v2_request_roundtrip() {
        let r = WireRequest {
            id: "r7".into(),
            spec: GenerationSpec::new()
                .seed(99)
                .steps(50)
                .size(128, 256)
                .quality(Quality::Draft)
                .priority(Priority::High)
                .deadline_s(0.75),
        };
        let back = WireRequest::parse(&r.to_line()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn negative_seed_is_a_typed_rejection_not_a_cast() {
        // v1: `{"seed": -1}` used to become seed 2^64-1 via `as u64`.
        for line in [
            "{\"id\": \"x\", \"seed\": -1}",
            "{\"id\": \"x\", \"spec\": {\"seed\": -7}}",
        ] {
            let e = WireRequest::parse(line).unwrap_err();
            assert!(matches!(e, Error::Spec(_)), "{line} -> {e:?}");
            assert_eq!(e.wire_code(), "bad_spec");
            assert!(e.to_string().contains("non-negative"), "{e}");
        }
    }

    #[test]
    fn rejects_malformed() {
        for line in [
            "{}",
            "{\"id\": 3, \"seed\": 1}",
            "{\"id\": \"x\"}",
            "{\"seed\": 4}",
            "{\"id\": \"x\", \"seed\": 1, \"spec\": {}}",
            "{\"id\": \"x\", \"spec\": 5}",
        ] {
            let e = WireRequest::parse(line).unwrap_err();
            assert!(
                matches!(e, Error::Protocol(_) | Error::Spec(_)),
                "{line} -> {e:?}"
            );
        }
        assert!(matches!(
            WireRequest::parse("not json").unwrap_err(),
            Error::Json { .. }
        ));
    }

    #[test]
    fn invalid_spec_fields_get_bad_spec_code() {
        for line in [
            "{\"id\": \"x\", \"spec\": {\"steps\": 1}}",
            "{\"id\": \"x\", \"spec\": {\"quality\": \"ultra\"}}",
            "{\"id\": \"x\", \"spec\": {\"height\": 100}}",
            "{\"id\": \"x\", \"spec\": {\"deadline_s\": 0}}",
        ] {
            let e = WireRequest::parse(line).unwrap_err();
            assert_eq!(e.wire_code(), "bad_spec", "{line} -> {e:?}");
        }
    }

    #[test]
    fn error_line_is_json_with_stable_codes() {
        let line = error_line("x", &Error::msg("boom"));
        let v = json::parse(&line).unwrap();
        assert!(!v.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "error");
        assert!(v.get("error").unwrap().as_str().unwrap().contains("boom"));

        let line = error_line("x", &Error::Spec("bad steps".into()));
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "bad_spec");

        let line = error_line("x", &Error::Shutdown);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "shutdown");
    }

    #[test]
    fn deadline_line_carries_structured_fields() {
        let line = error_line(
            "r1",
            &Error::DeadlineExceeded { deadline_s: 0.5, late_by_s: 0.125 },
        );
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("code").unwrap().as_str().unwrap(), "deadline");
        assert_eq!(v.get("deadline_s").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(v.get("late_by_s").unwrap().as_f64().unwrap(), 0.125);
    }

    #[test]
    fn busy_line_is_structured() {
        // Both the direct constructor and the Error::Busy route must
        // produce code=busy with the depth as a separate field — and
        // must NOT serialize internal state into the message.
        for line in [
            busy_line("r1", 5),
            error_line("r1", &Error::Busy { queue_depth: 5 }),
        ] {
            let v = json::parse(&line).unwrap();
            assert!(!v.get("ok").unwrap().as_bool().unwrap());
            assert_eq!(v.get("code").unwrap().as_str().unwrap(), "busy");
            assert_eq!(v.get("queue_depth").unwrap().as_usize().unwrap(), 5);
            assert!(!v
                .get("error")
                .unwrap()
                .as_str()
                .unwrap()
                .contains('5'));
        }
    }

    /// One randomized round-trip case (no shrinking — the spec space
    /// is flat enough that the raw counterexample is already minimal).
    #[derive(Debug, Clone)]
    struct Case {
        spec: GenerationSpec,
        corrupt: bool,
        which: u8,
    }

    impl crate::util::proptest::Shrink for Case {}

    /// Satellite: builder validation + wire round-trip over randomized
    /// specs. Valid specs must survive `parse(to_line(spec))` exactly;
    /// invalid ones must be rejected with the `bad_spec` code.
    #[test]
    fn property_spec_wire_roundtrip() {
        forall(
            41,
            300,
            |rng| {
                // Seeds capped at MAX_SEED: JSON numbers are f64.
                let mut spec = GenerationSpec::new()
                    .seed(rng.next_u64() % (crate::spec::MAX_SEED + 1));
                // Each optional field present with probability ~1/2.
                if rng.below(2) == 0 {
                    spec = spec.steps(2 + rng.below(200) as usize);
                }
                if rng.below(2) == 0 {
                    let h = 8 * (1 + rng.below(64) as usize);
                    let w = 8 * (1 + rng.below(64) as usize);
                    spec = spec.size(h, w);
                }
                spec = spec.quality(match rng.below(3) {
                    0 => Quality::Draft,
                    1 => Quality::Standard,
                    _ => Quality::High,
                });
                spec = spec.priority(match rng.below(3) {
                    0 => Priority::Low,
                    1 => Priority::Normal,
                    _ => Priority::High,
                });
                if rng.below(2) == 0 {
                    spec = spec.deadline_s(
                        0.01 + 10.0 * rng.next_f64(),
                    );
                }
                // Corrupt ~1/4 of the samples with one invalid field.
                let corrupt = rng.below(4) == 0;
                let which = rng.below(3) as u8;
                Case { spec, corrupt, which }
            },
            |Case { spec, corrupt, which }| {
                if *corrupt {
                    let mut bad = spec.clone();
                    match which {
                        0 => bad.steps = Some(1),
                        1 => bad.height_px = Some(12), // not 8-aligned
                        _ => bad.deadline_s = Some(-1.0),
                    }
                    let req =
                        WireRequest { id: "p".into(), spec: bad.clone() };
                    let e = match WireRequest::parse(&req.to_line()) {
                        Err(e) => e,
                        Ok(_) => {
                            return Err(format!(
                                "invalid spec accepted: {bad:?}"
                            ))
                        }
                    };
                    ensure(
                        e.wire_code() == "bad_spec",
                        format!("wrong code {} for {bad:?}", e.wire_code()),
                    )?;
                    return Ok(());
                }
                ensure(
                    spec.validate().is_ok(),
                    format!("generator produced invalid spec {spec:?}"),
                )?;
                let req = WireRequest { id: "p".into(), spec: spec.clone() };
                let back = WireRequest::parse(&req.to_line())
                    .map_err(|e| format!("roundtrip failed: {e}"))?;
                ensure(
                    back.spec == *spec,
                    format!("roundtrip drift: {spec:?} -> {:?}", back.spec),
                )?;
                Ok(())
            },
        );
    }
}
