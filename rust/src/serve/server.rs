//! TCP JSON-lines server: the deployable front-end, truly concurrent.
//!
//! `stadi serve --addr 127.0.0.1:7878 --workers 4` runs two kinds of
//! threads around the thread-safe bounded priority [`Router`]
//! (priority desc, earliest deadline, FIFO; expired requests shed on
//! dequeue with the typed `deadline` wire code):
//!
//! * the **event loop** (caller's thread) — a single `poll(2)`
//!   readiness loop owning the nonblocking listener and a bounded
//!   connection table ([`IoMode::Events`], the default on unix). Each
//!   table slot carries the connection's read buffer (line framing,
//!   oversize cap), per-connection sequence numbers, the FIFO reorder
//!   map, and a bounded write queue — so response ordering and write
//!   backpressure live in the table, not in two threads per
//!   connection. Requests parse on the lazy wire hot path
//!   ([`protocol::parse_lazy`]) and enqueue (busy rejections answered
//!   immediately with the structured `busy` code); completions flow
//!   back from the workers over a self-pipe that wakes `poll`. The
//!   listener is registered only while the table has a free slot, so
//!   at `max_connections` new clients wait in the OS accept backlog
//!   with zero CPU spent on them. `--io threads` keeps the previous
//!   reader/reorder-writer thread pair per connection (byte-identical
//!   responses, pinned by the connection-scale test) for one release.
//! * a **worker pool** draining the queue into per-request
//!   [`Session`](crate::coordinator::Session)s on the shared
//!   [`EngineCore`] — N in-flight requests overlap their sampler /
//!   halo / serialization work around the single PJRT service thread.
//!
//! Execution is abstracted behind [`JobRunner`] so the serving
//! machinery is testable without artifacts (integration tests drive it
//! with a stub runner; production uses [`SessionRunner`]).

use std::collections::BTreeMap;
#[cfg(unix)]
use std::collections::VecDeque;
#[cfg(unix)]
use std::io::Read;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
#[cfg(unix)]
use std::sync::Mutex;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use crate::config::{BatchConfig, DegradeConfig, IoMode};
use crate::coordinator::{EngineCore, FusedJoiner, Generation};
use crate::error::{Error, Result};
use crate::federation::FrontTier;
use crate::fleet::{FleetManager, GangPolicy};
use crate::serve::batch::{BatchGates, FuseKey, JoinReply, Offer};
use crate::serve::degrade;
use crate::serve::protocol::{self, WireRequest};
use crate::serve::router::{Dequeued, Job, Prioritized, Router, RouterStats};
use crate::spec::{GenerationSpec, Quality};
use crate::util::{json, stats};

/// How often blocked accept/read calls re-check shutdown flags.
const ACCEPT_POLL: Duration = Duration::from_millis(10);
const READ_POLL: Duration = Duration::from_millis(100);
/// Cap on how long a response write may block: a client that stops
/// reading (full TCP send buffer) must not wedge its writer thread —
/// and with it `serve`'s final join — indefinitely. The event loop
/// applies the same bound as a stall deadline: a connection whose
/// socket has accepted no response bytes for this long while bytes
/// are queued is torn down.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);
/// Event-path cap on one request line. A line that grows past it
/// without a newline is answered with a typed `bad_request` and
/// discarded up to its terminating newline; the connection survives.
/// Generous: real v2 request lines are a few hundred bytes.
#[cfg(unix)]
const MAX_LINE_BYTES: usize = 64 * 1024;
/// Event-path read gate: once this many unwritten response bytes are
/// queued on a connection (client not reading), stop reading new
/// requests from it until the client drains — already-admitted work
/// still answers, but a non-reading client can't grow its queue
/// unboundedly or wedge anyone else.
#[cfg(unix)]
const WRITE_HIGH_WATER: usize = 256 * 1024;

/// Serving knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Router queue capacity (admission control).
    pub queue_capacity: usize,
    /// Worker threads draining the queue — the number of requests in
    /// flight concurrently.
    pub workers: usize,
    /// Stop after this many executed requests (0 = no cap). With more
    /// than one worker this is a low-water mark, not an exact count:
    /// jobs already in flight on other workers when the Nth completes
    /// still drain (their clients are owed responses) and are counted.
    pub max_requests: usize,
    /// Cap on simultaneously-open client connections — the event
    /// loop's table size (threads mode: one reader + writer thread
    /// pair each). At the cap the listener is deregistered from the
    /// poll set, so further connections wait in the OS accept backlog
    /// — the job queue bounds work, this bounds table slots/threads.
    pub max_connections: usize,
    /// Connection front-end: [`IoMode::Events`] (default) runs every
    /// connection in the single poll-thread table; [`IoMode::Threads`]
    /// keeps the pre-event-loop thread-per-connection path
    /// (byte-identical responses, selectable for one release). On
    /// non-unix targets events mode falls back to threads.
    pub io: IoMode,
    /// Cross-request batching (fused denoise sessions). Disabled by
    /// default: the solo path is pinned byte-identical to pre-batching
    /// behavior.
    pub batch: BatchConfig,
    /// Graceful degradation under overload (pressure-driven quality
    /// demotion + mid-flight suffix re-quantization). Disabled by
    /// default: the serve path is pinned bit-exact to pre-degrade
    /// behavior.
    pub degrade: DegradeConfig,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            queue_capacity: 64,
            workers: 2,
            max_requests: 0,
            max_connections: 256,
            io: IoMode::default(),
            batch: BatchConfig::default(),
            degrade: DegradeConfig::default(),
        }
    }
}

/// Executes one job into one wire response line. Implemented by
/// [`SessionRunner`] for real generation; tests substitute stubs so
/// the queueing/ordering/shutdown machinery runs without artifacts.
pub trait JobRunner: Send + Sync + 'static {
    /// Returns `(ok, response line)`; `ok` feeds the router's
    /// per-outcome stats.
    fn run(&self, job: &Job) -> (bool, String);

    /// Like [`JobRunner::run`], with the number of jobs still queued
    /// behind this one — the live demand signal load-adaptive runners
    /// (gang policies) act on. Workers call this; the default ignores
    /// the load, so plain runners only implement `run`.
    fn run_with_load(&self, job: &Job, queued: usize) -> (bool, String) {
        let _ = queued;
        self.run(job)
    }

    /// Admission-time validation, called by the connection reader when
    /// a request parses, *before* it enters the router. An `Err` is
    /// answered immediately with the error's wire code and the job
    /// never queues, never reaches a worker, and never acquires a
    /// fleet lease — this is where inexecutable resolutions are shed
    /// with `bad_spec`. The default admits everything (stub runners,
    /// plain harnesses).
    fn admit(&self, job: &Job) -> Result<()> {
        let _ = job;
        Ok(())
    }

    /// Batch-compatibility key for a job: jobs with equal keys may
    /// fuse into one session. `None` (the default) = this job never
    /// fuses, so the worker skips the admission window entirely.
    fn fuse_key(&self, job: &Job) -> Option<FuseKey> {
        let _ = job;
        None
    }

    /// Run a gathered group of fuse-compatible jobs, ideally as one
    /// fused session; returns one `(ok, line)` per job, in order.
    /// `record` feeds the router's occupancy histogram: call it once
    /// per dispatched session with the total member count (including
    /// barrier joiners); a job adopted into *another* session must not
    /// be recorded here (its founder counts it). The default runs each
    /// job solo (stub runners, batching off).
    fn run_batched(
        &self,
        jobs: &[Job],
        backlog: usize,
        record: &dyn Fn(usize),
    ) -> Vec<(bool, String)> {
        jobs.iter()
            .map(|j| {
                record(1);
                self.run_with_load(j, backlog)
            })
            .collect()
    }

    /// Admission-time shaping hook, called by the worker on a freshly
    /// popped job *before* it is fuse-keyed or executed. A
    /// pressure-aware runner may rewrite the spec here (quality-tier
    /// demotion under backlog); the default leaves it untouched.
    fn shape(&self, job: &mut Job, backlog: usize) {
        let _ = (job, backlog);
    }

    /// [`JobRunner::run_batched`] with a *live* backlog probe in
    /// addition to the dispatch-time snapshot, so a degradation-aware
    /// runner can re-read queueing pressure at mid-flight sync
    /// barriers. The default ignores the probe — behavior identical to
    /// `run_batched` — so plain runners never see it.
    fn run_batched_live(
        &self,
        jobs: &[Job],
        backlog: usize,
        live_backlog: &dyn Fn() -> usize,
        record: &dyn Fn(usize),
    ) -> Vec<(bool, String)> {
        let _ = live_backlog;
        self.run_batched(jobs, backlog, record)
    }

    /// Cumulative graceful-degradation counters
    /// `(demoted, requantized)` the server folds into the router's
    /// final stats snapshot at shutdown. The default reports none.
    fn degrade_counts(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Production runner: one fresh [`Session`](crate::coordinator::Session)
/// per job on the shared core. With a fleet configured, each job first
/// acquires a [`GpuLease`](crate::fleet::GpuLease) per the gang policy
/// and plans/executes on that subset only — disjoint gangs run truly
/// concurrently. The lease is scoped to the job, so it releases on
/// success, on error, and on panic (the worker's `catch_unwind`
/// unwinds through it).
pub struct SessionRunner {
    core: Arc<EngineCore>,
    fleet: Option<(FleetManager, Arc<dyn GangPolicy>)>,
    batch: Option<BatchRuntime>,
    degrade: Option<DegradeState>,
}

/// Batching state owned by the runner: the config plus the live
/// join-at-barrier matchmaking registry shared by all workers.
struct BatchRuntime {
    cfg: BatchConfig,
    gates: BatchGates,
}

/// Degradation state owned by the runner: the ladder config, the
/// router capacity the pressure signal normalizes against, and the
/// cumulative activity counters the server folds into the router's
/// final stats at shutdown.
struct DegradeState {
    cfg: DegradeConfig,
    queue_capacity: usize,
    demoted: AtomicU64,
    requantized: AtomicU64,
}

impl SessionRunner {
    /// Whole-cluster sessions (PR 1 behavior — equivalent to a fleet
    /// under the `AllGpus` policy, without the ledger).
    pub fn new(core: Arc<EngineCore>) -> Self {
        SessionRunner { core, fleet: None, batch: None, degrade: None }
    }

    /// Gang-partitioned sessions: acquire a policy-chosen lease per
    /// job. The policy sees live queue depth (blocked acquirers) and
    /// the scheduler's own `simulate_latency` as its predictor.
    pub fn with_fleet(
        core: Arc<EngineCore>,
        fleet: FleetManager,
        policy: Arc<dyn GangPolicy>,
    ) -> Self {
        SessionRunner {
            core,
            fleet: Some((fleet, policy)),
            batch: None,
            degrade: None,
        }
    }

    /// Enable the graceful-degradation ladder (no-op when
    /// `cfg.enabled` is false — the default path stays bit-exact):
    /// popped jobs walk the admission demotion ladder against the live
    /// backlog, and solo sessions re-quantize their running step
    /// suffix at a sync barrier once pressure crosses the top
    /// threshold. `queue_capacity` is the router capacity the pressure
    /// signal normalizes the backlog against.
    pub fn with_degrade(
        mut self,
        cfg: &DegradeConfig,
        queue_capacity: usize,
    ) -> Self {
        if cfg.enabled {
            self.degrade = Some(DegradeState {
                cfg: cfg.clone(),
                queue_capacity: queue_capacity.max(1),
                demoted: AtomicU64::new(0),
                requantized: AtomicU64::new(0),
            });
        }
        self
    }

    /// Enable cross-request batching (no-op when `cfg.enabled` is
    /// false or `max_batch <= 1`): the serve worker gathers
    /// fuse-compatible jobs into one session, and — with a fleet —
    /// in-flight fused sessions adopt later compatible requests at
    /// their sync barriers via slot leases.
    pub fn with_batching(mut self, cfg: &BatchConfig) -> Self {
        if cfg.enabled && cfg.max_batch > 1 {
            self.batch = Some(BatchRuntime {
                cfg: cfg.clone(),
                gates: BatchGates::new(),
            });
        }
        self
    }

    fn generate(&self, job: &Job, queued: usize) -> Result<Generation> {
        let spec = &job.spec;
        match &self.fleet {
            None => self.core.generate(spec),
            Some((fleet, policy)) => {
                let core = Arc::clone(&self.core);
                let spec_for_predict = spec.clone();
                // Gangs larger than the spec's latent can feed (one
                // granule per device) are unplannable; declining them
                // up front costs an integer compare instead of a full
                // failing planner pass per oversized prefix.
                let max_gang = self.core.max_gang_for(spec)?;
                // The predictor closes over the request's spec, so the
                // policy prices *this* request's steps and rows — a
                // draft-quality request is cheap to place on a small
                // gang, a native high-quality one is not.
                let predict = move |gang: &[usize]| {
                    if gang.len() > max_gang {
                        return None;
                    }
                    core.predict_latency_for(&spec_for_predict, gang).ok()
                };
                // `queued` (jobs still in the router behind this one)
                // is the demand the policy shards the fleet for —
                // blocked co-workers alone cap at workers-1 and would
                // never push an adaptive policy past its threshold.
                let lease = fleet.acquire_for(
                    policy.as_ref(),
                    &self.core.effective_speeds(),
                    Some(&predict),
                    queued,
                    spec.priority,
                    job.deadline,
                )?;
                // Lease drops (devices return to the pool) when this
                // scope exits — normally or by unwind.
                self.core.session_for_on(spec, &lease)?.execute(spec)
            }
        }
    }

    /// Solo generation with the mid-flight degradation lever armed:
    /// identical planning/leasing to [`SessionRunner::generate`], but
    /// executed through `Session::execute_degraded_seeded`, which asks
    /// `should_requantize` at each post-warmup sync barrier and — at
    /// most once per request — halves the remaining fast-grid step
    /// suffix. The probe fires only when live queueing pressure sits
    /// past the *top* threshold, the (possibly already demoted) tier
    /// is above the configured floor, and the predicted latency does
    /// not already fit the remaining deadline budget. With mid-flight
    /// re-planning enabled the drift-adaptive loop keeps precedence
    /// and only admission demotion applies.
    fn generate_degraded(
        &self,
        job: &Job,
        queued: usize,
        live_backlog: &dyn Fn() -> usize,
    ) -> Result<Generation> {
        let Some(ds) = &self.degrade else {
            return self.generate(job, queued);
        };
        if self.core.config().replan.enabled {
            return self.generate(job, queued);
        }
        let spec = &job.spec;
        let n_dev = self.core.effective_speeds().len();
        let all: Vec<usize> = (0..n_dev).collect();
        // Full-request prediction at the current (post-shape) tier: a
        // conservative ceiling on the remaining work, so "fits the
        // budget" can only become false as the deadline burns down.
        let predicted = self.core.predict_latency_for(spec, &all).ok();
        let deadline = job.deadline;
        let at_floor = degrade::tier_rank(spec.quality)
            <= degrade::tier_rank(ds.cfg.floor);
        let thresholds = ds.cfg.pressure_thresholds.clone();
        let capacity = ds.queue_capacity;
        let mut should = move || {
            if at_floor {
                return false;
            }
            let budget = deadline.map(|d| {
                let now = Instant::now();
                if d >= now {
                    (d - now).as_secs_f64()
                } else {
                    -((now - d).as_secs_f64())
                }
            });
            if let (Some(b), Some(p)) = (budget, predicted) {
                if p * degrade::PRICE_SLACK <= b {
                    return false; // still makes the SLO untouched
                }
            }
            let pressure = degrade::pressure_signal(
                live_backlog(),
                capacity,
                predicted,
                budget,
            );
            degrade::wants_requantize(pressure, &thresholds)
        };
        let g = match &self.fleet {
            None => self
                .core
                .session_for(spec)?
                .execute_degraded_seeded(spec.seed, &mut should)?,
            Some((fleet, policy)) => {
                let core = Arc::clone(&self.core);
                let spec_for_predict = spec.clone();
                let max_gang = self.core.max_gang_for(spec)?;
                let predict = move |gang: &[usize]| {
                    if gang.len() > max_gang {
                        return None;
                    }
                    core.predict_latency_for(&spec_for_predict, gang).ok()
                };
                let lease = fleet.acquire_for(
                    policy.as_ref(),
                    &self.core.effective_speeds(),
                    Some(&predict),
                    queued,
                    spec.priority,
                    job.deadline,
                )?;
                self.core
                    .session_for_on(spec, &lease)?
                    .execute_degraded_seeded(spec.seed, &mut should)?
            }
        };
        // One `ReplanEvent` per fired re-quantization (the degraded
        // loop emits nothing else) — this is what
        // `RouterStats::requantized` counts.
        ds.requantized.fetch_add(g.replans.len() as u64, Ordering::Relaxed);
        Ok(g)
    }

    /// Found one fused session for a gathered group: a single lease
    /// (policy-priced at the group's batch size), a single plan, one
    /// independent latent trajectory per member. With a fleet and
    /// spare capacity under `max_batch`, the session opens joiner
    /// slots and a [`BatchGates`] gate so compatible requests landing
    /// mid-flight attach at the next sync barrier.
    fn generate_fused(
        &self,
        jobs: &[Job],
        key: FuseKey,
        queued: usize,
        rt: &BatchRuntime,
        record: &dyn Fn(usize),
    ) -> Result<Vec<Generation>> {
        let spec = &jobs[0].spec;
        let seeds: Vec<u64> = jobs.iter().map(|j| j.seed()).collect();
        let (fleet, policy) = match &self.fleet {
            // Whole-cluster fused session: the single implicit gang
            // leaves nothing for a joiner to attach to, so no gate.
            None => {
                let out = self
                    .core
                    .session_for(spec)?
                    .execute_fused_seeded(&seeds, None)?;
                record(out.members.len());
                return Ok(out.members);
            }
            Some((fleet, policy)) => (fleet, policy),
        };
        let core = Arc::clone(&self.core);
        let spec_for_predict = spec.clone();
        let max_gang = self.core.max_gang_for(spec)?;
        let batch = seeds.len();
        // Price the whole fused session, not one request: a batch of
        // B amortizes fixed and halo cost over B rows' worth of work,
        // which is exactly what the policy should weigh when sizing
        // the gang (`timeline::simulate_batched`).
        let predict = move |gang: &[usize]| {
            if gang.len() > max_gang {
                return None;
            }
            core.predict_latency_for_batched(&spec_for_predict, gang, batch)
                .ok()
        };
        let lease = fleet.acquire_for(
            policy.as_ref(),
            &self.core.effective_speeds(),
            Some(&predict),
            queued,
            spec.priority,
            jobs[0].deadline,
        )?;
        let session = self.core.session_for_on(spec, &lease)?;
        // Founders share the owner slot, so capping joiner slots at
        // `max_batch - founders` keeps total members <= max_batch.
        let joiner_slots = rt.cfg.max_batch.saturating_sub(seeds.len());
        let mut adopted: Vec<Offer> = Vec::new();
        let out = if joiner_slots == 0 {
            session.execute_fused_seeded(&seeds, None)
        } else {
            lease.open_slots(joiner_slots as u32 + 1);
            let gate = rt.gates.register(key, lease.devices().to_vec());
            let r = {
                let mut poll = |attach: bool| -> Vec<FusedJoiner> {
                    if !attach {
                        // Closing handshake: after `close` no offer
                        // can land, so this drain sees the complete
                        // set and nothing is silently dropped.
                        gate.close();
                    }
                    let fresh = gate.drain();
                    let joiners = fresh
                        .iter()
                        .map(|o| FusedJoiner { token: o.token, seed: o.seed })
                        .collect();
                    adopted.extend(fresh);
                    joiners
                };
                session.execute_fused_seeded(&seeds, Some(&mut poll))
            };
            // On the error path the gate may still hold undrained
            // offers; dropping it declines them (their workers fall
            // back to founding their own sessions — nothing ran).
            drop(gate);
            r
        };
        match out {
            Ok(outcome) => {
                record(outcome.members.len() + outcome.joined.len());
                let mut by_token: BTreeMap<u64, Generation> =
                    outcome.joined.into_iter().collect();
                for offer in adopted {
                    match by_token.remove(&offer.token) {
                        Some(gen) => {
                            offer.resolve(JoinReply::Done(Box::new(gen)))
                        }
                        // Defensive: an adopted offer always comes
                        // back in `joined`; decline rather than hang
                        // its worker if that invariant ever breaks.
                        None => offer.resolve(JoinReply::Declined),
                    }
                }
                Ok(outcome.members)
            }
            Err(e) => {
                // Members adopted into the failing session owe their
                // clients the error, same as the founders.
                for offer in adopted {
                    offer.resolve(JoinReply::Failed(Error::msg(format!(
                        "fused session failed: {e}"
                    ))));
                }
                record(seeds.len());
                Err(e)
            }
        }
    }
}

impl JobRunner for SessionRunner {
    fn run(&self, job: &Job) -> (bool, String) {
        self.run_with_load(job, 0)
    }

    /// Admission gate: a spec the engine cannot execute (field ranges,
    /// misaligned sizes, unregistered resolutions) is rejected at
    /// parse time — wire code `bad_spec` — instead of deep in the
    /// engine after a lease was already acquired.
    fn admit(&self, job: &Job) -> Result<()> {
        self.core.check_spec(&job.spec)
    }

    fn run_with_load(&self, job: &Job, queued: usize) -> (bool, String) {
        let t0 = Instant::now();
        match self.generate(job, queued) {
            Ok(g) => {
                let wall = t0.elapsed().as_secs_f64();
                (
                    true,
                    protocol::response_line(&job.id, &job.spec, &g, wall),
                )
            }
            Err(e) => (false, protocol::error_line(&job.id, &e)),
        }
    }

    fn fuse_key(&self, job: &Job) -> Option<FuseKey> {
        let _rt = self.batch.as_ref()?;
        self.core
            .fuse_signature(&job.spec)
            .ok()
            .map(FuseKey::from_signature)
    }

    /// Admission-time rung walk: demote the request's quality tier
    /// against the popped backlog pressure, each rung priced by the
    /// planner-backed latency predictor against the remaining deadline
    /// budget and floored at `DegradeConfig::floor`. Requests carrying
    /// an explicit step count pin their plan and are never reshaped.
    /// Runs before the job is fuse-keyed, so batching groups form on
    /// the demoted spec.
    fn shape(&self, job: &mut Job, backlog: usize) {
        let Some(ds) = &self.degrade else { return };
        if job.spec.steps.is_some() {
            return;
        }
        let budget = job.deadline_slack_s();
        let n_dev = self.core.effective_speeds().len();
        let all: Vec<usize> = (0..n_dev).collect();
        let spec = job.spec.clone();
        let core = &self.core;
        let mut predict = |q: Quality| {
            core.predict_latency_for(&spec.clone().quality(q), &all).ok()
        };
        let pressure = degrade::pressure_signal(
            backlog,
            ds.queue_capacity,
            predict(job.spec.quality),
            budget,
        );
        let demoted = degrade::admission_demotion(
            job.spec.quality,
            pressure,
            &ds.cfg,
            budget,
            &mut predict,
        );
        if demoted != job.spec.quality {
            crate::log_debug!(
                "serve",
                "degrade: {} {} -> {} (pressure {:.2})",
                job.id,
                job.spec.quality.as_str(),
                demoted.as_str(),
                pressure
            );
            job.spec.quality = demoted;
            ds.demoted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Solo jobs run with the mid-flight re-quantization lever armed
    /// (live backlog probed at sync barriers). Fused groups — and any
    /// job that could still join one — keep the plain batched path:
    /// thinning a shared lockstep schedule would degrade every member,
    /// so the mid-flight lever is solo-only by design.
    fn run_batched_live(
        &self,
        jobs: &[Job],
        backlog: usize,
        live_backlog: &dyn Fn() -> usize,
        record: &dyn Fn(usize),
    ) -> Vec<(bool, String)> {
        if jobs.len() == 1
            && self.degrade.is_some()
            && self.fuse_key(&jobs[0]).is_none()
        {
            let job = &jobs[0];
            record(1);
            let t0 = Instant::now();
            return vec![match self.generate_degraded(
                job,
                backlog,
                live_backlog,
            ) {
                Ok(g) => {
                    let wall = t0.elapsed().as_secs_f64();
                    (
                        true,
                        protocol::response_line(&job.id, &job.spec, &g, wall),
                    )
                }
                Err(e) => (false, protocol::error_line(&job.id, &e)),
            }];
        }
        self.run_batched(jobs, backlog, record)
    }

    fn degrade_counts(&self) -> (u64, u64) {
        match &self.degrade {
            Some(ds) => (
                ds.demoted.load(Ordering::Relaxed),
                ds.requantized.load(Ordering::Relaxed),
            ),
            None => (0, 0),
        }
    }

    fn run_batched(
        &self,
        jobs: &[Job],
        backlog: usize,
        record: &dyn Fn(usize),
    ) -> Vec<(bool, String)> {
        let solo_all = |jobs: &[Job]| {
            jobs.iter()
                .map(|j| {
                    record(1);
                    self.run_with_load(j, backlog)
                })
                .collect::<Vec<_>>()
        };
        let Some(rt) = &self.batch else { return solo_all(jobs) };
        // The worker gathers by key, so a mixed group means a bug or a
        // spec whose signature stopped resolving; degrade to solo runs
        // rather than fuse incompatible plans.
        let key = match self.fuse_key(&jobs[0]) {
            Some(k)
                if jobs.iter().all(|j| self.fuse_key(j) == Some(k)) =>
            {
                k
            }
            _ => return solo_all(jobs),
        };
        let t0 = Instant::now();
        if jobs.len() == 1 {
            let Some((fleet, _)) = &self.fleet else {
                // No fleet = no slot leases to join and no gang to
                // share: a lone job gains nothing from the fused path.
                return solo_all(jobs);
            };
            // A lone compatible job first offers itself to an
            // in-flight fused session (join at the next barrier)
            // instead of founding its own.
            if let Some(rx) = rt.gates.offer(key, fleet, jobs[0].seed()) {
                match rx.recv() {
                    Ok(JoinReply::Done(gen)) => {
                        let wall = t0.elapsed().as_secs_f64();
                        return vec![(
                            true,
                            protocol::response_line(
                                &jobs[0].id,
                                &jobs[0].spec,
                                &gen,
                                wall,
                            ),
                        )];
                    }
                    Ok(JoinReply::Failed(e)) => {
                        return vec![(
                            false,
                            protocol::error_line(&jobs[0].id, &e),
                        )];
                    }
                    // Declined (or the session died before adopting —
                    // a dropped sender reads the same): nothing ran,
                    // so found our own session below.
                    Ok(JoinReply::Declined) | Err(_) => {}
                }
            }
        }
        match self.generate_fused(jobs, key, backlog, rt, record) {
            Ok(gens) => {
                let wall = t0.elapsed().as_secs_f64();
                jobs.iter()
                    .zip(gens)
                    .map(|(j, g)| {
                        (
                            true,
                            protocol::response_line(&j.id, &j.spec, &g, wall),
                        )
                    })
                    .collect()
            }
            Err(e) => jobs
                .iter()
                .map(|j| (false, protocol::error_line(&j.id, &e)))
                .collect(),
        }
    }
}

/// Thin std-only `poll(2)` / `pipe(2)` wrapper. No new dependency:
/// std already links libc on unix, so declaring the four prototypes
/// we need is enough.
#[cfg(unix)]
mod sys {
    /// Mirror of C `struct pollfd` (identical layout on every unix
    /// std supports: int fd, short events, short revents).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    #[cfg(target_os = "macos")]
    type NfdsT = u32;
    #[cfg(not(target_os = "macos"))]
    type NfdsT = u64;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
        fn pipe(fds: *mut i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        fn close(fd: i32) -> i32;
    }

    /// Block until some fd is ready or `timeout_ms` elapses; returns
    /// the number of ready fds (0 on timeout; errors — in practice
    /// only EINTR — read as a timeout tick, the caller re-polls).
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> usize {
        let n = unsafe {
            poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms)
        };
        n.max(0) as usize
    }

    /// Self-pipe for waking the poll thread from worker threads.
    pub struct WakePipe {
        read_fd: i32,
        write_fd: i32,
    }

    impl WakePipe {
        pub fn new() -> std::io::Result<WakePipe> {
            let mut fds = [0i32; 2];
            if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(WakePipe { read_fd: fds[0], write_fd: fds[1] })
        }

        pub fn read_fd(&self) -> i32 {
            self.read_fd
        }

        /// One byte per wake. A full pipe means tens of thousands of
        /// wakes are already pending, so blocking briefly here (until
        /// the poll thread drains) is harmless — the wake the caller
        /// wanted is guaranteed either way.
        pub fn wake(&self) {
            let b = [1u8];
            let _ = unsafe { write(self.write_fd, b.as_ptr(), 1) };
        }

        /// Drain pending wake bytes. Call only after `poll` reported
        /// the read end readable: one read then never blocks, and any
        /// bytes beyond the buffer just re-wake the next poll.
        pub fn drain(&self) {
            let mut buf = [0u8; 4096];
            let _ = unsafe {
                read(self.read_fd, buf.as_mut_ptr(), buf.len())
            };
        }
    }

    impl Drop for WakePipe {
        fn drop(&mut self) {
            unsafe {
                close(self.read_fd);
                close(self.write_fd);
            }
        }
    }
}

/// Slot + generation pair naming one live connection in the event
/// loop's table. The generation guards completion routing: a slot
/// reused after its connection died gets a fresh generation, so a
/// late completion addressed to the dead connection is discarded
/// instead of landing on the new tenant.
#[cfg(unix)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ConnId {
    slot: usize,
    generation: u64,
}

/// Completion mailbox from the worker pool back to the poll thread:
/// push the finished line, then poke the wake pipe so a `poll` blocked
/// on idle sockets returns immediately. Shared by `Arc` with every
/// in-flight event-mode ticket; the pipe fds close only when the last
/// clone drops, so a completion can never write into a reused fd.
#[cfg(unix)]
struct CompletionQueue {
    items: Mutex<Vec<(ConnId, u64, String)>>,
    pipe: sys::WakePipe,
}

#[cfg(unix)]
impl CompletionQueue {
    fn new() -> std::io::Result<CompletionQueue> {
        Ok(CompletionQueue {
            items: Mutex::new(Vec::new()),
            pipe: sys::WakePipe::new()?,
        })
    }

    fn push(&self, conn: ConnId, seq: u64, line: String) {
        self.items.lock().unwrap().push((conn, seq, line));
        self.pipe.wake();
    }

    fn drain(&self) -> Vec<(ConnId, u64, String)> {
        std::mem::take(&mut *self.items.lock().unwrap())
    }
}

/// Where a finished ticket's response line goes: the per-connection
/// writer channel (threads mode) or the event loop's completion
/// mailbox plus the connection's table id (events mode).
enum ReplyRoute {
    Channel(mpsc::Sender<(u64, String)>),
    #[cfg(unix)]
    Event { queue: Arc<CompletionQueue>, conn: ConnId },
}

impl ReplyRoute {
    fn send(&self, seq: u64, line: String) {
        match self {
            // A channel send error means the connection (and its
            // writer) died first; the response is undeliverable
            // either way, same as an events-mode generation miss.
            ReplyRoute::Channel(tx) => {
                let _ = tx.send((seq, line));
            }
            #[cfg(unix)]
            ReplyRoute::Event { queue, conn } => {
                queue.push(*conn, seq, line);
            }
        }
    }
}

/// A job bundled with its reply route: which connection and where in
/// that connection's response order (the sequence number).
struct Ticket {
    job: Job,
    seq: u64,
    reply: ReplyRoute,
}

/// Queue position comes from the request spec: priority tier, then
/// earliest deadline, then FIFO (the router's discipline).
impl Prioritized for Ticket {
    fn priority_rank(&self) -> u8 {
        self.job.priority_rank()
    }

    fn deadline(&self) -> Option<Instant> {
        self.job.deadline()
    }
}

/// One event-loop table slot: everything the thread-per-connection
/// path kept in a reader thread's stack and a writer thread's reorder
/// map, flattened into plain state the poll thread owns.
#[cfg(unix)]
struct Conn {
    stream: TcpStream,
    generation: u64,
    /// Raw bytes read but not yet framed into lines.
    rbuf: Vec<u8>,
    /// Skipping the tail of an already-answered oversized line, up to
    /// its terminating newline.
    discarding: bool,
    /// Client half-closed its write side (EOF): no more requests will
    /// arrive; the connection stays in the table until every assigned
    /// sequence number has been answered and flushed.
    eof: bool,
    /// Next request sequence number to assign on this connection.
    next_seq: u64,
    /// Next response sequence to put on the wire (per-connection FIFO).
    next_write: u64,
    /// Out-of-order completions parked until their turn.
    pending: BTreeMap<u64, String>,
    /// Encoded response bytes the socket hasn't accepted yet.
    wbuf: VecDeque<u8>,
    /// Last instant the socket accepted response bytes (or the write
    /// queue went from empty to non-empty) — drives the stalled-writer
    /// teardown at WRITE_TIMEOUT.
    last_progress: Instant,
}

/// What one framing pass pulled out of a connection's read buffer.
#[cfg(unix)]
enum Frame {
    /// A complete request line (newline stripped), or the final
    /// unterminated line at EOF.
    Line(String),
    /// The buffer grew past MAX_LINE_BYTES with no newline: answer a
    /// typed `bad_request` now and discard to the next newline.
    Oversize,
    /// Invalid UTF-8 on the wire — the threads-mode `read_line` dies
    /// on this too (InvalidData), so drop the connection.
    BadUtf8,
    /// Nothing more to frame.
    Done,
}

/// The readiness front-end: one thread, one `poll(2)` set, a bounded
/// connection table. Replaces the reader/reorder-writer thread pair
/// per connection; the worker pool is unchanged and talks back
/// through the [`CompletionQueue`]'s wake pipe.
#[cfg(unix)]
struct EventLoop {
    listener: TcpListener,
    router: Arc<Router<Ticket>>,
    runner: Arc<dyn JobRunner>,
    queue: Arc<CompletionQueue>,
    /// Slot-indexed table; `None` slots are free. Fixed size =
    /// `max_connections`: the table never reallocates, and "table
    /// full" is exactly "listener deregistered".
    conns: Vec<Option<Conn>>,
    n_open: usize,
    next_generation: u64,
}

#[cfg(unix)]
impl EventLoop {
    fn new(
        listener: TcpListener,
        router: Arc<Router<Ticket>>,
        runner: Arc<dyn JobRunner>,
        max_connections: usize,
    ) -> std::io::Result<EventLoop> {
        let mut conns = Vec::new();
        conns.resize_with(max_connections.max(1), || None);
        Ok(EventLoop {
            listener,
            router,
            runner,
            queue: Arc::new(CompletionQueue::new()?),
            conns,
            n_open: 0,
            next_generation: 0,
        })
    }

    /// Run until `stop`/`done` is set or the listener fails. Returns
    /// the fatal accept error, if any. Connections may still be open
    /// on return; the caller drains workers and then calls
    /// [`EventLoop::shutdown_flush`].
    fn run(
        &mut self,
        stop: &Option<Arc<AtomicBool>>,
        done: &Arc<AtomicBool>,
    ) -> Option<std::io::Error> {
        loop {
            if done.load(Ordering::SeqCst) {
                return None;
            }
            if let Some(s) = stop {
                if s.load(Ordering::Relaxed) {
                    return None;
                }
            }
            self.deliver_completions();
            self.reap_stalled();

            // Build the poll set: the wake pipe always; the listener
            // only while the table has a free slot (at the cap, new
            // clients wait in the OS accept backlog and cost zero
            // CPU — no busy-wait); each connection for read and/or
            // write interest. A connection over its write high-water
            // mark loses read interest until the client drains.
            let mut fds = vec![sys::PollFd {
                fd: self.queue.pipe.read_fd(),
                events: sys::POLLIN,
                revents: 0,
            }];
            let listener_at = if self.n_open < self.conns.len() {
                fds.push(sys::PollFd {
                    fd: self.listener.as_raw_fd(),
                    events: sys::POLLIN,
                    revents: 0,
                });
                Some(fds.len() - 1)
            } else {
                None
            };
            // (poll-set index, table slot) for every registered conn.
            let mut conn_at: Vec<(usize, usize)> = Vec::new();
            for (slot, c) in self.conns.iter().enumerate() {
                let Some(c) = c else { continue };
                let mut events = 0i16;
                if !c.eof && c.wbuf.len() < WRITE_HIGH_WATER {
                    events |= sys::POLLIN;
                }
                if !c.wbuf.is_empty() {
                    events |= sys::POLLOUT;
                }
                if events == 0 {
                    // EOF'd or gated, waiting only on worker
                    // completions — the wake pipe covers that.
                    continue;
                }
                conn_at.push((fds.len(), slot));
                fds.push(sys::PollFd {
                    fd: c.stream.as_raw_fd(),
                    events,
                    revents: 0,
                });
            }

            // Bounded wait so `stop`/`done` are re-checked even when
            // no fd ever becomes ready (mirrors READ_POLL).
            if sys::poll_fds(&mut fds, READ_POLL.as_millis() as i32)
                == 0
            {
                continue;
            }
            if fds[0].revents != 0 {
                self.queue.pipe.drain();
            }
            if let Some(i) = listener_at {
                if fds[i].revents != 0 {
                    if let Some(e) = self.accept_ready() {
                        return Some(e);
                    }
                }
            }
            for (i, slot) in conn_at {
                let re = fds[i].revents;
                if re == 0 {
                    continue;
                }
                if re & (sys::POLLERR | sys::POLLNVAL) != 0 {
                    self.drop_conn(slot);
                    continue;
                }
                if re & sys::POLLOUT != 0 {
                    self.flush_conn(slot);
                    self.maybe_close(slot);
                }
                // POLLHUP without POLLIN still needs a read to
                // observe the EOF and run the half-close path.
                if re & (sys::POLLIN | sys::POLLHUP) != 0 {
                    self.read_conn(slot, done);
                }
            }
        }
    }

    /// Accept until the listener would block or the table fills.
    fn accept_ready(&mut self) -> Option<std::io::Error> {
        while self.n_open < self.conns.len() {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    crate::log_debug!("serve", "connection from {peer}");
                    let slot = self
                        .conns
                        .iter()
                        .position(|c| c.is_none())
                        .expect("n_open < len implies a free slot");
                    self.next_generation += 1;
                    self.conns[slot] = Some(Conn {
                        stream,
                        generation: self.next_generation,
                        rbuf: Vec::new(),
                        discarding: false,
                        eof: false,
                        next_seq: 0,
                        next_write: 0,
                        pending: BTreeMap::new(),
                        wbuf: VecDeque::new(),
                        last_progress: Instant::now(),
                    });
                    self.n_open += 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    return None;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Some(e),
            }
        }
        None
    }

    /// Pull whatever the socket has ready, frame it into lines, and
    /// process each. Bounded per call (fairness: one firehose client
    /// can't monopolize the poll thread — level-triggered poll
    /// reports it readable again next iteration) and gated on the
    /// write high-water mark.
    fn read_conn(&mut self, slot: usize, done: &Arc<AtomicBool>) {
        let mut buf = [0u8; 4096];
        for _ in 0..16 {
            let res = {
                let Some(c) = self.conns[slot].as_mut() else {
                    return;
                };
                if c.eof || c.wbuf.len() >= WRITE_HIGH_WATER {
                    return;
                }
                (&c.stream).read(&mut buf)
            };
            match res {
                Ok(0) => {
                    if let Some(c) = self.conns[slot].as_mut() {
                        c.eof = true;
                    }
                    // A final unterminated line still parses — the
                    // threads-mode read_line returns it too.
                    self.process_buffer(slot, true);
                    self.maybe_close(slot);
                    return;
                }
                Ok(n) => {
                    if let Some(c) = self.conns[slot].as_mut() {
                        c.rbuf.extend_from_slice(&buf[..n]);
                    }
                    self.process_buffer(slot, false);
                    if done.load(Ordering::SeqCst) {
                        return;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.drop_conn(slot);
                    return;
                }
            }
        }
    }

    /// Frame complete lines out of the read buffer and process each.
    /// `at_eof` additionally flushes a final unterminated line.
    fn process_buffer(&mut self, slot: usize, at_eof: bool) {
        loop {
            let frame = {
                let Some(c) = self.conns[slot].as_mut() else {
                    return;
                };
                match c.rbuf.iter().position(|&b| b == b'\n') {
                    Some(nl) => {
                        let rest = c.rbuf.split_off(nl + 1);
                        let mut head =
                            std::mem::replace(&mut c.rbuf, rest);
                        head.pop(); // the newline itself
                        if c.discarding {
                            // Tail of an answered oversized line.
                            c.discarding = false;
                            continue;
                        }
                        match String::from_utf8(head) {
                            Ok(s) => Frame::Line(s),
                            Err(_) => Frame::BadUtf8,
                        }
                    }
                    None if c.discarding => {
                        c.rbuf.clear();
                        Frame::Done
                    }
                    None if c.rbuf.len() > MAX_LINE_BYTES => {
                        c.discarding = true;
                        c.rbuf.clear();
                        Frame::Oversize
                    }
                    None if at_eof && !c.rbuf.is_empty() => {
                        let bytes = std::mem::take(&mut c.rbuf);
                        match String::from_utf8(bytes) {
                            Ok(s) => Frame::Line(s),
                            Err(_) => Frame::BadUtf8,
                        }
                    }
                    None => Frame::Done,
                }
            };
            match frame {
                Frame::Line(s) => self.process_line(slot, &s),
                Frame::Oversize => {
                    let seq = {
                        let Some(c) = self.conns[slot].as_mut() else {
                            return;
                        };
                        let s = c.next_seq;
                        c.next_seq += 1;
                        s
                    };
                    self.router.record_oversized();
                    let line = protocol::error_line(
                        "?",
                        &Error::Protocol(format!(
                            "request line exceeds {MAX_LINE_BYTES} \
                             bytes"
                        )),
                    );
                    self.deliver(slot, seq, line);
                }
                Frame::BadUtf8 => {
                    self.drop_conn(slot);
                    return;
                }
                Frame::Done => return,
            }
        }
    }

    /// One request line: assign a sequence number, parse on the lazy
    /// hot path, gate admission, enqueue — or answer the error
    /// immediately into the connection's reorder. Mirrors the
    /// threads-mode reader body line for line.
    fn process_line(&mut self, slot: usize, text: &str) {
        let text = text.trim();
        if text.is_empty() {
            return;
        }
        let (this_seq, generation) = {
            let Some(c) = self.conns[slot].as_mut() else { return };
            let s = c.next_seq;
            c.next_seq += 1;
            (s, c.generation)
        };
        let (parsed, lazy) = protocol::parse_lazy_tracked(text);
        self.router.record_parse(lazy);
        match parsed {
            Ok(req) => {
                // Deadlines are stamped here, at admission: queueing
                // time counts against the SLO.
                let job = Job::new(req.id.clone(), req.spec);
                // Admission gate: a job the runner cannot execute
                // (e.g. an unregistered resolution) is answered now
                // and never queues or leases GPUs.
                if let Err(e) = self.runner.admit(&job) {
                    self.router.record_inadmissible();
                    let line = protocol::error_line(&job.id, &e);
                    self.deliver(slot, this_seq, line);
                } else {
                    let ticket = Ticket {
                        job,
                        seq: this_seq,
                        reply: ReplyRoute::Event {
                            queue: Arc::clone(&self.queue),
                            conn: ConnId { slot, generation },
                        },
                    };
                    if let Err(e) = self.router.submit(ticket) {
                        let line = protocol::error_line(&req.id, &e);
                        self.deliver(slot, this_seq, line);
                    }
                }
            }
            Err(e) => {
                let line = protocol::error_line("?", &e);
                self.deliver(slot, this_seq, line);
            }
        }
    }

    /// Route every queued completion into its connection's reorder
    /// buffer, discarding ones whose connection died first (stale
    /// generation) — the events-mode analogue of a send to a dropped
    /// channel receiver.
    fn deliver_completions(&mut self) {
        for (conn, seq, line) in self.queue.drain() {
            let live = matches!(
                self.conns.get(conn.slot).and_then(|c| c.as_ref()),
                Some(c) if c.generation == conn.generation
            );
            if live {
                self.deliver(conn.slot, seq, line);
            }
        }
    }

    /// Park `line` at `seq` in the connection's reorder buffer, move
    /// every now-in-order response onto the write queue, then try the
    /// socket immediately — most responses go out without waiting for
    /// the next POLLOUT.
    fn deliver(&mut self, slot: usize, seq: u64, line: String) {
        {
            let Some(c) = self.conns[slot].as_mut() else { return };
            c.pending.insert(seq, line);
            while let Some(l) = c.pending.remove(&c.next_write) {
                if c.wbuf.is_empty() {
                    // Start the stall clock when the queue becomes
                    // non-empty, not when bytes were last accepted
                    // possibly long ago.
                    c.last_progress = Instant::now();
                }
                c.wbuf.extend(l.as_bytes());
                c.wbuf.push_back(b'\n');
                c.next_write += 1;
            }
        }
        self.flush_conn(slot);
        self.maybe_close(slot);
    }

    /// Write as much of the queue as the socket accepts right now.
    fn flush_conn(&mut self, slot: usize) {
        let dead = {
            let Some(c) = self.conns[slot].as_mut() else { return };
            let mut dead = false;
            while !c.wbuf.is_empty() {
                let (head, _) = c.wbuf.as_slices();
                match (&c.stream).write(head) {
                    Ok(0) => {
                        dead = true;
                        break;
                    }
                    Ok(n) => {
                        c.wbuf.drain(..n);
                        c.last_progress = Instant::now();
                    }
                    Err(e)
                        if e.kind() == ErrorKind::WouldBlock =>
                    {
                        break
                    }
                    Err(e)
                        if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
            dead
        };
        if dead {
            self.drop_conn(slot);
        }
    }

    /// Drop a half-closed connection once every assigned sequence has
    /// been answered and flushed (EOF alone doesn't close it: the
    /// client is still owed its responses).
    fn maybe_close(&mut self, slot: usize) {
        let close = matches!(
            self.conns[slot].as_ref(),
            Some(c) if c.eof
                && c.wbuf.is_empty()
                && c.pending.is_empty()
                && c.next_write == c.next_seq
        );
        if close {
            self.drop_conn(slot);
        }
    }

    /// Tear down connections whose socket has accepted nothing for
    /// WRITE_TIMEOUT while responses are queued (client stopped
    /// reading) — the table's analogue of the threads-mode write
    /// timeout, so one non-reading client can't pin its slot forever.
    fn reap_stalled(&mut self) {
        for slot in 0..self.conns.len() {
            let stalled = matches!(
                self.conns[slot].as_ref(),
                Some(c) if !c.wbuf.is_empty()
                    && c.last_progress.elapsed() >= WRITE_TIMEOUT
            );
            if stalled {
                self.drop_conn(slot);
            }
        }
    }

    fn drop_conn(&mut self, slot: usize) {
        if self.conns[slot].take().is_some() {
            self.n_open -= 1;
        }
    }

    /// Final drain after the workers have joined: route the last
    /// completions (including `close_and_answer`'s shutdown lines),
    /// then flush every connection's write queue, polling for
    /// writability, bounded by WRITE_TIMEOUT — the events-mode
    /// analogue of joining the per-connection writer threads.
    fn shutdown_flush(&mut self) {
        let deadline = Instant::now() + WRITE_TIMEOUT;
        loop {
            self.deliver_completions();
            let waiting: Vec<(usize, i32)> = self
                .conns
                .iter()
                .enumerate()
                .filter_map(|(slot, c)| {
                    let c = c.as_ref()?;
                    if c.wbuf.is_empty() {
                        None
                    } else {
                        Some((slot, c.stream.as_raw_fd()))
                    }
                })
                .collect();
            if waiting.is_empty() {
                // Every deliverable byte is out: each assigned seq
                // was answered by a worker, an immediate error, or
                // close_and_answer before this runs, so an empty
                // write queue means nothing is still owed.
                return;
            }
            if Instant::now() >= deadline {
                return;
            }
            let mut fds: Vec<sys::PollFd> = waiting
                .iter()
                .map(|&(_, fd)| sys::PollFd {
                    fd,
                    events: sys::POLLOUT,
                    revents: 0,
                })
                .collect();
            sys::poll_fds(&mut fds, 100);
            for (i, &(slot, _)) in waiting.iter().enumerate() {
                if fds[i].revents != 0 {
                    self.flush_conn(slot);
                }
            }
        }
    }
}

/// Serve with real sessions on the shared core. Returns total requests
/// executed. See [`serve_with`] for the machinery.
pub fn serve(
    core: Arc<EngineCore>,
    listener: TcpListener,
    opts: ServeOptions,
    stop: Option<Arc<AtomicBool>>,
) -> Result<u64> {
    let runner = Arc::new(
        SessionRunner::new(core)
            .with_batching(&opts.batch)
            .with_degrade(&opts.degrade, opts.queue_capacity),
    );
    serve_with(runner, listener, opts, stop)
}

/// Serve with fleet partitioning: every job leases a policy-chosen
/// GPU gang and plans/executes on it alone, so the worker pool runs
/// disjoint gangs concurrently instead of contending for the whole
/// cluster. `workers` should be at least the number of gangs the
/// policy can carve out, or the extra parallelism goes unused.
pub fn serve_fleet(
    core: Arc<EngineCore>,
    policy: Arc<dyn GangPolicy>,
    listener: TcpListener,
    opts: ServeOptions,
    stop: Option<Arc<AtomicBool>>,
) -> Result<u64> {
    let fleet = core.fleet();
    crate::log_info!(
        "serve",
        "fleet partitioning on: {} devices, policy {}",
        fleet.num_devices(),
        policy.name()
    );
    let runner = Arc::new(
        SessionRunner::with_fleet(core, fleet, policy)
            .with_batching(&opts.batch)
            .with_degrade(&opts.degrade, opts.queue_capacity),
    );
    serve_with(runner, listener, opts, stop)
}

/// Runs each job through a [`FrontTier`]: shard-policy routing,
/// spill-over admission across sibling nodes, and (when the tier
/// enables it) barrier-checkpoint migration off saturated nodes.
pub struct FederatedRunner {
    tier: Arc<FrontTier>,
}

impl FederatedRunner {
    pub fn new(tier: Arc<FrontTier>) -> Self {
        FederatedRunner { tier }
    }
}

impl JobRunner for FederatedRunner {
    fn run(&self, job: &Job) -> (bool, String) {
        self.run_with_load(job, 0)
    }

    /// Nodes are homogeneous (one config builds them all), so any
    /// node's engine validates a spec for the whole tier.
    fn admit(&self, job: &Job) -> Result<()> {
        self.tier.node(0).core().check_spec(&job.spec)
    }

    fn run_with_load(&self, job: &Job, queued: usize) -> (bool, String) {
        let t0 = Instant::now();
        match self.tier.serve_one(&job.spec, queued) {
            Ok(g) => {
                let wall = t0.elapsed().as_secs_f64();
                (
                    true,
                    protocol::response_line(&job.id, &job.spec, &g, wall),
                )
            }
            Err(e) => (false, protocol::error_line(&job.id, &e)),
        }
    }
}

/// Serve across a federated tier: every request routes to a home node
/// by the tier's shard policy, spills to the best-ranked sibling when
/// the home answers busy, and — with migration on — may finish on an
/// idle sibling after a mid-plan barrier handoff.
pub fn serve_federated(
    tier: Arc<FrontTier>,
    listener: TcpListener,
    opts: ServeOptions,
    stop: Option<Arc<AtomicBool>>,
) -> Result<u64> {
    crate::log_info!(
        "serve",
        "federation on: {} nodes, policy {}, migrate {}",
        tier.num_nodes(),
        tier.policy_name(),
        tier.migrate_enabled()
    );
    serve_with(Arc::new(FederatedRunner::new(tier)), listener, opts, stop)
}

/// Serve until `stop` is set, `max_requests` is reached, or forever.
///
/// The listener is switched to nonblocking and polled, so a set `stop`
/// flag interrupts the accept loop even if no connection ever arrives
/// (the old blocking accept only noticed the flag on the *next*
/// connection). Shutdown drains in-flight jobs, discards queued ones,
/// and joins every thread before returning.
pub fn serve_with(
    runner: Arc<dyn JobRunner>,
    listener: TcpListener,
    opts: ServeOptions,
    stop: Option<Arc<AtomicBool>>,
) -> Result<u64> {
    serve_with_stats(runner, listener, opts, stop).map(|(n, _)| n)
}

/// [`serve_with`], additionally returning the router's final stats
/// snapshot (admission/outcome counters, latency percentiles) so
/// harnesses can assert on served traffic, not just the count.
pub fn serve_with_stats(
    runner: Arc<dyn JobRunner>,
    listener: TcpListener,
    opts: ServeOptions,
    stop: Option<Arc<AtomicBool>>,
) -> Result<(u64, RouterStats)> {
    let n_workers = opts.workers.max(1);
    let router: Arc<Router<Ticket>> =
        Arc::new(Router::new(opts.queue_capacity));
    // Internal shutdown latch: set by the accept loop (stop flag) or by
    // the worker that executes the final counted request.
    let done = Arc::new(AtomicBool::new(false));
    let handled = Arc::new(AtomicU64::new(0));
    listener.set_nonblocking(true)?;
    crate::log_info!(
        "serve",
        "listening on {} ({} workers, queue {}, io {})",
        listener.local_addr()?,
        n_workers,
        router.capacity(),
        opts.io.as_str()
    );

    // Choose the connection front-end before spawning workers so a
    // failed event-loop setup (pipe exhaustion) errors out cleanly
    // with nothing to join. Non-unix targets have no poll(2) wrapper
    // and always take the threads path.
    enum FrontEnd {
        #[cfg(unix)]
        Events(Box<EventLoop>),
        Threads(TcpListener),
    }
    let mut front = match opts.io {
        #[cfg(unix)]
        IoMode::Events => FrontEnd::Events(Box::new(EventLoop::new(
            listener,
            Arc::clone(&router),
            Arc::clone(&runner),
            opts.max_connections.max(1),
        )?)),
        _ => FrontEnd::Threads(listener),
    };

    let workers: Vec<_> = (0..n_workers)
        .map(|_| {
            let router = Arc::clone(&router);
            let runner = Arc::clone(&runner);
            let done = Arc::clone(&done);
            let handled = Arc::clone(&handled);
            let max = opts.max_requests as u64;
            let batch_cfg = opts.batch.clone();
            thread::spawn(move || {
                // Count one delivered response toward `max_requests`
                // and trip shutdown at the low-water mark.
                let count_handled = |n_new: u64| {
                    let n = handled.fetch_add(n_new, Ordering::SeqCst)
                        + n_new;
                    if max > 0 && n >= max {
                        done.store(true, Ordering::SeqCst);
                        close_and_answer(&router);
                    }
                };
                while let Some(popped) = router.pop() {
                    let t0 = Instant::now();
                    // Deadline shed: the router hands expired jobs
                    // back instead of running them — answer with the
                    // typed `deadline` code and count a failure.
                    let mut leader = match popped {
                        Dequeued::Ready(t) => t,
                        Dequeued::Expired(t) => {
                            answer_expired(&router, &t);
                            count_handled(1);
                            continue;
                        }
                    };
                    // Admission-time degradation: a pressure-aware
                    // runner may demote the request's quality tier
                    // here, before the job is fuse-keyed or executed
                    // (the default hook is a no-op).
                    runner.shape(&mut leader.job, router.backlog());
                    // Batching: park the leader through a bounded
                    // admission window and gather fuse-compatible
                    // companions off the queue. Parked requests left
                    // `queue_len` but still count in `backlog`, so
                    // gang policies keep seeing the waiting demand.
                    let mut group = vec![leader];
                    if batch_cfg.enabled && batch_cfg.max_batch > 1 {
                        if let Some(key) = runner.fuse_key(&group[0].job)
                        {
                            router.park(1);
                            let until = Instant::now()
                                + Duration::from_millis(
                                    batch_cfg.window_ms,
                                );
                            while group.len() < batch_cfg.max_batch {
                                let m = router.pop_match_timeout(
                                    |c: &Ticket| {
                                        runner.fuse_key(&c.job)
                                            == Some(key)
                                    },
                                    until,
                                );
                                match m {
                                    Some(Dequeued::Ready(c)) => {
                                        router.park(1);
                                        group.push(c);
                                    }
                                    Some(Dequeued::Expired(c)) => {
                                        answer_expired(&router, &c);
                                        count_handled(1);
                                    }
                                    None => break,
                                }
                            }
                            router.unpark(group.len());
                        }
                    }
                    // A panicking runner must not shrink the pool (with
                    // one worker it would wedge the whole server) nor
                    // leave a sequence gap in any reply stream.
                    let jobs: Vec<Job> =
                        group.iter().map(|c| c.job.clone()).collect();
                    let backlog = router.backlog();
                    let live_backlog = || router.backlog();
                    let results = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            runner.run_batched_live(
                                &jobs,
                                backlog,
                                &live_backlog,
                                &|size| {
                                    if size > 0 {
                                        router.record_batch(size);
                                    }
                                },
                            )
                        }),
                    )
                    .unwrap_or_else(|_| {
                        jobs.iter()
                            .map(|j| {
                                (
                                    false,
                                    protocol::error_line(
                                        &j.id,
                                        &Error::msg(
                                            "internal error: job panicked",
                                        ),
                                    ),
                                )
                            })
                            .collect()
                    });
                    for (i, c) in group.into_iter().enumerate() {
                        // Defensive: a runner returning the wrong
                        // arity still answers every client.
                        let (ok, line) =
                            results.get(i).cloned().unwrap_or_else(|| {
                                (
                                    false,
                                    protocol::error_line(
                                        &c.job.id,
                                        &Error::msg(
                                            "internal error: missing \
                                             batch result",
                                        ),
                                    ),
                                )
                            });
                        router
                            .record_outcome(ok, t0.elapsed().as_secs_f64());
                        // Deliver before counting so the final client
                        // gets its response before shutdown begins.
                        c.reply.send(c.seq, line);
                        count_handled(1);
                    }
                }
            })
        })
        .collect();

    let mut conns = Vec::new();
    let mut accept_err = None;
    match &mut front {
        #[cfg(unix)]
        FrontEnd::Events(el) => {
            accept_err = el.run(&stop, &done);
        }
        FrontEnd::Threads(listener) => loop {
            if done.load(Ordering::SeqCst) {
                break;
            }
            if let Some(s) = &stop {
                if s.load(Ordering::Relaxed) {
                    break;
                }
            }
            // Reap finished connection handlers every iteration (not
            // just when idle — under sustained connection churn the
            // accept call below may never report WouldBlock) so a
            // long-lived server doesn't hold one JoinHandle per
            // connection ever accepted.
            conns.retain(|c| !c.is_finished());
            // At the connection cap, let new connections queue in the
            // OS accept backlog instead of spawning unbounded thread
            // pairs.
            if conns.len() >= opts.max_connections.max(1) {
                thread::sleep(ACCEPT_POLL);
                continue;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let router = Arc::clone(&router);
                    let done = Arc::clone(&done);
                    let runner = Arc::clone(&runner);
                    conns.push(thread::spawn(move || {
                        handle_connection(
                            stream, &router, &done, &runner,
                        );
                    }));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    thread::sleep(ACCEPT_POLL);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    accept_err = Some(e);
                    break;
                }
            }
        },
    }

    // Shutdown: wake workers (in-flight jobs drain; queued ones are
    // answered with shutdown errors), unblock connection readers, join
    // everything. Events mode then flushes the table's write queues —
    // the in-flight and shutdown answers workers routed through the
    // completion mailbox after the poll loop exited.
    done.store(true, Ordering::SeqCst);
    let dropped = close_and_answer(&router);
    if dropped > 0 {
        crate::log_info!("serve", "shutdown dropped {dropped} queued jobs");
    }
    for w in workers {
        let _ = w.join();
    }
    #[cfg(unix)]
    if let FrontEnd::Events(el) = &mut front {
        el.shutdown_flush();
    }
    for c in conns {
        let _ = c.join();
    }
    // Fold the runner's cumulative degradation activity into the final
    // snapshot (counters live on the runner so the ladder needs no
    // router handle).
    let (demoted, requantized) = runner.degrade_counts();
    if demoted > 0 || requantized > 0 {
        router.record_degrade(demoted, requantized);
    }
    let s = router.stats();
    // latency_summary already carries n/mean/p50/p95/max; the same
    // figures are available structured on the returned RouterStats.
    crate::log_info!(
        "serve",
        "done: admitted={} rejected={} inadmissible={} completed={} \
         failed={} batched={} solo={} fused_sessions={} \
         mean_fused={:.2} demoted={} requantized={} ({})",
        s.admitted,
        s.rejected,
        s.inadmissible,
        s.completed,
        s.failed,
        s.batched,
        s.solo,
        s.fused_sessions,
        s.mean_fused,
        s.demoted,
        s.requantized,
        s.latency_summary
    );
    match accept_err {
        Some(e) => Err(e.into()),
        None => Ok((handled.load(Ordering::SeqCst), s)),
    }
}

/// Answer a ticket that expired while queued with the typed
/// `deadline` wire code and record the failure (workers call this for
/// expired leaders and for expired would-be batch companions alike).
fn answer_expired(router: &Router<Ticket>, t: &Ticket) {
    let late = t
        .job
        .deadline_slack_s()
        .map(|s| (-s).max(0.0))
        .unwrap_or(0.0);
    let line = protocol::error_line(
        &t.job.id,
        &Error::DeadlineExceeded {
            deadline_s: t.job.spec.deadline_s.unwrap_or(0.0),
            late_by_s: late,
        },
    );
    router.record_outcome(false, 0.0);
    t.reply.send(t.seq, line);
}

/// Close the router and answer every still-queued ticket with a
/// shutdown error line, so (a) its client isn't left waiting on a
/// response that will never come and (b) the writer's per-connection
/// FIFO reorder isn't blocked forever on the dropped sequence number.
fn close_and_answer(router: &Router<Ticket>) -> usize {
    let dropped = router.drain_close();
    let n = dropped.len();
    for t in dropped {
        // Count the outcome so admitted always reconciles against
        // completed + failed in the final stats line.
        router.record_outcome(false, 0.0);
        t.reply.send(
            t.seq,
            protocol::error_line(&t.job.id, &Error::Shutdown),
        );
    }
    n
}

/// Reader half of one connection: parse lines, assign each a sequence
/// number, validate admission with the runner, enqueue (or answer
/// immediately on parse error / inadmissible spec / busy). Spawns the
/// writer half that restores per-connection FIFO order.
fn handle_connection(
    stream: TcpStream,
    router: &Router<Ticket>,
    done: &AtomicBool,
    runner: &Arc<dyn JobRunner>,
) {
    let peer = stream
        .peer_addr()
        .map(|p| p.to_string())
        .unwrap_or_else(|_| "?".into());
    crate::log_debug!("serve", "connection from {peer}");
    // BSD-derived platforms (macOS) make accepted sockets inherit the
    // listener's O_NONBLOCK; we want blocking-with-timeout semantics,
    // so reset explicitly (no-op on Linux).
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    // Timeouts make the reader re-check `done` so server shutdown is
    // never blocked on an idle client holding its connection open.
    if stream.set_read_timeout(Some(READ_POLL)).is_err() {
        return;
    }
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // A write that blocks past this (client not reading) errors out and
    // tears the connection down instead of hanging shutdown's join.
    if writer_stream.set_write_timeout(Some(WRITE_TIMEOUT)).is_err() {
        return;
    }
    let (tx, rx) = mpsc::channel::<(u64, String)>();
    let writer = thread::spawn(move || write_in_order(writer_stream, rx));

    let mut reader = BufReader::new(stream);
    let mut seq = 0u64;
    let mut line = String::new();
    loop {
        // Checked between lines too (not just on read timeouts) so a
        // client that keeps sending can't stall server shutdown. A dead
        // writer (client stopped reading; write timed out) also ends
        // the reader — otherwise a misbehaving client could keep
        // workers computing responses nobody will ever receive.
        if done.load(Ordering::SeqCst) || writer.is_finished() {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client closed.
            Ok(_) => {
                let text = line.trim();
                if !text.is_empty() {
                    let this_seq = seq;
                    seq += 1;
                    match WireRequest::parse(text) {
                        Ok(req) => {
                            // Deadlines are stamped here, at admission:
                            // queueing time counts against the SLO.
                            let job = Job::new(req.id.clone(), req.spec);
                            // Admission gate: a job the runner cannot
                            // execute (e.g. an unregistered
                            // resolution) is answered now and never
                            // queues or leases GPUs.
                            if let Err(e) = runner.admit(&job) {
                                router.record_inadmissible();
                                let _ = tx.send((
                                    this_seq,
                                    protocol::error_line(&job.id, &e),
                                ));
                            } else {
                                let ticket = Ticket {
                                    job,
                                    seq: this_seq,
                                    reply: ReplyRoute::Channel(
                                        tx.clone(),
                                    ),
                                };
                                if let Err(e) = router.submit(ticket) {
                                    let _ = tx.send((
                                        this_seq,
                                        protocol::error_line(&req.id, &e),
                                    ));
                                }
                            }
                        }
                        Err(e) => {
                            let _ = tx.send((
                                this_seq,
                                protocol::error_line("?", &e),
                            ));
                        }
                    }
                }
                line.clear();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut
                ) =>
            {
                // Read timeout. A partially-read line stays in `line`
                // (read_line appends) and completes on a later call.
                if done.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    crate::log_debug!("serve", "connection from {peer} closing");
    // Dropping our sender lets the writer drain in-flight responses
    // and exit once every outstanding ticket's clone is gone too.
    drop(tx);
    let _ = writer.join();
}

/// Writer half of one connection: responses arrive tagged with their
/// per-connection sequence number in completion order; buffer
/// out-of-order ones and write strictly in submission order.
fn write_in_order(
    mut stream: TcpStream,
    rx: mpsc::Receiver<(u64, String)>,
) {
    let mut next = 0u64;
    let mut pending: BTreeMap<u64, String> = BTreeMap::new();
    while let Ok((seq, line)) = rx.recv() {
        pending.insert(seq, line);
        while let Some(l) = pending.remove(&next) {
            // Errors include the WRITE_TIMEOUT expiring on a client
            // that stopped reading; either way the connection is dead.
            if writeln!(stream, "{l}").is_err() {
                return; // client gone; nothing left to deliver
            }
            next += 1;
        }
    }
    // Channel closed with gaps: defensive only — every current path
    // sends exactly one line per assigned seq (success, catch_unwind'd
    // runner panic, busy/parse rejection, and shutdown drain via
    // `close_and_answer`). Should a future path drop a ticket without
    // responding, the remaining out-of-order responses are
    // undeliverable in FIFO order and die with the connection.
}

/// Simple blocking client for tests/examples.
pub struct Client {
    writer: TcpStream,
    // One persistent reader: a fresh BufReader per request could
    // swallow bytes already buffered from a previous read and then
    // block forever on the next.
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    /// Send one v1 request (`{"id","seed"}` — the backcompat shape),
    /// read one response line.
    pub fn request(&mut self, id: &str, seed: u64) -> Result<String> {
        self.send(id, seed)?;
        self.read_line()
    }

    /// Send one v2 request with a full spec, read one response line.
    pub fn request_spec(
        &mut self,
        id: &str,
        spec: &GenerationSpec,
    ) -> Result<String> {
        self.send_spec(id, spec)?;
        self.read_line()
    }

    /// Send one v1 request without waiting for the response
    /// (pipelining; pair with [`Client::read_line`]).
    pub fn send(&mut self, id: &str, seed: u64) -> Result<()> {
        let req = WireRequest {
            id: id.into(),
            spec: GenerationSpec::new().seed(seed),
        };
        writeln!(self.writer, "{}", req.to_line_v1())?;
        Ok(())
    }

    /// Send one v2 request without waiting for the response.
    pub fn send_spec(
        &mut self,
        id: &str,
        spec: &GenerationSpec,
    ) -> Result<()> {
        let req = WireRequest { id: id.into(), spec: spec.clone() };
        writeln!(self.writer, "{}", req.to_line())?;
        Ok(())
    }

    /// Read the next response line.
    pub fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }
}

/// Client-side view of one [`drive_workload`] run.
#[derive(Debug, Clone)]
pub struct WorkloadStats {
    pub wall_s: f64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
}

impl WorkloadStats {
    pub fn throughput_rps(&self, requests: usize) -> f64 {
        if self.wall_s > 0.0 {
            requests as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Drive `clients` concurrent connections with `per_client` sequential
/// requests each (seeds counting up from `seed0`) — the shared load
/// harness for benches and examples. Returns wall time plus the
/// mean/p50/p95 of per-request latencies across every client; fails if
/// any response is not `ok`.
pub fn drive_workload(
    addr: &str,
    clients: usize,
    per_client: usize,
    seed0: u64,
) -> Result<WorkloadStats> {
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..clients {
        let addr = addr.to_string();
        threads.push(thread::spawn(move || -> Result<Vec<f64>> {
            let mut client = Client::connect(&addr)?;
            let mut latencies = Vec::with_capacity(per_client);
            for i in 0..per_client {
                let t = Instant::now();
                let line = client.request(
                    &format!("c{c}-r{i}"),
                    seed0 + (c * per_client + i) as u64,
                )?;
                latencies.push(t.elapsed().as_secs_f64());
                let v = json::parse(&line)?;
                if !v.get("ok")?.as_bool()? {
                    return Err(Error::Protocol(format!(
                        "request c{c}-r{i} failed: {line}"
                    )));
                }
            }
            Ok(latencies)
        }));
    }
    let mut all = Vec::new();
    for t in threads {
        all.extend(
            t.join()
                .map_err(|_| Error::msg("client thread panicked"))??,
        );
    }
    Ok(WorkloadStats {
        wall_s: t0.elapsed().as_secs_f64(),
        mean_latency_s: stats::mean(&all),
        p50_latency_s: stats::percentile(&all, 50.0),
        p95_latency_s: stats::percentile(&all, 95.0),
    })
}

#[cfg(test)]
mod tests {
    // End-to-end server tests live in rust/tests/integration_serve.rs:
    // the queueing/ordering/shutdown machinery runs there against a
    // stub JobRunner (no artifacts needed), real-generation paths
    // against built artifacts.
}
