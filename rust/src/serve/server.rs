//! TCP JSON-lines server: the deployable front-end.
//!
//! `stadi serve --addr 127.0.0.1:7878` accepts connections, reads one
//! request per line, routes through the bounded `Router`, executes on
//! the engine, and writes one response line per request. Connections
//! are handled sequentially per the single-request-at-a-time engine
//! model (the cluster cooperates on each image); concurrency control
//! is the router's bounded queue.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::Engine;
use crate::error::Result;
use crate::serve::protocol::{self, WireRequest};
use crate::serve::router::{Job, Router};

/// Serve until `stop` is set (or forever). Returns total requests
/// handled. `max_requests` caps the run for tests/examples (0 = no
/// cap).
pub fn serve(
    engine: &mut Engine,
    listener: TcpListener,
    queue_capacity: usize,
    max_requests: usize,
    stop: Option<Arc<AtomicBool>>,
) -> Result<u64> {
    let mut router = Router::new(queue_capacity);
    let mut handled = 0u64;
    crate::log_info!(
        "serve",
        "listening on {}",
        listener.local_addr()?
    );
    for conn in listener.incoming() {
        if let Some(s) = &stop {
            if s.load(Ordering::Relaxed) {
                break;
            }
        }
        let stream = conn?;
        handled += handle_connection(engine, &mut router, stream)?;
        if max_requests > 0 && handled >= max_requests as u64 {
            break;
        }
    }
    let s = router.stats();
    crate::log_info!(
        "serve",
        "done: admitted={} rejected={} completed={} failed={} ({})",
        s.admitted,
        s.rejected,
        s.completed,
        s.failed,
        s.latency_summary
    );
    Ok(handled)
}

fn handle_connection(
    engine: &mut Engine,
    router: &mut Router,
    stream: TcpStream,
) -> Result<u64> {
    let peer = stream.peer_addr()?;
    crate::log_debug!("serve", "connection from {peer}");
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let mut handled = 0u64;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match WireRequest::parse(&line) {
            Ok(r) => r,
            Err(e) => {
                writeln!(writer, "{}", protocol::error_line("?", &e))?;
                continue;
            }
        };
        if let Err(e) =
            router.submit(Job { id: req.id.clone(), seed: req.seed })
        {
            writeln!(writer, "{}", protocol::error_line(&req.id, &e))?;
            continue;
        }
        // Single-flight engine: serve immediately.
        while let Some((job, result)) = router.serve_next(engine) {
            let response = match result {
                Ok((generation, wall)) => {
                    protocol::response_line(&job.id, &generation, wall)
                }
                Err(e) => protocol::error_line(&job.id, &e),
            };
            writeln!(writer, "{response}")?;
            handled += 1;
        }
    }
    Ok(handled)
}

/// Simple blocking client for tests/examples.
pub struct Client {
    writer: TcpStream,
    // One persistent reader: a fresh BufReader per request could
    // swallow bytes already buffered from a previous read and then
    // block forever on the next.
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    /// Send one request, read one response line.
    pub fn request(&mut self, id: &str, seed: u64) -> Result<String> {
        let req = WireRequest { id: id.into(), seed };
        writeln!(self.writer, "{}", req.to_line())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }
}

#[cfg(test)]
mod tests {
    // End-to-end server tests live in rust/tests/integration_serve.rs
    // (they need built artifacts + a real engine).
}
