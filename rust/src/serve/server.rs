//! TCP JSON-lines server: the deployable front-end, truly concurrent.
//!
//! `stadi serve --addr 127.0.0.1:7878 --workers 4` runs three kinds of
//! threads around the thread-safe bounded priority [`Router`]
//! (priority desc, earliest deadline, FIFO; expired requests shed on
//! dequeue with the typed `deadline` wire code):
//!
//! * the **accept loop** (caller's thread) — nonblocking listener
//!   polled every few ms so a set `stop` flag interrupts it even when
//!   no connection ever arrives;
//! * one **connection handler** per client — a reader that parses one
//!   request per line and enqueues it (busy rejections answered
//!   immediately with the structured `busy` code), plus a writer that
//!   reorders responses by per-connection sequence number so every
//!   client sees answers in the order it sent requests (FIFO fairness
//!   per connection) no matter which worker finished first;
//! * a **worker pool** draining the queue into per-request
//!   [`Session`](crate::coordinator::Session)s on the shared
//!   [`EngineCore`] — N in-flight requests overlap their sampler /
//!   halo / serialization work around the single PJRT service thread.
//!
//! Execution is abstracted behind [`JobRunner`] so the serving
//! machinery is testable without artifacts (integration tests drive it
//! with a stub runner; production uses [`SessionRunner`]).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use crate::config::{BatchConfig, DegradeConfig};
use crate::coordinator::{EngineCore, FusedJoiner, Generation};
use crate::error::{Error, Result};
use crate::federation::FrontTier;
use crate::fleet::{FleetManager, GangPolicy};
use crate::serve::batch::{BatchGates, FuseKey, JoinReply, Offer};
use crate::serve::degrade;
use crate::serve::protocol::{self, WireRequest};
use crate::serve::router::{Dequeued, Job, Prioritized, Router, RouterStats};
use crate::spec::{GenerationSpec, Quality};
use crate::util::{json, stats};

/// How often blocked accept/read calls re-check shutdown flags.
const ACCEPT_POLL: Duration = Duration::from_millis(10);
const READ_POLL: Duration = Duration::from_millis(100);
/// Cap on how long a response write may block: a client that stops
/// reading (full TCP send buffer) must not wedge its writer thread —
/// and with it `serve`'s final join — indefinitely.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Serving knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Router queue capacity (admission control).
    pub queue_capacity: usize,
    /// Worker threads draining the queue — the number of requests in
    /// flight concurrently.
    pub workers: usize,
    /// Stop after this many executed requests (0 = no cap). With more
    /// than one worker this is a low-water mark, not an exact count:
    /// jobs already in flight on other workers when the Nth completes
    /// still drain (their clients are owed responses) and are counted.
    pub max_requests: usize,
    /// Cap on simultaneously-open client connections (each costs a
    /// reader + writer thread). At the cap the accept loop pauses, so
    /// further connections wait in the OS accept backlog — the job
    /// queue bounds work, this bounds threads.
    pub max_connections: usize,
    /// Cross-request batching (fused denoise sessions). Disabled by
    /// default: the solo path is pinned byte-identical to pre-batching
    /// behavior.
    pub batch: BatchConfig,
    /// Graceful degradation under overload (pressure-driven quality
    /// demotion + mid-flight suffix re-quantization). Disabled by
    /// default: the serve path is pinned bit-exact to pre-degrade
    /// behavior.
    pub degrade: DegradeConfig,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            queue_capacity: 64,
            workers: 2,
            max_requests: 0,
            max_connections: 256,
            batch: BatchConfig::default(),
            degrade: DegradeConfig::default(),
        }
    }
}

/// Executes one job into one wire response line. Implemented by
/// [`SessionRunner`] for real generation; tests substitute stubs so
/// the queueing/ordering/shutdown machinery runs without artifacts.
pub trait JobRunner: Send + Sync + 'static {
    /// Returns `(ok, response line)`; `ok` feeds the router's
    /// per-outcome stats.
    fn run(&self, job: &Job) -> (bool, String);

    /// Like [`JobRunner::run`], with the number of jobs still queued
    /// behind this one — the live demand signal load-adaptive runners
    /// (gang policies) act on. Workers call this; the default ignores
    /// the load, so plain runners only implement `run`.
    fn run_with_load(&self, job: &Job, queued: usize) -> (bool, String) {
        let _ = queued;
        self.run(job)
    }

    /// Admission-time validation, called by the connection reader when
    /// a request parses, *before* it enters the router. An `Err` is
    /// answered immediately with the error's wire code and the job
    /// never queues, never reaches a worker, and never acquires a
    /// fleet lease — this is where inexecutable resolutions are shed
    /// with `bad_spec`. The default admits everything (stub runners,
    /// plain harnesses).
    fn admit(&self, job: &Job) -> Result<()> {
        let _ = job;
        Ok(())
    }

    /// Batch-compatibility key for a job: jobs with equal keys may
    /// fuse into one session. `None` (the default) = this job never
    /// fuses, so the worker skips the admission window entirely.
    fn fuse_key(&self, job: &Job) -> Option<FuseKey> {
        let _ = job;
        None
    }

    /// Run a gathered group of fuse-compatible jobs, ideally as one
    /// fused session; returns one `(ok, line)` per job, in order.
    /// `record` feeds the router's occupancy histogram: call it once
    /// per dispatched session with the total member count (including
    /// barrier joiners); a job adopted into *another* session must not
    /// be recorded here (its founder counts it). The default runs each
    /// job solo (stub runners, batching off).
    fn run_batched(
        &self,
        jobs: &[Job],
        backlog: usize,
        record: &dyn Fn(usize),
    ) -> Vec<(bool, String)> {
        jobs.iter()
            .map(|j| {
                record(1);
                self.run_with_load(j, backlog)
            })
            .collect()
    }

    /// Admission-time shaping hook, called by the worker on a freshly
    /// popped job *before* it is fuse-keyed or executed. A
    /// pressure-aware runner may rewrite the spec here (quality-tier
    /// demotion under backlog); the default leaves it untouched.
    fn shape(&self, job: &mut Job, backlog: usize) {
        let _ = (job, backlog);
    }

    /// [`JobRunner::run_batched`] with a *live* backlog probe in
    /// addition to the dispatch-time snapshot, so a degradation-aware
    /// runner can re-read queueing pressure at mid-flight sync
    /// barriers. The default ignores the probe — behavior identical to
    /// `run_batched` — so plain runners never see it.
    fn run_batched_live(
        &self,
        jobs: &[Job],
        backlog: usize,
        live_backlog: &dyn Fn() -> usize,
        record: &dyn Fn(usize),
    ) -> Vec<(bool, String)> {
        let _ = live_backlog;
        self.run_batched(jobs, backlog, record)
    }

    /// Cumulative graceful-degradation counters
    /// `(demoted, requantized)` the server folds into the router's
    /// final stats snapshot at shutdown. The default reports none.
    fn degrade_counts(&self) -> (u64, u64) {
        (0, 0)
    }
}

/// Production runner: one fresh [`Session`](crate::coordinator::Session)
/// per job on the shared core. With a fleet configured, each job first
/// acquires a [`GpuLease`](crate::fleet::GpuLease) per the gang policy
/// and plans/executes on that subset only — disjoint gangs run truly
/// concurrently. The lease is scoped to the job, so it releases on
/// success, on error, and on panic (the worker's `catch_unwind`
/// unwinds through it).
pub struct SessionRunner {
    core: Arc<EngineCore>,
    fleet: Option<(FleetManager, Arc<dyn GangPolicy>)>,
    batch: Option<BatchRuntime>,
    degrade: Option<DegradeState>,
}

/// Batching state owned by the runner: the config plus the live
/// join-at-barrier matchmaking registry shared by all workers.
struct BatchRuntime {
    cfg: BatchConfig,
    gates: BatchGates,
}

/// Degradation state owned by the runner: the ladder config, the
/// router capacity the pressure signal normalizes against, and the
/// cumulative activity counters the server folds into the router's
/// final stats at shutdown.
struct DegradeState {
    cfg: DegradeConfig,
    queue_capacity: usize,
    demoted: AtomicU64,
    requantized: AtomicU64,
}

impl SessionRunner {
    /// Whole-cluster sessions (PR 1 behavior — equivalent to a fleet
    /// under the `AllGpus` policy, without the ledger).
    pub fn new(core: Arc<EngineCore>) -> Self {
        SessionRunner { core, fleet: None, batch: None, degrade: None }
    }

    /// Gang-partitioned sessions: acquire a policy-chosen lease per
    /// job. The policy sees live queue depth (blocked acquirers) and
    /// the scheduler's own `simulate_latency` as its predictor.
    pub fn with_fleet(
        core: Arc<EngineCore>,
        fleet: FleetManager,
        policy: Arc<dyn GangPolicy>,
    ) -> Self {
        SessionRunner {
            core,
            fleet: Some((fleet, policy)),
            batch: None,
            degrade: None,
        }
    }

    /// Enable the graceful-degradation ladder (no-op when
    /// `cfg.enabled` is false — the default path stays bit-exact):
    /// popped jobs walk the admission demotion ladder against the live
    /// backlog, and solo sessions re-quantize their running step
    /// suffix at a sync barrier once pressure crosses the top
    /// threshold. `queue_capacity` is the router capacity the pressure
    /// signal normalizes the backlog against.
    pub fn with_degrade(
        mut self,
        cfg: &DegradeConfig,
        queue_capacity: usize,
    ) -> Self {
        if cfg.enabled {
            self.degrade = Some(DegradeState {
                cfg: cfg.clone(),
                queue_capacity: queue_capacity.max(1),
                demoted: AtomicU64::new(0),
                requantized: AtomicU64::new(0),
            });
        }
        self
    }

    /// Enable cross-request batching (no-op when `cfg.enabled` is
    /// false or `max_batch <= 1`): the serve worker gathers
    /// fuse-compatible jobs into one session, and — with a fleet —
    /// in-flight fused sessions adopt later compatible requests at
    /// their sync barriers via slot leases.
    pub fn with_batching(mut self, cfg: &BatchConfig) -> Self {
        if cfg.enabled && cfg.max_batch > 1 {
            self.batch = Some(BatchRuntime {
                cfg: cfg.clone(),
                gates: BatchGates::new(),
            });
        }
        self
    }

    fn generate(&self, job: &Job, queued: usize) -> Result<Generation> {
        let spec = &job.spec;
        match &self.fleet {
            None => self.core.generate(spec),
            Some((fleet, policy)) => {
                let core = Arc::clone(&self.core);
                let spec_for_predict = spec.clone();
                // Gangs larger than the spec's latent can feed (one
                // granule per device) are unplannable; declining them
                // up front costs an integer compare instead of a full
                // failing planner pass per oversized prefix.
                let max_gang = self.core.max_gang_for(spec)?;
                // The predictor closes over the request's spec, so the
                // policy prices *this* request's steps and rows — a
                // draft-quality request is cheap to place on a small
                // gang, a native high-quality one is not.
                let predict = move |gang: &[usize]| {
                    if gang.len() > max_gang {
                        return None;
                    }
                    core.predict_latency_for(&spec_for_predict, gang).ok()
                };
                // `queued` (jobs still in the router behind this one)
                // is the demand the policy shards the fleet for —
                // blocked co-workers alone cap at workers-1 and would
                // never push an adaptive policy past its threshold.
                let lease = fleet.acquire_for(
                    policy.as_ref(),
                    &self.core.effective_speeds(),
                    Some(&predict),
                    queued,
                    spec.priority,
                    job.deadline,
                )?;
                // Lease drops (devices return to the pool) when this
                // scope exits — normally or by unwind.
                self.core.session_for_on(spec, &lease)?.execute(spec)
            }
        }
    }

    /// Solo generation with the mid-flight degradation lever armed:
    /// identical planning/leasing to [`SessionRunner::generate`], but
    /// executed through `Session::execute_degraded_seeded`, which asks
    /// `should_requantize` at each post-warmup sync barrier and — at
    /// most once per request — halves the remaining fast-grid step
    /// suffix. The probe fires only when live queueing pressure sits
    /// past the *top* threshold, the (possibly already demoted) tier
    /// is above the configured floor, and the predicted latency does
    /// not already fit the remaining deadline budget. With mid-flight
    /// re-planning enabled the drift-adaptive loop keeps precedence
    /// and only admission demotion applies.
    fn generate_degraded(
        &self,
        job: &Job,
        queued: usize,
        live_backlog: &dyn Fn() -> usize,
    ) -> Result<Generation> {
        let Some(ds) = &self.degrade else {
            return self.generate(job, queued);
        };
        if self.core.config().replan.enabled {
            return self.generate(job, queued);
        }
        let spec = &job.spec;
        let n_dev = self.core.effective_speeds().len();
        let all: Vec<usize> = (0..n_dev).collect();
        // Full-request prediction at the current (post-shape) tier: a
        // conservative ceiling on the remaining work, so "fits the
        // budget" can only become false as the deadline burns down.
        let predicted = self.core.predict_latency_for(spec, &all).ok();
        let deadline = job.deadline;
        let at_floor = degrade::tier_rank(spec.quality)
            <= degrade::tier_rank(ds.cfg.floor);
        let thresholds = ds.cfg.pressure_thresholds.clone();
        let capacity = ds.queue_capacity;
        let mut should = move || {
            if at_floor {
                return false;
            }
            let budget = deadline.map(|d| {
                let now = Instant::now();
                if d >= now {
                    (d - now).as_secs_f64()
                } else {
                    -((now - d).as_secs_f64())
                }
            });
            if let (Some(b), Some(p)) = (budget, predicted) {
                if p * degrade::PRICE_SLACK <= b {
                    return false; // still makes the SLO untouched
                }
            }
            let pressure = degrade::pressure_signal(
                live_backlog(),
                capacity,
                predicted,
                budget,
            );
            degrade::wants_requantize(pressure, &thresholds)
        };
        let g = match &self.fleet {
            None => self
                .core
                .session_for(spec)?
                .execute_degraded_seeded(spec.seed, &mut should)?,
            Some((fleet, policy)) => {
                let core = Arc::clone(&self.core);
                let spec_for_predict = spec.clone();
                let max_gang = self.core.max_gang_for(spec)?;
                let predict = move |gang: &[usize]| {
                    if gang.len() > max_gang {
                        return None;
                    }
                    core.predict_latency_for(&spec_for_predict, gang).ok()
                };
                let lease = fleet.acquire_for(
                    policy.as_ref(),
                    &self.core.effective_speeds(),
                    Some(&predict),
                    queued,
                    spec.priority,
                    job.deadline,
                )?;
                self.core
                    .session_for_on(spec, &lease)?
                    .execute_degraded_seeded(spec.seed, &mut should)?
            }
        };
        // One `ReplanEvent` per fired re-quantization (the degraded
        // loop emits nothing else) — this is what
        // `RouterStats::requantized` counts.
        ds.requantized.fetch_add(g.replans.len() as u64, Ordering::Relaxed);
        Ok(g)
    }

    /// Found one fused session for a gathered group: a single lease
    /// (policy-priced at the group's batch size), a single plan, one
    /// independent latent trajectory per member. With a fleet and
    /// spare capacity under `max_batch`, the session opens joiner
    /// slots and a [`BatchGates`] gate so compatible requests landing
    /// mid-flight attach at the next sync barrier.
    fn generate_fused(
        &self,
        jobs: &[Job],
        key: FuseKey,
        queued: usize,
        rt: &BatchRuntime,
        record: &dyn Fn(usize),
    ) -> Result<Vec<Generation>> {
        let spec = &jobs[0].spec;
        let seeds: Vec<u64> = jobs.iter().map(|j| j.seed()).collect();
        let (fleet, policy) = match &self.fleet {
            // Whole-cluster fused session: the single implicit gang
            // leaves nothing for a joiner to attach to, so no gate.
            None => {
                let out = self
                    .core
                    .session_for(spec)?
                    .execute_fused_seeded(&seeds, None)?;
                record(out.members.len());
                return Ok(out.members);
            }
            Some((fleet, policy)) => (fleet, policy),
        };
        let core = Arc::clone(&self.core);
        let spec_for_predict = spec.clone();
        let max_gang = self.core.max_gang_for(spec)?;
        let batch = seeds.len();
        // Price the whole fused session, not one request: a batch of
        // B amortizes fixed and halo cost over B rows' worth of work,
        // which is exactly what the policy should weigh when sizing
        // the gang (`timeline::simulate_batched`).
        let predict = move |gang: &[usize]| {
            if gang.len() > max_gang {
                return None;
            }
            core.predict_latency_for_batched(&spec_for_predict, gang, batch)
                .ok()
        };
        let lease = fleet.acquire_for(
            policy.as_ref(),
            &self.core.effective_speeds(),
            Some(&predict),
            queued,
            spec.priority,
            jobs[0].deadline,
        )?;
        let session = self.core.session_for_on(spec, &lease)?;
        // Founders share the owner slot, so capping joiner slots at
        // `max_batch - founders` keeps total members <= max_batch.
        let joiner_slots = rt.cfg.max_batch.saturating_sub(seeds.len());
        let mut adopted: Vec<Offer> = Vec::new();
        let out = if joiner_slots == 0 {
            session.execute_fused_seeded(&seeds, None)
        } else {
            lease.open_slots(joiner_slots as u32 + 1);
            let gate = rt.gates.register(key, lease.devices().to_vec());
            let r = {
                let mut poll = |attach: bool| -> Vec<FusedJoiner> {
                    if !attach {
                        // Closing handshake: after `close` no offer
                        // can land, so this drain sees the complete
                        // set and nothing is silently dropped.
                        gate.close();
                    }
                    let fresh = gate.drain();
                    let joiners = fresh
                        .iter()
                        .map(|o| FusedJoiner { token: o.token, seed: o.seed })
                        .collect();
                    adopted.extend(fresh);
                    joiners
                };
                session.execute_fused_seeded(&seeds, Some(&mut poll))
            };
            // On the error path the gate may still hold undrained
            // offers; dropping it declines them (their workers fall
            // back to founding their own sessions — nothing ran).
            drop(gate);
            r
        };
        match out {
            Ok(outcome) => {
                record(outcome.members.len() + outcome.joined.len());
                let mut by_token: BTreeMap<u64, Generation> =
                    outcome.joined.into_iter().collect();
                for offer in adopted {
                    match by_token.remove(&offer.token) {
                        Some(gen) => {
                            offer.resolve(JoinReply::Done(Box::new(gen)))
                        }
                        // Defensive: an adopted offer always comes
                        // back in `joined`; decline rather than hang
                        // its worker if that invariant ever breaks.
                        None => offer.resolve(JoinReply::Declined),
                    }
                }
                Ok(outcome.members)
            }
            Err(e) => {
                // Members adopted into the failing session owe their
                // clients the error, same as the founders.
                for offer in adopted {
                    offer.resolve(JoinReply::Failed(Error::msg(format!(
                        "fused session failed: {e}"
                    ))));
                }
                record(seeds.len());
                Err(e)
            }
        }
    }
}

impl JobRunner for SessionRunner {
    fn run(&self, job: &Job) -> (bool, String) {
        self.run_with_load(job, 0)
    }

    /// Admission gate: a spec the engine cannot execute (field ranges,
    /// misaligned sizes, unregistered resolutions) is rejected at
    /// parse time — wire code `bad_spec` — instead of deep in the
    /// engine after a lease was already acquired.
    fn admit(&self, job: &Job) -> Result<()> {
        self.core.check_spec(&job.spec)
    }

    fn run_with_load(&self, job: &Job, queued: usize) -> (bool, String) {
        let t0 = Instant::now();
        match self.generate(job, queued) {
            Ok(g) => {
                let wall = t0.elapsed().as_secs_f64();
                (
                    true,
                    protocol::response_line(&job.id, &job.spec, &g, wall),
                )
            }
            Err(e) => (false, protocol::error_line(&job.id, &e)),
        }
    }

    fn fuse_key(&self, job: &Job) -> Option<FuseKey> {
        let _rt = self.batch.as_ref()?;
        self.core
            .fuse_signature(&job.spec)
            .ok()
            .map(FuseKey::from_signature)
    }

    /// Admission-time rung walk: demote the request's quality tier
    /// against the popped backlog pressure, each rung priced by the
    /// planner-backed latency predictor against the remaining deadline
    /// budget and floored at `DegradeConfig::floor`. Requests carrying
    /// an explicit step count pin their plan and are never reshaped.
    /// Runs before the job is fuse-keyed, so batching groups form on
    /// the demoted spec.
    fn shape(&self, job: &mut Job, backlog: usize) {
        let Some(ds) = &self.degrade else { return };
        if job.spec.steps.is_some() {
            return;
        }
        let budget = job.deadline_slack_s();
        let n_dev = self.core.effective_speeds().len();
        let all: Vec<usize> = (0..n_dev).collect();
        let spec = job.spec.clone();
        let core = &self.core;
        let mut predict = |q: Quality| {
            core.predict_latency_for(&spec.clone().quality(q), &all).ok()
        };
        let pressure = degrade::pressure_signal(
            backlog,
            ds.queue_capacity,
            predict(job.spec.quality),
            budget,
        );
        let demoted = degrade::admission_demotion(
            job.spec.quality,
            pressure,
            &ds.cfg,
            budget,
            &mut predict,
        );
        if demoted != job.spec.quality {
            crate::log_debug!(
                "serve",
                "degrade: {} {} -> {} (pressure {:.2})",
                job.id,
                job.spec.quality.as_str(),
                demoted.as_str(),
                pressure
            );
            job.spec.quality = demoted;
            ds.demoted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Solo jobs run with the mid-flight re-quantization lever armed
    /// (live backlog probed at sync barriers). Fused groups — and any
    /// job that could still join one — keep the plain batched path:
    /// thinning a shared lockstep schedule would degrade every member,
    /// so the mid-flight lever is solo-only by design.
    fn run_batched_live(
        &self,
        jobs: &[Job],
        backlog: usize,
        live_backlog: &dyn Fn() -> usize,
        record: &dyn Fn(usize),
    ) -> Vec<(bool, String)> {
        if jobs.len() == 1
            && self.degrade.is_some()
            && self.fuse_key(&jobs[0]).is_none()
        {
            let job = &jobs[0];
            record(1);
            let t0 = Instant::now();
            return vec![match self.generate_degraded(
                job,
                backlog,
                live_backlog,
            ) {
                Ok(g) => {
                    let wall = t0.elapsed().as_secs_f64();
                    (
                        true,
                        protocol::response_line(&job.id, &job.spec, &g, wall),
                    )
                }
                Err(e) => (false, protocol::error_line(&job.id, &e)),
            }];
        }
        self.run_batched(jobs, backlog, record)
    }

    fn degrade_counts(&self) -> (u64, u64) {
        match &self.degrade {
            Some(ds) => (
                ds.demoted.load(Ordering::Relaxed),
                ds.requantized.load(Ordering::Relaxed),
            ),
            None => (0, 0),
        }
    }

    fn run_batched(
        &self,
        jobs: &[Job],
        backlog: usize,
        record: &dyn Fn(usize),
    ) -> Vec<(bool, String)> {
        let solo_all = |jobs: &[Job]| {
            jobs.iter()
                .map(|j| {
                    record(1);
                    self.run_with_load(j, backlog)
                })
                .collect::<Vec<_>>()
        };
        let Some(rt) = &self.batch else { return solo_all(jobs) };
        // The worker gathers by key, so a mixed group means a bug or a
        // spec whose signature stopped resolving; degrade to solo runs
        // rather than fuse incompatible plans.
        let key = match self.fuse_key(&jobs[0]) {
            Some(k)
                if jobs.iter().all(|j| self.fuse_key(j) == Some(k)) =>
            {
                k
            }
            _ => return solo_all(jobs),
        };
        let t0 = Instant::now();
        if jobs.len() == 1 {
            let Some((fleet, _)) = &self.fleet else {
                // No fleet = no slot leases to join and no gang to
                // share: a lone job gains nothing from the fused path.
                return solo_all(jobs);
            };
            // A lone compatible job first offers itself to an
            // in-flight fused session (join at the next barrier)
            // instead of founding its own.
            if let Some(rx) = rt.gates.offer(key, fleet, jobs[0].seed()) {
                match rx.recv() {
                    Ok(JoinReply::Done(gen)) => {
                        let wall = t0.elapsed().as_secs_f64();
                        return vec![(
                            true,
                            protocol::response_line(
                                &jobs[0].id,
                                &jobs[0].spec,
                                &gen,
                                wall,
                            ),
                        )];
                    }
                    Ok(JoinReply::Failed(e)) => {
                        return vec![(
                            false,
                            protocol::error_line(&jobs[0].id, &e),
                        )];
                    }
                    // Declined (or the session died before adopting —
                    // a dropped sender reads the same): nothing ran,
                    // so found our own session below.
                    Ok(JoinReply::Declined) | Err(_) => {}
                }
            }
        }
        match self.generate_fused(jobs, key, backlog, rt, record) {
            Ok(gens) => {
                let wall = t0.elapsed().as_secs_f64();
                jobs.iter()
                    .zip(gens)
                    .map(|(j, g)| {
                        (
                            true,
                            protocol::response_line(&j.id, &j.spec, &g, wall),
                        )
                    })
                    .collect()
            }
            Err(e) => jobs
                .iter()
                .map(|j| (false, protocol::error_line(&j.id, &e)))
                .collect(),
        }
    }
}

/// A job bundled with its reply route: which connection (the channel)
/// and where in that connection's response order (the sequence number).
struct Ticket {
    job: Job,
    seq: u64,
    reply: mpsc::Sender<(u64, String)>,
}

/// Queue position comes from the request spec: priority tier, then
/// earliest deadline, then FIFO (the router's discipline).
impl Prioritized for Ticket {
    fn priority_rank(&self) -> u8 {
        self.job.priority_rank()
    }

    fn deadline(&self) -> Option<Instant> {
        self.job.deadline()
    }
}

/// Serve with real sessions on the shared core. Returns total requests
/// executed. See [`serve_with`] for the machinery.
pub fn serve(
    core: Arc<EngineCore>,
    listener: TcpListener,
    opts: ServeOptions,
    stop: Option<Arc<AtomicBool>>,
) -> Result<u64> {
    let runner = Arc::new(
        SessionRunner::new(core)
            .with_batching(&opts.batch)
            .with_degrade(&opts.degrade, opts.queue_capacity),
    );
    serve_with(runner, listener, opts, stop)
}

/// Serve with fleet partitioning: every job leases a policy-chosen
/// GPU gang and plans/executes on it alone, so the worker pool runs
/// disjoint gangs concurrently instead of contending for the whole
/// cluster. `workers` should be at least the number of gangs the
/// policy can carve out, or the extra parallelism goes unused.
pub fn serve_fleet(
    core: Arc<EngineCore>,
    policy: Arc<dyn GangPolicy>,
    listener: TcpListener,
    opts: ServeOptions,
    stop: Option<Arc<AtomicBool>>,
) -> Result<u64> {
    let fleet = core.fleet();
    crate::log_info!(
        "serve",
        "fleet partitioning on: {} devices, policy {}",
        fleet.num_devices(),
        policy.name()
    );
    let runner = Arc::new(
        SessionRunner::with_fleet(core, fleet, policy)
            .with_batching(&opts.batch)
            .with_degrade(&opts.degrade, opts.queue_capacity),
    );
    serve_with(runner, listener, opts, stop)
}

/// Runs each job through a [`FrontTier`]: shard-policy routing,
/// spill-over admission across sibling nodes, and (when the tier
/// enables it) barrier-checkpoint migration off saturated nodes.
pub struct FederatedRunner {
    tier: Arc<FrontTier>,
}

impl FederatedRunner {
    pub fn new(tier: Arc<FrontTier>) -> Self {
        FederatedRunner { tier }
    }
}

impl JobRunner for FederatedRunner {
    fn run(&self, job: &Job) -> (bool, String) {
        self.run_with_load(job, 0)
    }

    /// Nodes are homogeneous (one config builds them all), so any
    /// node's engine validates a spec for the whole tier.
    fn admit(&self, job: &Job) -> Result<()> {
        self.tier.node(0).core().check_spec(&job.spec)
    }

    fn run_with_load(&self, job: &Job, queued: usize) -> (bool, String) {
        let t0 = Instant::now();
        match self.tier.serve_one(&job.spec, queued) {
            Ok(g) => {
                let wall = t0.elapsed().as_secs_f64();
                (
                    true,
                    protocol::response_line(&job.id, &job.spec, &g, wall),
                )
            }
            Err(e) => (false, protocol::error_line(&job.id, &e)),
        }
    }
}

/// Serve across a federated tier: every request routes to a home node
/// by the tier's shard policy, spills to the best-ranked sibling when
/// the home answers busy, and — with migration on — may finish on an
/// idle sibling after a mid-plan barrier handoff.
pub fn serve_federated(
    tier: Arc<FrontTier>,
    listener: TcpListener,
    opts: ServeOptions,
    stop: Option<Arc<AtomicBool>>,
) -> Result<u64> {
    crate::log_info!(
        "serve",
        "federation on: {} nodes, policy {}, migrate {}",
        tier.num_nodes(),
        tier.policy_name(),
        tier.migrate_enabled()
    );
    serve_with(Arc::new(FederatedRunner::new(tier)), listener, opts, stop)
}

/// Serve until `stop` is set, `max_requests` is reached, or forever.
///
/// The listener is switched to nonblocking and polled, so a set `stop`
/// flag interrupts the accept loop even if no connection ever arrives
/// (the old blocking accept only noticed the flag on the *next*
/// connection). Shutdown drains in-flight jobs, discards queued ones,
/// and joins every thread before returning.
pub fn serve_with(
    runner: Arc<dyn JobRunner>,
    listener: TcpListener,
    opts: ServeOptions,
    stop: Option<Arc<AtomicBool>>,
) -> Result<u64> {
    serve_with_stats(runner, listener, opts, stop).map(|(n, _)| n)
}

/// [`serve_with`], additionally returning the router's final stats
/// snapshot (admission/outcome counters, latency percentiles) so
/// harnesses can assert on served traffic, not just the count.
pub fn serve_with_stats(
    runner: Arc<dyn JobRunner>,
    listener: TcpListener,
    opts: ServeOptions,
    stop: Option<Arc<AtomicBool>>,
) -> Result<(u64, RouterStats)> {
    let n_workers = opts.workers.max(1);
    let router: Arc<Router<Ticket>> =
        Arc::new(Router::new(opts.queue_capacity));
    // Internal shutdown latch: set by the accept loop (stop flag) or by
    // the worker that executes the final counted request.
    let done = Arc::new(AtomicBool::new(false));
    let handled = Arc::new(AtomicU64::new(0));
    listener.set_nonblocking(true)?;
    crate::log_info!(
        "serve",
        "listening on {} ({} workers, queue {})",
        listener.local_addr()?,
        n_workers,
        router.capacity()
    );

    let workers: Vec<_> = (0..n_workers)
        .map(|_| {
            let router = Arc::clone(&router);
            let runner = Arc::clone(&runner);
            let done = Arc::clone(&done);
            let handled = Arc::clone(&handled);
            let max = opts.max_requests as u64;
            let batch_cfg = opts.batch.clone();
            thread::spawn(move || {
                // Count one delivered response toward `max_requests`
                // and trip shutdown at the low-water mark.
                let count_handled = |n_new: u64| {
                    let n = handled.fetch_add(n_new, Ordering::SeqCst)
                        + n_new;
                    if max > 0 && n >= max {
                        done.store(true, Ordering::SeqCst);
                        close_and_answer(&router);
                    }
                };
                while let Some(popped) = router.pop() {
                    let t0 = Instant::now();
                    // Deadline shed: the router hands expired jobs
                    // back instead of running them — answer with the
                    // typed `deadline` code and count a failure.
                    let mut leader = match popped {
                        Dequeued::Ready(t) => t,
                        Dequeued::Expired(t) => {
                            answer_expired(&router, &t);
                            count_handled(1);
                            continue;
                        }
                    };
                    // Admission-time degradation: a pressure-aware
                    // runner may demote the request's quality tier
                    // here, before the job is fuse-keyed or executed
                    // (the default hook is a no-op).
                    runner.shape(&mut leader.job, router.backlog());
                    // Batching: park the leader through a bounded
                    // admission window and gather fuse-compatible
                    // companions off the queue. Parked requests left
                    // `queue_len` but still count in `backlog`, so
                    // gang policies keep seeing the waiting demand.
                    let mut group = vec![leader];
                    if batch_cfg.enabled && batch_cfg.max_batch > 1 {
                        if let Some(key) = runner.fuse_key(&group[0].job)
                        {
                            router.park(1);
                            let until = Instant::now()
                                + Duration::from_millis(
                                    batch_cfg.window_ms,
                                );
                            while group.len() < batch_cfg.max_batch {
                                let m = router.pop_match_timeout(
                                    |c: &Ticket| {
                                        runner.fuse_key(&c.job)
                                            == Some(key)
                                    },
                                    until,
                                );
                                match m {
                                    Some(Dequeued::Ready(c)) => {
                                        router.park(1);
                                        group.push(c);
                                    }
                                    Some(Dequeued::Expired(c)) => {
                                        answer_expired(&router, &c);
                                        count_handled(1);
                                    }
                                    None => break,
                                }
                            }
                            router.unpark(group.len());
                        }
                    }
                    // A panicking runner must not shrink the pool (with
                    // one worker it would wedge the whole server) nor
                    // leave a sequence gap in any reply stream.
                    let jobs: Vec<Job> =
                        group.iter().map(|c| c.job.clone()).collect();
                    let backlog = router.backlog();
                    let live_backlog = || router.backlog();
                    let results = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            runner.run_batched_live(
                                &jobs,
                                backlog,
                                &live_backlog,
                                &|size| {
                                    if size > 0 {
                                        router.record_batch(size);
                                    }
                                },
                            )
                        }),
                    )
                    .unwrap_or_else(|_| {
                        jobs.iter()
                            .map(|j| {
                                (
                                    false,
                                    protocol::error_line(
                                        &j.id,
                                        &Error::msg(
                                            "internal error: job panicked",
                                        ),
                                    ),
                                )
                            })
                            .collect()
                    });
                    for (i, c) in group.into_iter().enumerate() {
                        // Defensive: a runner returning the wrong
                        // arity still answers every client.
                        let (ok, line) =
                            results.get(i).cloned().unwrap_or_else(|| {
                                (
                                    false,
                                    protocol::error_line(
                                        &c.job.id,
                                        &Error::msg(
                                            "internal error: missing \
                                             batch result",
                                        ),
                                    ),
                                )
                            });
                        router
                            .record_outcome(ok, t0.elapsed().as_secs_f64());
                        // Deliver before counting so the final client
                        // gets its response before shutdown begins.
                        let _ = c.reply.send((c.seq, line));
                        count_handled(1);
                    }
                }
            })
        })
        .collect();

    let mut conns = Vec::new();
    let mut accept_err = None;
    loop {
        if done.load(Ordering::SeqCst) {
            break;
        }
        if let Some(s) = &stop {
            if s.load(Ordering::Relaxed) {
                break;
            }
        }
        // Reap finished connection handlers every iteration (not just
        // when idle — under sustained connection churn the accept call
        // below may never report WouldBlock) so a long-lived server
        // doesn't hold one JoinHandle per connection ever accepted.
        conns.retain(|c| !c.is_finished());
        // At the connection cap, let new connections queue in the OS
        // accept backlog instead of spawning unbounded thread pairs.
        if conns.len() >= opts.max_connections.max(1) {
            thread::sleep(ACCEPT_POLL);
            continue;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let router = Arc::clone(&router);
                let done = Arc::clone(&done);
                let runner = Arc::clone(&runner);
                conns.push(thread::spawn(move || {
                    handle_connection(stream, &router, &done, &runner);
                }));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(ACCEPT_POLL);
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => {
                accept_err = Some(e);
                break;
            }
        }
    }

    // Shutdown: wake workers (in-flight jobs drain; queued ones are
    // answered with shutdown errors), unblock connection readers, join
    // everything.
    done.store(true, Ordering::SeqCst);
    let dropped = close_and_answer(&router);
    if dropped > 0 {
        crate::log_info!("serve", "shutdown dropped {dropped} queued jobs");
    }
    for w in workers {
        let _ = w.join();
    }
    for c in conns {
        let _ = c.join();
    }
    // Fold the runner's cumulative degradation activity into the final
    // snapshot (counters live on the runner so the ladder needs no
    // router handle).
    let (demoted, requantized) = runner.degrade_counts();
    if demoted > 0 || requantized > 0 {
        router.record_degrade(demoted, requantized);
    }
    let s = router.stats();
    // latency_summary already carries n/mean/p50/p95/max; the same
    // figures are available structured on the returned RouterStats.
    crate::log_info!(
        "serve",
        "done: admitted={} rejected={} inadmissible={} completed={} \
         failed={} batched={} solo={} fused_sessions={} \
         mean_fused={:.2} demoted={} requantized={} ({})",
        s.admitted,
        s.rejected,
        s.inadmissible,
        s.completed,
        s.failed,
        s.batched,
        s.solo,
        s.fused_sessions,
        s.mean_fused,
        s.demoted,
        s.requantized,
        s.latency_summary
    );
    match accept_err {
        Some(e) => Err(e.into()),
        None => Ok((handled.load(Ordering::SeqCst), s)),
    }
}

/// Answer a ticket that expired while queued with the typed
/// `deadline` wire code and record the failure (workers call this for
/// expired leaders and for expired would-be batch companions alike).
fn answer_expired(router: &Router<Ticket>, t: &Ticket) {
    let late = t
        .job
        .deadline_slack_s()
        .map(|s| (-s).max(0.0))
        .unwrap_or(0.0);
    let line = protocol::error_line(
        &t.job.id,
        &Error::DeadlineExceeded {
            deadline_s: t.job.spec.deadline_s.unwrap_or(0.0),
            late_by_s: late,
        },
    );
    router.record_outcome(false, 0.0);
    let _ = t.reply.send((t.seq, line));
}

/// Close the router and answer every still-queued ticket with a
/// shutdown error line, so (a) its client isn't left waiting on a
/// response that will never come and (b) the writer's per-connection
/// FIFO reorder isn't blocked forever on the dropped sequence number.
fn close_and_answer(router: &Router<Ticket>) -> usize {
    let dropped = router.drain_close();
    let n = dropped.len();
    for t in dropped {
        // Count the outcome so admitted always reconciles against
        // completed + failed in the final stats line.
        router.record_outcome(false, 0.0);
        let _ = t.reply.send((
            t.seq,
            protocol::error_line(&t.job.id, &Error::Shutdown),
        ));
    }
    n
}

/// Reader half of one connection: parse lines, assign each a sequence
/// number, validate admission with the runner, enqueue (or answer
/// immediately on parse error / inadmissible spec / busy). Spawns the
/// writer half that restores per-connection FIFO order.
fn handle_connection(
    stream: TcpStream,
    router: &Router<Ticket>,
    done: &AtomicBool,
    runner: &Arc<dyn JobRunner>,
) {
    let peer = stream
        .peer_addr()
        .map(|p| p.to_string())
        .unwrap_or_else(|_| "?".into());
    crate::log_debug!("serve", "connection from {peer}");
    // BSD-derived platforms (macOS) make accepted sockets inherit the
    // listener's O_NONBLOCK; we want blocking-with-timeout semantics,
    // so reset explicitly (no-op on Linux).
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    // Timeouts make the reader re-check `done` so server shutdown is
    // never blocked on an idle client holding its connection open.
    if stream.set_read_timeout(Some(READ_POLL)).is_err() {
        return;
    }
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // A write that blocks past this (client not reading) errors out and
    // tears the connection down instead of hanging shutdown's join.
    if writer_stream.set_write_timeout(Some(WRITE_TIMEOUT)).is_err() {
        return;
    }
    let (tx, rx) = mpsc::channel::<(u64, String)>();
    let writer = thread::spawn(move || write_in_order(writer_stream, rx));

    let mut reader = BufReader::new(stream);
    let mut seq = 0u64;
    let mut line = String::new();
    loop {
        // Checked between lines too (not just on read timeouts) so a
        // client that keeps sending can't stall server shutdown. A dead
        // writer (client stopped reading; write timed out) also ends
        // the reader — otherwise a misbehaving client could keep
        // workers computing responses nobody will ever receive.
        if done.load(Ordering::SeqCst) || writer.is_finished() {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client closed.
            Ok(_) => {
                let text = line.trim();
                if !text.is_empty() {
                    let this_seq = seq;
                    seq += 1;
                    match WireRequest::parse(text) {
                        Ok(req) => {
                            // Deadlines are stamped here, at admission:
                            // queueing time counts against the SLO.
                            let job = Job::new(req.id.clone(), req.spec);
                            // Admission gate: a job the runner cannot
                            // execute (e.g. an unregistered
                            // resolution) is answered now and never
                            // queues or leases GPUs.
                            if let Err(e) = runner.admit(&job) {
                                router.record_inadmissible();
                                let _ = tx.send((
                                    this_seq,
                                    protocol::error_line(&job.id, &e),
                                ));
                            } else {
                                let ticket = Ticket {
                                    job,
                                    seq: this_seq,
                                    reply: tx.clone(),
                                };
                                if let Err(e) = router.submit(ticket) {
                                    let _ = tx.send((
                                        this_seq,
                                        protocol::error_line(&req.id, &e),
                                    ));
                                }
                            }
                        }
                        Err(e) => {
                            let _ = tx.send((
                                this_seq,
                                protocol::error_line("?", &e),
                            ));
                        }
                    }
                }
                line.clear();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut
                ) =>
            {
                // Read timeout. A partially-read line stays in `line`
                // (read_line appends) and completes on a later call.
                if done.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    crate::log_debug!("serve", "connection from {peer} closing");
    // Dropping our sender lets the writer drain in-flight responses
    // and exit once every outstanding ticket's clone is gone too.
    drop(tx);
    let _ = writer.join();
}

/// Writer half of one connection: responses arrive tagged with their
/// per-connection sequence number in completion order; buffer
/// out-of-order ones and write strictly in submission order.
fn write_in_order(
    mut stream: TcpStream,
    rx: mpsc::Receiver<(u64, String)>,
) {
    let mut next = 0u64;
    let mut pending: BTreeMap<u64, String> = BTreeMap::new();
    while let Ok((seq, line)) = rx.recv() {
        pending.insert(seq, line);
        while let Some(l) = pending.remove(&next) {
            // Errors include the WRITE_TIMEOUT expiring on a client
            // that stopped reading; either way the connection is dead.
            if writeln!(stream, "{l}").is_err() {
                return; // client gone; nothing left to deliver
            }
            next += 1;
        }
    }
    // Channel closed with gaps: defensive only — every current path
    // sends exactly one line per assigned seq (success, catch_unwind'd
    // runner panic, busy/parse rejection, and shutdown drain via
    // `close_and_answer`). Should a future path drop a ticket without
    // responding, the remaining out-of-order responses are
    // undeliverable in FIFO order and die with the connection.
}

/// Simple blocking client for tests/examples.
pub struct Client {
    writer: TcpStream,
    // One persistent reader: a fresh BufReader per request could
    // swallow bytes already buffered from a previous read and then
    // block forever on the next.
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    /// Send one v1 request (`{"id","seed"}` — the backcompat shape),
    /// read one response line.
    pub fn request(&mut self, id: &str, seed: u64) -> Result<String> {
        self.send(id, seed)?;
        self.read_line()
    }

    /// Send one v2 request with a full spec, read one response line.
    pub fn request_spec(
        &mut self,
        id: &str,
        spec: &GenerationSpec,
    ) -> Result<String> {
        self.send_spec(id, spec)?;
        self.read_line()
    }

    /// Send one v1 request without waiting for the response
    /// (pipelining; pair with [`Client::read_line`]).
    pub fn send(&mut self, id: &str, seed: u64) -> Result<()> {
        let req = WireRequest {
            id: id.into(),
            spec: GenerationSpec::new().seed(seed),
        };
        writeln!(self.writer, "{}", req.to_line_v1())?;
        Ok(())
    }

    /// Send one v2 request without waiting for the response.
    pub fn send_spec(
        &mut self,
        id: &str,
        spec: &GenerationSpec,
    ) -> Result<()> {
        let req = WireRequest { id: id.into(), spec: spec.clone() };
        writeln!(self.writer, "{}", req.to_line())?;
        Ok(())
    }

    /// Read the next response line.
    pub fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }
}

/// Client-side view of one [`drive_workload`] run.
#[derive(Debug, Clone)]
pub struct WorkloadStats {
    pub wall_s: f64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
}

impl WorkloadStats {
    pub fn throughput_rps(&self, requests: usize) -> f64 {
        if self.wall_s > 0.0 {
            requests as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Drive `clients` concurrent connections with `per_client` sequential
/// requests each (seeds counting up from `seed0`) — the shared load
/// harness for benches and examples. Returns wall time plus the
/// mean/p50/p95 of per-request latencies across every client; fails if
/// any response is not `ok`.
pub fn drive_workload(
    addr: &str,
    clients: usize,
    per_client: usize,
    seed0: u64,
) -> Result<WorkloadStats> {
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..clients {
        let addr = addr.to_string();
        threads.push(thread::spawn(move || -> Result<Vec<f64>> {
            let mut client = Client::connect(&addr)?;
            let mut latencies = Vec::with_capacity(per_client);
            for i in 0..per_client {
                let t = Instant::now();
                let line = client.request(
                    &format!("c{c}-r{i}"),
                    seed0 + (c * per_client + i) as u64,
                )?;
                latencies.push(t.elapsed().as_secs_f64());
                let v = json::parse(&line)?;
                if !v.get("ok")?.as_bool()? {
                    return Err(Error::Protocol(format!(
                        "request c{c}-r{i} failed: {line}"
                    )));
                }
            }
            Ok(latencies)
        }));
    }
    let mut all = Vec::new();
    for t in threads {
        all.extend(
            t.join()
                .map_err(|_| Error::msg("client thread panicked"))??,
        );
    }
    Ok(WorkloadStats {
        wall_s: t0.elapsed().as_secs_f64(),
        mean_latency_s: stats::mean(&all),
        p50_latency_s: stats::percentile(&all, 50.0),
        p95_latency_s: stats::percentile(&all, 95.0),
    })
}

#[cfg(test)]
mod tests {
    // End-to-end server tests live in rust/tests/integration_serve.rs:
    // the queueing/ordering/shutdown machinery runs there against a
    // stub JobRunner (no artifacts needed), real-generation paths
    // against built artifacts.
}
