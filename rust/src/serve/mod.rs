//! Serving front-end: JSON-lines protocol, bounded router, TCP server.

pub mod protocol;
pub mod router;
pub mod sim;
pub mod server;
