//! Serving front-end: JSON-lines protocol, thread-safe bounded router,
//! concurrent TCP server (accept loop + worker pool over per-request
//! sessions, optionally fleet-partitioned via gang policies), and the
//! M/G/c + gang-policy queueing simulations.
//!
//! See rust/DESIGN_SERVE.md for the architecture diagram, the fleet
//! lease lifecycle, and locking rules.

pub mod protocol;
pub mod router;
pub mod server;
pub mod sim;
