//! Serving front-end: JSON-lines protocol, thread-safe bounded router,
//! concurrent TCP server (accept loop + worker pool over per-request
//! sessions), and the M/G/c queueing simulation.
//!
//! See rust/DESIGN_SERVE.md for the architecture diagram and locking
//! rules.

pub mod protocol;
pub mod router;
pub mod server;
pub mod sim;
