//! Serving front-end: JSON-lines protocol (v2 `GenerationSpec`
//! requests, v1 seed lines kept compatible, with a lazy wire scanner
//! on the hot path that falls back to the full tree parse on anything
//! unusual), thread-safe bounded priority router (priority desc /
//! earliest-deadline / FIFO, with dequeue-time deadline shedding),
//! concurrent TCP server (a single poll(2) event loop owning a
//! bounded connection table — `--io threads` keeps the old
//! thread-per-connection path for one release — plus a worker pool
//! over per-request sessions, optionally fleet-partitioned via gang
//! policies or federated across a multi-node
//! [`FrontTier`](crate::federation::FrontTier)), and the M/G/c +
//! gang-policy + mixed-priority + federation queueing simulations.
//!
//! See rust/DESIGN_SERVE.md for the architecture diagram, the fleet
//! lease lifecycle, and locking rules.

pub mod batch;
pub mod degrade;
pub mod protocol;
pub mod router;
pub mod server;
pub mod sim;
