//! Public engine API: configure once, generate many.
//!
//! The `Engine` owns the PJRT runtime, the simulated cluster, the
//! profiler and the schedule; each `generate` call plans (Eq. 4 + 5)
//! against current effective speeds, executes Algorithm 1 (dataflow or
//! threaded per config), and reports both the image and the simulated
//! cluster latency (timeline).

use crate::config::{EngineConfig, ExecMode};
use crate::coordinator::{dataflow, threaded, timeline};
use crate::device::{build_cluster, CostModel, SimGpu};
use crate::error::Result;
use crate::model::latents::{seeded_cond, seeded_noise};
use crate::model::schedule::Schedule;
use crate::runtime::tensor::Tensor;
use crate::runtime::{ExecHandle, ExecService};
use crate::sched::plan::Plan;
use crate::sched::Profiler;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Seeds the initial noise and the conditioning vector (the
    /// prompt-embedding stand-in, DESIGN.md §3).
    pub seed: u64,
}

/// Full result of one request.
#[derive(Debug)]
pub struct Generation {
    pub latent: Tensor,
    pub plan: Plan,
    pub stats: dataflow::ExecStats,
    /// Simulated heterogeneous-cluster latency for this plan.
    pub timeline: timeline::Timeline,
}

/// The STADI inference engine.
pub struct Engine {
    config: EngineConfig,
    /// Keeps the PJRT service thread alive.
    _service: ExecService,
    exec: ExecHandle,
    cluster: Vec<SimGpu>,
    profiler: Profiler,
    schedule: Schedule,
}

impl Engine {
    /// Load artifacts and build the engine. Uses the uncalibrated cost
    /// model; call [`Engine::calibrate`] (or `with_cost_model`) for
    /// timing-faithful timelines.
    pub fn new(config: EngineConfig) -> Result<Self> {
        Self::with_cost_model(config, CostModel::uncalibrated())
    }

    pub fn with_cost_model(config: EngineConfig, cost: CostModel) -> Result<Self> {
        config.validate()?;
        let service = ExecService::spawn(&config.artifacts_dir)?;
        let exec = service.handle();
        let cluster = build_cluster(&config.devices, cost);
        let profiler = Profiler::new(&config.devices);
        let schedule = Schedule::from_info(&exec.manifest().schedule);
        Ok(Engine {
            config,
            _service: service,
            exec,
            cluster,
            profiler,
            schedule,
        })
    }

    /// Re-calibrate the per-step cost model from real PJRT timings and
    /// rebuild the cluster with it.
    pub fn calibrate(&mut self, reps: usize) -> Result<CostModel> {
        let cost = self.exec.calibrate(reps)?;
        self.cluster = build_cluster(&self.config.devices, cost);
        Ok(cost)
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Handle to the execution service (manifest, features, ...).
    pub fn exec(&self) -> &ExecHandle {
        &self.exec
    }

    pub fn cluster(&self) -> &[SimGpu] {
        &self.cluster
    }

    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    pub fn profiler_mut(&mut self) -> &mut Profiler {
        &mut self.profiler
    }

    /// Build the joint plan for current effective speeds.
    pub fn plan(&self) -> Result<Plan> {
        let speeds = self.profiler.effective_speeds();
        let names: Vec<String> =
            self.config.devices.iter().map(|d| d.name.clone()).collect();
        let m = &self.exec.manifest().model;
        if self.config.stadi.cost_aware && self.config.stadi.spatial {
            return Plan::build_cost_aware(
                &self.schedule,
                &speeds,
                &names,
                &self.config.stadi,
                &self.cluster[0].cost,
                m.latent_h,
                m.row_granularity,
            );
        }
        Plan::build(
            &self.schedule,
            &speeds,
            &names,
            &self.config.stadi,
            m.latent_h,
            m.row_granularity,
        )
    }

    /// Generate with an explicit plan (benches use this to sweep).
    pub fn generate_with_plan(
        &mut self,
        plan: &Plan,
        req: &Request,
    ) -> Result<Generation> {
        let model = self.exec.manifest().model.clone();
        // Pre-compile every artifact the plan needs so compilation
        // never lands inside measured step times (it would poison the
        // profiler's effective-speed estimates — a freshly-compiling
        // device would look 100x slower and get itself excluded).
        let keys: Vec<String> = plan
            .included_devices()
            .map(|d| format!("denoiser_h{}", d.rows.rows))
            .collect();
        self.exec.warm(&keys)?;
        let noise = seeded_noise(&model, req.seed);
        let cond = seeded_cond(&model, req.seed);
        let out = match self.config.mode {
            ExecMode::Dataflow => {
                dataflow::execute(&self.exec, plan, &noise, &cond)?
            }
            ExecMode::Threaded => threaded::execute(
                &self.exec,
                plan,
                &self.cluster,
                &noise,
                &cond,
                true,
            )?,
        };
        // Feed measured per-step compute back into the profiler
        // ("historical inference time profiles", paper §V).
        for d in plan.included_devices() {
            if out.stats.steps_run[d.device] > 0 {
                self.profiler.record_step(
                    d.device,
                    d.rows.rows * out.stats.steps_run[d.device],
                    out.stats.compute_s[d.device],
                );
            }
        }
        let tl = timeline::simulate(
            plan,
            &self.cluster,
            &self.config.comm,
            &self.exec.manifest().model,
        )?;
        Ok(Generation {
            latent: out.latent,
            plan: plan.clone(),
            stats: out.stats,
            timeline: tl,
        })
    }

    /// Plan + generate.
    pub fn generate(&mut self, req: &Request) -> Result<Generation> {
        let plan = self.plan()?;
        self.generate_with_plan(&plan, req)
    }

    /// Convenience: generate from a bare seed.
    pub fn generate_seeded(&mut self, seed: u64) -> Result<Generation> {
        self.generate(&Request { seed })
    }

    /// Latency-only simulation of the current plan (no numerics).
    pub fn simulate_latency(&self, plan: &Plan) -> Result<timeline::Timeline> {
        timeline::simulate(
            plan,
            &self.cluster,
            &self.config.comm,
            &self.exec.manifest().model,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StadiParams;
    use std::path::PathBuf;

    fn config(occ: &[f64]) -> Option<EngineConfig> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let mut cfg = EngineConfig::two_gpu_default(dir, occ);
        cfg.stadi = StadiParams {
            m_base: 8,
            m_warmup: 2,
            ..StadiParams::default()
        };
        Some(cfg)
    }

    #[test]
    fn end_to_end_generate() {
        let Some(cfg) = config(&[0.0, 0.4]) else { return };
        let mut engine = Engine::new(cfg).unwrap();
        let g = engine.generate_seeded(1).unwrap();
        assert_eq!(g.latent.shape, vec![32, 32, 4]);
        assert!(g.timeline.total_s > 0.0);
        assert!(g.stats.steps_run.iter().sum::<usize>() > 0);
    }

    #[test]
    fn same_seed_same_plan_same_image() {
        let Some(cfg) = config(&[0.0, 0.0]) else { return };
        let mut engine = Engine::new(cfg).unwrap();
        // Pin the plan: `generate` feeds measured timings back into the
        // profiler, so back-to-back auto-planned runs may legally pick
        // different patch splits (and thus different images — Table II
        // shows outputs are split-dependent).
        let plan = engine.plan().unwrap();
        let a = engine
            .generate_with_plan(&plan, &Request { seed: 5 })
            .unwrap();
        let b = engine
            .generate_with_plan(&plan, &Request { seed: 5 })
            .unwrap();
        assert_eq!(a.latent, b.latent);
        let c = engine
            .generate_with_plan(&plan, &Request { seed: 6 })
            .unwrap();
        assert!(a.latent.max_abs_diff(&c.latent) > 1e-3);
    }

    #[test]
    fn profiler_learns_from_runs() {
        let Some(cfg) = config(&[0.0, 0.6]) else { return };
        let mut engine = Engine::new(cfg).unwrap();
        engine.generate_seeded(1).unwrap();
        let v = engine.profiler_mut().effective_speeds();
        // Both devices ran on the same physical substrate without
        // stretching (dataflow mode) so measured speeds converge —
        // the point is just that history flows through.
        assert_eq!(v.len(), 2);
        assert!(v.iter().all(|&x| x > 0.0 && x <= 1.0));
    }
}
