//! Threaded execution of Algorithm 1: one worker thread per included
//! device, blocking x all-gathers and async KV publishes over the
//! `CollectiveBus`, with per-device heterogeneity imposed by stretching
//! step durations (`SimGpu::stretch_step`).
//!
//! Numerics are identical to the dataflow executor by construction —
//! a device may only consume peer KV published at the preceding sync
//! point, which the gather barrier enforces (integration tests assert
//! bit-equality). This path exists to exercise the *real* serving
//! runtime: thread lifecycle, collective synchronization, staleness-
//! tolerant mailboxes, backpressure on the shared PJRT substrate.

use std::sync::Arc;
use std::thread;
use std::time::Instant;

use crate::comm::CollectiveBus;
use crate::device::SimGpu;
use crate::error::{Error, Result};
use crate::model::latents::token_range;
use crate::model::sampler;
use crate::runtime::artifacts::{ModelInfo, ResKey};
use crate::runtime::tensor::Tensor;
use crate::runtime::ExecHandle;
use crate::sched::plan::Plan;

use super::dataflow::{ExecState, RequestOutput};

/// Run one request with real worker threads at the native resolution
/// (the legacy entry point).
pub fn execute(
    exec: &ExecHandle,
    plan: &Plan,
    cluster: &[SimGpu],
    noise: &Tensor,
    cond: &[f32],
    stretch: bool,
) -> Result<RequestOutput> {
    let native = exec.registry().native();
    execute_at(
        exec,
        native.key,
        &native.model,
        plan,
        cluster,
        noise,
        cond,
        stretch,
    )
}

/// Run one request with real worker threads against a registered
/// resolution's artifacts.
#[allow(clippy::too_many_arguments)]
pub fn execute_at(
    exec: &ExecHandle,
    res: ResKey,
    model: &ModelInfo,
    plan: &Plan,
    cluster: &[SimGpu],
    noise: &Tensor,
    cond: &[f32],
    stretch: bool,
) -> Result<RequestOutput> {
    let mut st = ExecState::new(model, plan.devices.len(), noise);
    run_span_at(
        exec,
        res,
        model,
        plan,
        cluster,
        cond,
        &mut st,
        plan.sync_points.len(),
        stretch,
    )?;
    super::dataflow::finish(plan, st)
}

/// Run `n_syncs` sync intervals of `plan` with one scoped worker
/// thread per included device, from `st`'s position. Workers borrow
/// their device's buffers, run until they have passed `n_syncs` sync
/// barriers (the bundled x+KV all-gather), and leave every included
/// device's buffers fully fresh — which is what lets the adaptive
/// execution loop re-plan row ownership between spans with numerics
/// still bit-equal to the dataflow executor.
#[allow(clippy::too_many_arguments)]
pub fn run_span_at(
    exec: &ExecHandle,
    res: ResKey,
    model: &ModelInfo,
    plan: &Plan,
    cluster: &[SimGpu],
    cond: &[f32],
    st: &mut ExecState,
    n_syncs: usize,
    stretch: bool,
) -> Result<()> {
    let included: Vec<usize> = plan
        .devices
        .iter()
        .filter(|d| d.included())
        .map(|d| d.device)
        .collect();
    if included.is_empty() {
        return Err(Error::Sched("no included devices".into()));
    }
    if st.bufs.len() != plan.devices.len() {
        return Err(Error::Sched("state/plan size mismatch".into()));
    }
    let bus = CollectiveBus::new();
    let cond: Arc<Vec<f32>> = Arc::new(cond.to_vec());
    let ExecState { bufs, cursor, stats } = st;
    let cursors: Vec<usize> = cursor.clone();

    let mut results: Vec<(usize, Result<(usize, f64, usize)>)> =
        Vec::with_capacity(included.len());
    thread::scope(|scope| {
        let mut handles = Vec::new();
        for (di, bufs) in bufs.iter_mut().enumerate() {
            if !plan.devices[di].included() {
                continue;
            }
            let exec = exec.clone();
            let cond = Arc::clone(&cond);
            let bus = bus.clone();
            let plan_dev = &plan.devices[di];
            let all_devices = &plan.devices;
            let included = included.clone();
            let gpu = &cluster[di];
            let cursor0 = cursors[di];
            handles.push((
                di,
                scope.spawn(move || -> Result<(usize, f64, usize)> {
                    let (t0, t1) = token_range(model, plan_dev.rows);
                    let mut compute_s = 0.0f64;
                    let mut steps_run = 0usize;
                    let mut cur = cursor0;
                    let mut syncs_left = n_syncs;
                    while syncs_left > 0 {
                        let step =
                            plan_dev.steps.get(cur).ok_or_else(|| {
                                Error::Sched(format!(
                                    "device {} ran out of steps",
                                    plan_dev.name
                                ))
                            })?;
                        let x_patch = bufs
                            .x
                            .slice_rows(plan_dev.rows.row0, plan_dev.rows.rows);
                        let t_start = Instant::now();
                        let out = exec.denoise_at(
                            res,
                            plan_dev.rows.rows,
                            &x_patch,
                            &bufs.kv,
                            plan_dev.rows.row0,
                            step.t_from as f64,
                            &cond,
                        )?;
                        let real = t_start.elapsed().as_secs_f64();
                        compute_s += real;
                        steps_run += 1;
                        if stretch {
                            gpu.stretch_step(plan_dev.rows.rows, real);
                        }

                        bufs.scatter_kv(t0, &out.kv_fresh);
                        sampler::ddim_update_rows(
                            &mut bufs.x,
                            &out.eps_patch,
                            plan_dev.rows.row0,
                            step.coef,
                        );
                        cur += 1;

                        if step.sync {
                            // One uneven all-gather carries [x_patch ||
                            // kv block]: the x half is the synchronous
                            // output gather of Alg. 1, the kv half is
                            // the buffer update. Bundling them in the
                            // barrier pins the staleness semantics to
                            // the *sync point* (a peer racing ahead can
                            // never leak a fresher buffer into this
                            // interval), which is what makes threaded
                            // numerics bit-equal to the dataflow
                            // executor. Transfer-cost-wise the kv half
                            // is still modeled as maskable-async by the
                            // timeline simulator.
                            let own = bufs.x.slice_rows(
                                plan_dev.rows.row0,
                                plan_dev.rows.rows,
                            );
                            let mut payload = own.data;
                            payload.extend_from_slice(
                                &bufs.gather_kv(t0, t1 - t0).data,
                            );
                            let gathered = bus.all_gather(
                                "sync",
                                plan_dev.device,
                                &included,
                                payload,
                            )?;
                            for (&peer, data) in &gathered {
                                if peer == plan_dev.device {
                                    continue;
                                }
                                let pr = all_devices[peer].rows;
                                let x_len = pr.rows
                                    * model.latent_w
                                    * model.latent_c;
                                let patch = Tensor::new(
                                    vec![
                                        pr.rows,
                                        model.latent_w,
                                        model.latent_c,
                                    ],
                                    data[..x_len].to_vec(),
                                )?;
                                bufs.x.scatter_rows(pr.row0, &patch);
                                let (p0, p1) = token_range(model, pr);
                                let block = Tensor::new(
                                    vec![
                                        model.layers,
                                        p1 - p0,
                                        2 * model.dim,
                                    ],
                                    data[x_len..].to_vec(),
                                )?;
                                bufs.scatter_kv(p0, &block);
                            }
                            syncs_left -= 1;
                        }
                    }
                    Ok((cur, compute_s, steps_run))
                }),
            ));
        }
        for (di, h) in handles {
            let r = match h.join() {
                Ok(r) => r,
                Err(_) => Err(Error::msg("worker thread panicked")),
            };
            results.push((di, r));
        }
    });

    for (di, r) in results {
        let (cur, compute_s, steps_run) = r?;
        cursor[di] = cur;
        stats.compute_s[di] += compute_s;
        stats.steps_run[di] += steps_run;
    }
    stats.syncs += n_syncs;
    // The bundled barrier moves x+kv together; split accounting
    // analytically (every sync, every included device contributes its
    // x patch and kv block).
    let syncs = n_syncs as u64;
    let mut span_bytes = 0u64;
    for &di in &included {
        let d = &plan.devices[di];
        let x = (d.rows.rows * model.latent_w * model.latent_c * 4) as u64;
        let kv = (model.layers
            * model.tokens_for_rows(d.rows.rows)
            * 2
            * model.dim
            * 4) as u64;
        stats.x_bytes += syncs * x;
        stats.kv_bytes += syncs * kv;
        span_bytes += syncs * (x + kv);
    }
    debug_assert_eq!(span_bytes, bus.bytes_gathered());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, StadiParams};
    use crate::device::{build_cluster, CostModel};
    use crate::model::latents::{seeded_cond, seeded_noise};
    use crate::model::schedule::Schedule;
    use crate::runtime::ExecService;
    use std::path::PathBuf;

    fn runtime() -> Option<ExecService> {
        if !cfg!(feature = "xla-backend") {
            eprintln!("skipping: built without xla-backend");
            return None;
        }
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(ExecService::spawn(dir).unwrap())
    }

    #[test]
    fn threaded_matches_dataflow_bit_exactly() {
        let Some(svc) = runtime() else { return };
        let rt = svc.handle();
        let p = StadiParams {
            m_base: 8,
            m_warmup: 2,
            ..StadiParams::default()
        };
        let sched = Schedule::from_info(&rt.manifest().schedule);
        let speeds = [1.0, 0.5];
        let names = vec!["g0".into(), "g1".into()];
        let plan = Plan::build(&sched, &speeds, &names, &p, 32, 4).unwrap();
        let model = rt.manifest().model.clone();
        let noise = seeded_noise(&model, 21);
        let cond = seeded_cond(&model, 21);

        let df = super::super::dataflow::execute(&rt, &plan, &noise, &cond)
            .unwrap();
        let devs = vec![
            DeviceConfig::new("g0", 1.0, 0.0),
            DeviceConfig::new("g1", 1.0, 0.5),
        ];
        let cluster = build_cluster(&devs, CostModel::uncalibrated());
        let th = execute(&rt, &plan, &cluster, &noise, &cond, false)
            .unwrap();
        assert_eq!(
            df.latent, th.latent,
            "threaded and dataflow numerics diverge"
        );
        assert_eq!(df.stats.steps_run, th.stats.steps_run);
    }
}
