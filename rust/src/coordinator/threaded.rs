//! Threaded execution of Algorithm 1: one worker thread per included
//! device, blocking x all-gathers and async KV publishes over the
//! `CollectiveBus`, with per-device heterogeneity imposed by stretching
//! step durations (`SimGpu::stretch_step`).
//!
//! Numerics are identical to the dataflow executor by construction —
//! a device may only consume peer KV published at the preceding sync
//! point, which the gather barrier enforces (integration tests assert
//! bit-equality). This path exists to exercise the *real* serving
//! runtime: thread lifecycle, collective synchronization, staleness-
//! tolerant mailboxes, backpressure on the shared PJRT substrate.

use std::sync::Arc;
use std::thread;
use std::time::Instant;

use crate::comm::CollectiveBus;
use crate::device::SimGpu;
use crate::error::{Error, Result};
use crate::model::latents::token_range;
use crate::model::sampler;
use crate::runtime::artifacts::{ModelInfo, ResKey};
use crate::runtime::tensor::Tensor;
use crate::runtime::ExecHandle;
use crate::sched::plan::Plan;

use super::buffers::DeviceBuffers;
use super::dataflow::{ExecStats, RequestOutput};

/// Run one request with real worker threads at the native resolution
/// (the legacy entry point).
pub fn execute(
    exec: &ExecHandle,
    plan: &Plan,
    cluster: &[SimGpu],
    noise: &Tensor,
    cond: &[f32],
    stretch: bool,
) -> Result<RequestOutput> {
    let native = exec.registry().native();
    execute_at(
        exec,
        native.key,
        &native.model,
        plan,
        cluster,
        noise,
        cond,
        stretch,
    )
}

/// Run one request with real worker threads against a registered
/// resolution's artifacts.
#[allow(clippy::too_many_arguments)]
pub fn execute_at(
    exec: &ExecHandle,
    res: ResKey,
    model: &ModelInfo,
    plan: &Plan,
    cluster: &[SimGpu],
    noise: &Tensor,
    cond: &[f32],
    stretch: bool,
) -> Result<RequestOutput> {
    let model = model.clone();
    let included: Vec<usize> = plan
        .devices
        .iter()
        .filter(|d| d.included())
        .map(|d| d.device)
        .collect();
    if included.is_empty() {
        return Err(Error::Sched("no included devices".into()));
    }
    let bus = CollectiveBus::new();
    let cond: Arc<Vec<f32>> = Arc::new(cond.to_vec());

    let mut handles = Vec::new();
    for &di in &included {
        let exec = exec.clone();
        let cond = Arc::clone(&cond);
        let bus = bus.clone();
        let plan_dev = plan.devices[di].clone();
        let all_devices: Vec<_> = plan.devices.clone();
        let included = included.clone();
        let gpu = cluster[di].clone();
        let model = model.clone();
        let noise = noise.clone();
        handles.push(thread::spawn(move || -> Result<(usize, DeviceBuffers, f64, usize)> {
            let mut bufs = DeviceBuffers::new(&model, &noise);
            let (t0, t1) = token_range(&model, plan_dev.rows);
            let mut compute_s = 0.0f64;
            let mut steps_run = 0usize;
            for step in &plan_dev.steps {
                let x_patch =
                    bufs.x.slice_rows(plan_dev.rows.row0, plan_dev.rows.rows);
                let t_start = Instant::now();
                let out = exec.denoise_at(
                    res,
                    plan_dev.rows.rows,
                    &x_patch,
                    &bufs.kv,
                    plan_dev.rows.row0,
                    step.t_from as f64,
                    &cond,
                )?;
                let real = t_start.elapsed().as_secs_f64();
                compute_s += real;
                steps_run += 1;
                if stretch {
                    gpu.stretch_step(plan_dev.rows.rows, real);
                }

                bufs.scatter_kv(t0, &out.kv_fresh);
                sampler::ddim_update_rows(
                    &mut bufs.x,
                    &out.eps_patch,
                    plan_dev.rows.row0,
                    step.coef,
                );

                if step.sync {
                    // One uneven all-gather carries [x_patch || kv
                    // block]: the x half is the synchronous output
                    // gather of Alg. 1, the kv half is the buffer
                    // update. Bundling them in the barrier pins the
                    // staleness semantics to the *sync point* (a peer
                    // racing ahead can never leak a fresher buffer
                    // into this interval), which is what makes
                    // threaded numerics bit-equal to the dataflow
                    // executor. Transfer-cost-wise the kv half is
                    // still modeled as maskable-async by the timeline
                    // simulator.
                    let own = bufs
                        .x
                        .slice_rows(plan_dev.rows.row0, plan_dev.rows.rows);
                    let mut payload = own.data;
                    payload
                        .extend_from_slice(&bufs.gather_kv(t0, t1 - t0).data);
                    let gathered = bus.all_gather(
                        "sync",
                        plan_dev.device,
                        &included,
                        payload,
                    )?;
                    for (&peer, data) in &gathered {
                        if peer == plan_dev.device {
                            continue;
                        }
                        let pr = all_devices[peer].rows;
                        let x_len =
                            pr.rows * model.latent_w * model.latent_c;
                        let patch = Tensor::new(
                            vec![pr.rows, model.latent_w, model.latent_c],
                            data[..x_len].to_vec(),
                        )?;
                        bufs.x.scatter_rows(pr.row0, &patch);
                        let (p0, p1) = token_range(&model, pr);
                        let block = Tensor::new(
                            vec![model.layers, p1 - p0, 2 * model.dim],
                            data[x_len..].to_vec(),
                        )?;
                        bufs.scatter_kv(p0, &block);
                    }
                }
            }
            Ok((plan_dev.device, bufs, compute_s, steps_run))
        }));
    }

    let mut stats = ExecStats {
        compute_s: vec![0.0; plan.devices.len()],
        steps_run: vec![0; plan.devices.len()],
        ..Default::default()
    };
    let mut result: Option<Tensor> = None;
    for h in handles {
        let (di, bufs, compute_s, steps_run) = h
            .join()
            .map_err(|_| Error::msg("worker thread panicked"))??;
        stats.compute_s[di] = compute_s;
        stats.steps_run[di] = steps_run;
        if result.is_none() || di == included[0] {
            result = Some(bufs.x);
        }
    }
    stats.syncs = plan.sync_points.len();
    // The bundled barrier moves x+kv together; split accounting
    // analytically (every sync, every included device contributes its
    // x patch and kv block).
    let syncs = plan.sync_points.len() as u64;
    for &di in &included {
        let d = &plan.devices[di];
        let x = (d.rows.rows * model.latent_w * model.latent_c * 4) as u64;
        let kv = (model.layers
            * model.tokens_for_rows(d.rows.rows)
            * 2
            * model.dim
            * 4) as u64;
        stats.x_bytes += syncs * x;
        stats.kv_bytes += syncs * kv;
    }
    debug_assert_eq!(stats.x_bytes + stats.kv_bytes, bus.bytes_gathered());
    Ok(RequestOutput { latent: result.unwrap(), stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, StadiParams};
    use crate::device::{build_cluster, CostModel};
    use crate::model::latents::{seeded_cond, seeded_noise};
    use crate::model::schedule::Schedule;
    use crate::runtime::ExecService;
    use std::path::PathBuf;

    fn runtime() -> Option<ExecService> {
        if !cfg!(feature = "xla-backend") {
            eprintln!("skipping: built without xla-backend");
            return None;
        }
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(ExecService::spawn(dir).unwrap())
    }

    #[test]
    fn threaded_matches_dataflow_bit_exactly() {
        let Some(svc) = runtime() else { return };
        let rt = svc.handle();
        let p = StadiParams {
            m_base: 8,
            m_warmup: 2,
            ..StadiParams::default()
        };
        let sched = Schedule::from_info(&rt.manifest().schedule);
        let speeds = [1.0, 0.5];
        let names = vec!["g0".into(), "g1".into()];
        let plan = Plan::build(&sched, &speeds, &names, &p, 32, 4).unwrap();
        let model = rt.manifest().model.clone();
        let noise = seeded_noise(&model, 21);
        let cond = seeded_cond(&model, 21);

        let df = super::super::dataflow::execute(&rt, &plan, &noise, &cond)
            .unwrap();
        let devs = vec![
            DeviceConfig::new("g0", 1.0, 0.0),
            DeviceConfig::new("g1", 1.0, 0.5),
        ];
        let cluster = build_cluster(&devs, CostModel::uncalibrated());
        let th = execute(&rt, &plan, &cluster, &noise, &cond, false)
            .unwrap();
        assert_eq!(
            df.latent, th.latent,
            "threaded and dataflow numerics diverge"
        );
        assert_eq!(df.stats.steps_run, th.stats.steps_run);
    }
}
