//! Threaded execution of Algorithm 1: one worker thread per included
//! device, blocking x all-gathers and async KV publishes over the
//! `CollectiveBus`, with per-device heterogeneity imposed by stretching
//! step durations (`SimGpu::stretch_step`).
//!
//! Numerics are identical to the dataflow executor by construction —
//! a device may only consume peer KV published at the preceding sync
//! point, which the gather barrier enforces (integration tests assert
//! bit-equality). This path exists to exercise the *real* serving
//! runtime: thread lifecycle, collective synchronization, staleness-
//! tolerant mailboxes, backpressure on the shared PJRT substrate.

use std::sync::Arc;
use std::thread;
use std::time::Instant;

use crate::comm::CollectiveBus;
use crate::config::HaloMode;
use crate::device::SimGpu;
use crate::error::{Error, Result};
use crate::model::latents::token_range;
use crate::model::sampler;
use crate::runtime::artifacts::{ModelInfo, ResKey};
use crate::runtime::tensor::Tensor;
use crate::runtime::ExecHandle;
use crate::sched::plan::Plan;

use super::dataflow::{ExecState, HaloEntry, HaloPayload, RequestOutput};

/// A worker's private view of recent sync points' payloads: plan-local
/// sync index -> (device -> raw `[x || kv]` payload). Entries are
/// `Arc`-shared with the bus mailboxes, so keeping a history window is
/// cheap.
type LocalHistory = Vec<(usize, Vec<(usize, Arc<Vec<f32>>)>)>;

/// Run one request with real worker threads at the native resolution
/// (the legacy entry point).
pub fn execute(
    exec: &ExecHandle,
    plan: &Plan,
    cluster: &[SimGpu],
    noise: &Tensor,
    cond: &[f32],
    stretch: bool,
) -> Result<RequestOutput> {
    let native = exec.registry().native();
    execute_at(
        exec,
        native.key,
        &native.model,
        plan,
        cluster,
        noise,
        cond,
        stretch,
        HaloMode::Sync,
    )
}

/// Run one request with real worker threads against a registered
/// resolution's artifacts.
#[allow(clippy::too_many_arguments)]
pub fn execute_at(
    exec: &ExecHandle,
    res: ResKey,
    model: &ModelInfo,
    plan: &Plan,
    cluster: &[SimGpu],
    noise: &Tensor,
    cond: &[f32],
    stretch: bool,
    halo: HaloMode,
) -> Result<RequestOutput> {
    let mut st = ExecState::new(model, plan.devices.len(), noise);
    run_span_at(
        exec,
        res,
        model,
        plan,
        cluster,
        cond,
        &mut st,
        plan.sync_points.len(),
        stretch,
        halo,
    )?;
    super::dataflow::finish(plan, st)
}

/// Run `n_syncs` sync intervals of `plan` with one scoped worker
/// thread per included device, from `st`'s position. Workers borrow
/// their device's buffers, run until they have passed `n_syncs` sync
/// barriers (the bundled x+KV all-gather), and leave every included
/// device's buffers fully fresh — which is what lets the adaptive
/// execution loop re-plan row ownership between spans with numerics
/// still bit-equal to the dataflow executor.
#[allow(clippy::too_many_arguments)]
pub fn run_span_at(
    exec: &ExecHandle,
    res: ResKey,
    model: &ModelInfo,
    plan: &Plan,
    cluster: &[SimGpu],
    cond: &[f32],
    st: &mut ExecState,
    n_syncs: usize,
    stretch: bool,
    halo: HaloMode,
) -> Result<()> {
    let included: Vec<usize> = plan
        .devices
        .iter()
        .filter(|d| d.included())
        .map(|d| d.device)
        .collect();
    if included.is_empty() {
        return Err(Error::Sched("no included devices".into()));
    }
    if st.bufs.len() != plan.devices.len() {
        return Err(Error::Sched("state/plan size mismatch".into()));
    }
    let budget = halo.max_staleness();
    let bus = CollectiveBus::new();
    let cond: Arc<Vec<f32>> = Arc::new(cond.to_vec());
    let ExecState { bufs, cursor, stats, synced, halo: history } = st;
    let cursors: Vec<usize> = cursor.clone();
    let synced0 = *synced;
    // The fallback decision is plan-global per sync point, so every
    // worker takes the same branch at the same barrier — precompute it
    // once for the span.
    let fallback_map: Vec<bool> = (0..n_syncs)
        .map(|k| plan.displaced_fallback(synced0 + k, budget))
        .collect();
    // Seed each worker's private history window from the state (the
    // bus — and with it every per-sync mailbox — dies at span end, so
    // payloads a later span's displaced sync will consume stale must
    // ride through `ExecState`).
    let seed_history: LocalHistory = history
        .iter()
        .map(|e| {
            let payloads = e
                .payloads
                .iter()
                .map(|p| {
                    let mut data = p.x_patch.data.clone();
                    data.extend_from_slice(&p.kv_block.data);
                    (p.device, Arc::new(data))
                })
                .collect();
            (e.sync, payloads)
        })
        .collect();

    type WorkerOut = (usize, f64, usize, LocalHistory);
    let mut results: Vec<(usize, Result<WorkerOut>)> =
        Vec::with_capacity(included.len());
    thread::scope(|scope| {
        let mut handles = Vec::new();
        for (di, bufs) in bufs.iter_mut().enumerate() {
            if !plan.devices[di].included() {
                continue;
            }
            let exec = exec.clone();
            let cond = Arc::clone(&cond);
            let bus = bus.clone();
            let plan_dev = &plan.devices[di];
            let all_devices = &plan.devices;
            let included = included.clone();
            let gpu = &cluster[di];
            let cursor0 = cursors[di];
            let fallback_map = &fallback_map;
            let seed_history = &seed_history;
            handles.push((
                di,
                scope.spawn(move || -> Result<WorkerOut> {
                    let (t0, t1) = token_range(model, plan_dev.rows);
                    let mut compute_s = 0.0f64;
                    let mut steps_run = 0usize;
                    let mut cur = cursor0;
                    let mut syncs_left = n_syncs;
                    let mut local: LocalHistory = seed_history.clone();
                    // Reconstruct a peer's [x || kv] payload and
                    // scatter it into this worker's buffers (the row
                    // and token ranges are peer-disjoint, so scatter
                    // order is immaterial).
                    let scatter_peer = |bufs: &mut super::buffers::DeviceBuffers,
                                        peer: usize,
                                        data: &[f32]|
                     -> Result<()> {
                        let pr = all_devices[peer].rows;
                        let x_len =
                            pr.rows * model.latent_w * model.latent_c;
                        let patch = Tensor::new(
                            vec![
                                pr.rows,
                                model.latent_w,
                                model.latent_c,
                            ],
                            data[..x_len].to_vec(),
                        )?;
                        bufs.x.scatter_rows(pr.row0, &patch);
                        let (p0, p1) = token_range(model, pr);
                        let block = Tensor::new(
                            vec![model.layers, p1 - p0, 2 * model.dim],
                            data[x_len..].to_vec(),
                        )?;
                        bufs.scatter_kv(p0, &block);
                        Ok(())
                    };
                    while syncs_left > 0 {
                        let step =
                            plan_dev.steps.get(cur).ok_or_else(|| {
                                Error::Sched(format!(
                                    "device {} ran out of steps",
                                    plan_dev.name
                                ))
                            })?;
                        let x_patch = bufs
                            .x
                            .slice_rows(plan_dev.rows.row0, plan_dev.rows.rows);
                        let t_start = Instant::now();
                        let out = exec.denoise_at(
                            res,
                            plan_dev.rows.rows,
                            &x_patch,
                            &bufs.kv,
                            plan_dev.rows.row0,
                            step.t_from as f64,
                            &cond,
                        )?;
                        let real = t_start.elapsed().as_secs_f64();
                        compute_s += real;
                        steps_run += 1;
                        if stretch {
                            gpu.stretch_step(plan_dev.rows.rows, real);
                        }

                        bufs.scatter_kv(t0, &out.kv_fresh);
                        sampler::ddim_update_rows(
                            &mut bufs.x,
                            &out.eps_patch,
                            plan_dev.rows.row0,
                            step.coef,
                        );
                        cur += 1;

                        if step.sync {
                            // One payload carries [x_patch || kv
                            // block]: the x half is the synchronous
                            // output gather of Alg. 1, the kv half is
                            // the buffer update. Bundling them pins the
                            // staleness semantics to the *sync point*
                            // (a peer racing ahead can never leak a
                            // fresher buffer into this interval), which
                            // is what makes threaded numerics bit-equal
                            // to the dataflow executor.
                            let si = synced0 + (n_syncs - syncs_left);
                            let own = bufs.x.slice_rows(
                                plan_dev.rows.row0,
                                plan_dev.rows.rows,
                            );
                            let mut payload = own.data;
                            payload.extend_from_slice(
                                &bufs.gather_kv(t0, t1 - t0).data,
                            );
                            if fallback_map[n_syncs - syncs_left] {
                                // Blocking exchange: the uneven
                                // all-gather carries every payload
                                // through the barrier.
                                let gathered = bus.all_gather(
                                    "sync",
                                    plan_dev.device,
                                    &included,
                                    payload,
                                )?;
                                for (&peer, data) in &gathered {
                                    if peer == plan_dev.device {
                                        continue;
                                    }
                                    scatter_peer(bufs, peer, data)?;
                                }
                                if budget > 0 {
                                    local.push((
                                        si,
                                        gathered
                                            .into_iter()
                                            .map(|(d, v)| (d, Arc::new(v)))
                                            .collect(),
                                    ));
                                    while local.len() > budget + 1 {
                                        local.remove(0);
                                    }
                                }
                            } else {
                                // Displaced exchange: publish the fresh
                                // payload to this sync point's private
                                // channel, join an *empty* barrier (a
                                // publish happens-before its
                                // publisher's barrier join, so after
                                // the barrier every peer's fresh halo
                                // is visible and exactly version 1 on
                                // its channel), record everyone's fresh
                                // payload, then consume the entry from
                                // `budget` sync points ago.
                                let ch = format!("halo:{si}");
                                bus.publish(
                                    plan_dev.device,
                                    &ch,
                                    payload,
                                );
                                bus.all_gather(
                                    "sync",
                                    plan_dev.device,
                                    &included,
                                    Vec::new(),
                                )?;
                                let mut fresh: Vec<(
                                    usize,
                                    Arc<Vec<f32>>,
                                )> = Vec::with_capacity(included.len());
                                for &peer in &included {
                                    let data = bus
                                        .peek(peer, &ch)
                                        .ok_or_else(|| {
                                            Error::Comm(format!(
                                                "device {peer}: no halo \
                                                 published at sync {si}"
                                            ))
                                        })?;
                                    debug_assert_eq!(
                                        bus.peek_version(peer, &ch),
                                        1
                                    );
                                    fresh.push((peer, data));
                                }
                                local.push((si, fresh));
                                while local.len() > budget + 1 {
                                    local.remove(0);
                                }
                                let stale = local
                                    .iter()
                                    .find(|e| e.0 == si - budget)
                                    .ok_or_else(|| {
                                        Error::Comm(format!(
                                            "no halo history for sync {}",
                                            si - budget
                                        ))
                                    })?;
                                let stale: Vec<(usize, Arc<Vec<f32>>)> =
                                    stale.1.clone();
                                for (peer, data) in &stale {
                                    if *peer == plan_dev.device {
                                        continue;
                                    }
                                    scatter_peer(bufs, *peer, data)?;
                                }
                            }
                            syncs_left -= 1;
                        }
                    }
                    Ok((cur, compute_s, steps_run, local))
                }),
            ));
        }
        for (di, h) in handles {
            let r = match h.join() {
                Ok(r) => r,
                Err(_) => Err(Error::msg("worker thread panicked")),
            };
            results.push((di, r));
        }
    });

    let mut merged: Option<LocalHistory> = None;
    for (di, r) in results {
        let (cur, compute_s, steps_run, local) = r?;
        cursor[di] = cur;
        stats.compute_s[di] += compute_s;
        stats.steps_run[di] += steps_run;
        // Every worker's history window holds the same payloads (each
        // peeked the same channels); persist the first one.
        if merged.is_none() {
            merged = Some(local);
        }
    }
    if budget > 0 {
        if let Some(local) = merged {
            *history = local
                .into_iter()
                .map(|(sync, payloads)| -> Result<HaloEntry> {
                    let payloads = payloads
                        .into_iter()
                        .map(|(device, data)| -> Result<HaloPayload> {
                            let pr = plan.devices[device].rows;
                            let x_len =
                                pr.rows * model.latent_w * model.latent_c;
                            let x_patch = Tensor::new(
                                vec![
                                    pr.rows,
                                    model.latent_w,
                                    model.latent_c,
                                ],
                                data[..x_len].to_vec(),
                            )?;
                            let (p0, p1) = token_range(model, pr);
                            let kv_block = Tensor::new(
                                vec![model.layers, p1 - p0, 2 * model.dim],
                                data[x_len..].to_vec(),
                            )?;
                            Ok(HaloPayload { device, x_patch, kv_block })
                        })
                        .collect::<Result<Vec<_>>>()?;
                    Ok(HaloEntry { sync, payloads })
                })
                .collect::<Result<Vec<_>>>()?;
        }
    }
    *synced += n_syncs;
    stats.syncs += n_syncs;
    let displaced = fallback_map.iter().filter(|f| !**f).count();
    stats.halo_displaced += displaced;
    stats.halo_fallback += n_syncs - displaced;
    // The payloads move x+kv together; split accounting analytically
    // (every sync, every included device contributes its x patch and
    // kv block — fallback syncs through the gather, displaced syncs
    // through async publishes, with only the empty barrier in the
    // gather path).
    let syncs = n_syncs as u64;
    let mut per_sync = 0u64;
    for &di in &included {
        let d = &plan.devices[di];
        let x = (d.rows.rows * model.latent_w * model.latent_c * 4) as u64;
        let kv = (model.layers
            * model.tokens_for_rows(d.rows.rows)
            * 2
            * model.dim
            * 4) as u64;
        stats.x_bytes += syncs * x;
        stats.kv_bytes += syncs * kv;
        per_sync += x + kv;
    }
    debug_assert_eq!(
        (n_syncs - displaced) as u64 * per_sync,
        bus.bytes_gathered()
    );
    debug_assert_eq!(displaced as u64 * per_sync, bus.bytes_published());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, StadiParams};
    use crate::device::{build_cluster, CostModel};
    use crate::model::latents::{seeded_cond, seeded_noise};
    use crate::model::schedule::Schedule;
    use crate::runtime::ExecService;
    use std::path::PathBuf;

    fn runtime() -> Option<ExecService> {
        if !cfg!(feature = "xla-backend") {
            eprintln!("skipping: built without xla-backend");
            return None;
        }
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(ExecService::spawn(dir).unwrap())
    }

    #[test]
    fn threaded_matches_dataflow_bit_exactly() {
        let Some(svc) = runtime() else { return };
        let rt = svc.handle();
        let p = StadiParams {
            m_base: 8,
            m_warmup: 2,
            ..StadiParams::default()
        };
        let sched = Schedule::from_info(&rt.manifest().schedule);
        let speeds = [1.0, 0.5];
        let names = vec!["g0".into(), "g1".into()];
        let plan = Plan::build(&sched, &speeds, &names, &p, 32, 4).unwrap();
        let model = rt.manifest().model.clone();
        let noise = seeded_noise(&model, 21);
        let cond = seeded_cond(&model, 21);

        let df = super::super::dataflow::execute(&rt, &plan, &noise, &cond)
            .unwrap();
        let devs = vec![
            DeviceConfig::new("g0", 1.0, 0.0),
            DeviceConfig::new("g1", 1.0, 0.5),
        ];
        let cluster = build_cluster(&devs, CostModel::uncalibrated());
        let th = execute(&rt, &plan, &cluster, &noise, &cond, false)
            .unwrap();
        assert_eq!(
            df.latent, th.latent,
            "threaded and dataflow numerics diverge"
        );
        assert_eq!(df.stats.steps_run, th.stats.steps_run);
    }
}
