//! Timeline simulation: latency of a plan on a heterogeneous cluster.
//!
//! Replays the plan's sync-interval structure on a virtual clock with
//! calibrated per-step costs (DESIGN.md §4 "sim" mode) — single-core-
//! safe and deterministic, used for Figs. 2/8/9 and Table III.
//!
//! Model per sync interval (the span between consecutive sync points):
//! every included device runs its interval steps back-to-back
//! (1 for slow/warmup devices, up to 2 for fast devices); the sync
//! point completes when the last device arrives, then pays the
//! synchronous x all-gather. Warmup intervals also pay the KV exchange
//! synchronously (Alg. 1 line 11 "Update buffer synchronously");
//! afterwards KV publishes are asynchronous and overlap with the next
//! interval's compute, charging only their unmasked remainder — the
//! paper's "mask communication latency within computation".

use crate::comm::{all_gather_cost, all_reduce_cost, p2p_cost};
use crate::config::CommConfig;
use crate::device::{OccupancySchedule, SimGpu};
use crate::error::{Error, Result};
use crate::runtime::artifacts::ModelInfo;
use crate::sched::plan::Plan;

/// Simulated latency breakdown.
#[derive(Debug, Clone)]
pub struct Timeline {
    /// End-to-end request latency (seconds, virtual).
    pub total_s: f64,
    /// Per-device compute-busy seconds.
    pub busy_s: Vec<f64>,
    /// Per-device idle seconds (waiting at sync points).
    pub idle_s: Vec<f64>,
    /// Blocking communication seconds on the critical path.
    pub comm_s: f64,
    /// Mean utilization of included devices: busy / total.
    pub utilization: f64,
}

/// A drift source for the virtual clock: the deterministic occupancy
/// schedule plus the *global* device id of each local cluster index
/// (identity for whole-cluster runs, the lease map for gang sessions —
/// the schedule describes the fleet, not the gang).
pub type DriftCtx<'a> = (&'a OccupancySchedule, &'a [usize]);

/// Resumable virtual-clock state, so the adaptive execution loop can
/// simulate a request as a sequence of plan segments (re-plans switch
/// plans mid-request; the clock, per-device busy totals, async-KV debt
/// and drift step counters all carry across the switch).
#[derive(Debug, Clone)]
pub struct SimState {
    /// Per-device step cursor within the current plan.
    pub cursor: Vec<usize>,
    /// Per-device executed-step counters (the drift-schedule key);
    /// persist across plan switches.
    pub steps_done: Vec<usize>,
    /// Per-device compute-busy seconds.
    pub busy: Vec<f64>,
    /// Virtual clock.
    pub now: f64,
    /// Blocking communication seconds so far.
    pub comm_s: f64,
    /// Unmasked async-KV debt carried into the next interval.
    pub kv_debt: f64,
    /// Sync points completed within the current plan.
    pub synced: usize,
}

impl SimState {
    pub fn new(n: usize) -> Self {
        SimState {
            cursor: vec![0; n],
            steps_done: vec![0; n],
            busy: vec![0.0; n],
            now: 0.0,
            comm_s: 0.0,
            kv_debt: 0.0,
            synced: 0,
        }
    }

    /// Switch to a re-planned continuation: per-plan positions reset,
    /// clocks and drift counters persist.
    pub fn switch_plan(&mut self) {
        for c in self.cursor.iter_mut() {
            *c = 0;
        }
        self.synced = 0;
    }

    /// Charge a row-migration transfer at a re-plan barrier: the
    /// gained rows' x/KV state moves point-to-point before the next
    /// interval starts (conservative — see `sched::replan`).
    pub fn charge_migration(&mut self, comm: &CommConfig, bytes: u64) {
        if bytes == 0 {
            return;
        }
        let cost = p2p_cost(comm, bytes as usize);
        self.now += cost;
        self.comm_s += cost;
    }

    /// Finalize into a [`Timeline`]; idle/utilization are reported
    /// over `plan`'s included devices (for adaptive runs: the initial
    /// plan, so a mid-flight exclusion shows up as idle time).
    pub fn finish(&self, plan: &Plan) -> Timeline {
        let n = self.busy.len();
        let included: Vec<usize> = plan
            .devices
            .iter()
            .filter(|d| d.included())
            .map(|d| d.device)
            .collect();
        let now = self.now;
        let idle: Vec<f64> = (0..n)
            .map(|i| {
                if plan.devices[i].included() {
                    (now - self.busy[i]).max(0.0)
                } else {
                    0.0
                }
            })
            .collect();
        let util = if included.is_empty() || now <= 0.0 {
            0.0
        } else {
            included.iter().map(|&i| self.busy[i] / now).sum::<f64>()
                / included.len() as f64
        };
        Timeline {
            total_s: now,
            busy_s: self.busy.clone(),
            idle_s: idle,
            comm_s: self.comm_s,
            utilization: util,
        }
    }
}

/// Advance the virtual clock by `n_syncs` sync intervals of `plan`
/// from `st`'s position. With `drift`, each device's per-step time
/// follows the occupancy schedule at its own executed-step index;
/// without, this is arithmetic-identical to the original whole-plan
/// loop (the static `simulate` is a single full-length span).
pub fn simulate_span(
    plan: &Plan,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
    drift: Option<DriftCtx<'_>>,
    st: &mut SimState,
    n_syncs: usize,
) -> Result<()> {
    let n = plan.devices.len();
    if cluster.len() != n || st.cursor.len() != n {
        return Err(Error::Sched("cluster/plan size mismatch".into()));
    }
    if let Some((_, map)) = drift {
        if map.len() != n {
            return Err(Error::Sched(format!(
                "drift map names {} devices, plan has {n}",
                map.len()
            )));
        }
    }
    let included: Vec<usize> = plan
        .devices
        .iter()
        .filter(|d| d.included())
        .map(|d| d.device)
        .collect();

    // Per-device byte sizes exchanged at syncs.
    let x_bytes: Vec<usize> = plan
        .devices
        .iter()
        .map(|d| d.rows.rows * model.latent_w * model.latent_c * 4)
        .collect();
    let kv_bytes: Vec<usize> = plan
        .devices
        .iter()
        .map(|d| {
            model.layers
                * model.tokens_for_rows(d.rows.rows)
                * 2
                * model.dim
                * 4
        })
        .collect();
    let x_sizes: Vec<usize> =
        included.iter().map(|&i| x_bytes[i]).collect();
    let kv_sizes: Vec<usize> =
        included.iter().map(|&i| kv_bytes[i]).collect();

    for _ in 0..n_syncs {
        let si = st.synced;
        if si >= plan.sync_points.len() {
            return Err(Error::Sched("span past the last sync".into()));
        }
        let mut arrivals = Vec::with_capacity(included.len());
        let mut min_compute = f64::INFINITY;
        let mut is_warmup_interval = false;
        for &di in &included {
            let dp = &plan.devices[di];
            let mut t_dev = 0.0;
            loop {
                let step = dp.steps.get(st.cursor[di]).ok_or_else(|| {
                    Error::Sched("step underrun in timeline".into())
                })?;
                t_dev += match drift {
                    None => cluster[di].step_time(dp.rows.rows),
                    Some((sched, map)) => {
                        let v = sched.speed_at(
                            &cluster[di],
                            map[di],
                            st.steps_done[di],
                        );
                        cluster[di].cost.step_time(dp.rows.rows, v)
                    }
                };
                st.cursor[di] += 1;
                st.steps_done[di] += 1;
                if step.is_warmup {
                    is_warmup_interval = true;
                }
                if step.sync {
                    break;
                }
            }
            st.busy[di] += t_dev;
            min_compute = min_compute.min(t_dev);
            arrivals.push(t_dev);
        }
        // Async KV debt from the previous interval masks under this
        // interval's *minimum* compute (the first device to finish is
        // the one that could be blocked by unfinished transfers).
        let unmasked = (st.kv_debt - min_compute).max(0.0);
        st.comm_s += unmasked;

        let barrier = arrivals.iter().cloned().fold(0.0, f64::max);
        let x_cost = all_gather_cost(comm, &x_sizes);
        st.comm_s += x_cost;
        let mut t_interval = barrier + unmasked + x_cost;
        if is_warmup_interval || si == plan.sync_points.len() - 1 {
            // Warmup: synchronous KV exchange (blocking). The final
            // interval cannot mask trailing publishes either.
            let kv_cost = all_gather_cost(comm, &kv_sizes);
            st.comm_s += kv_cost;
            t_interval += kv_cost;
            st.kv_debt = 0.0;
        } else {
            st.kv_debt = all_gather_cost(comm, &kv_sizes);
        }
        st.now += t_interval;
        st.synced += 1;
    }
    Ok(())
}

/// Simulate a STADI/patch-parallel plan.
pub fn simulate(
    plan: &Plan,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
) -> Result<Timeline> {
    let mut st = SimState::new(plan.devices.len());
    simulate_span(
        plan,
        cluster,
        comm,
        model,
        None,
        &mut st,
        plan.sync_points.len(),
    )?;
    Ok(st.finish(plan))
}

/// Replay a *frozen* plan under an injected occupancy drift: the
/// baseline the mid-flight re-planner is measured against. `map`
/// names each local device's global id in the schedule.
pub fn simulate_under_drift(
    plan: &Plan,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
    sched: &OccupancySchedule,
    map: &[usize],
) -> Result<Timeline> {
    let mut st = SimState::new(plan.devices.len());
    simulate_span(
        plan,
        cluster,
        comm,
        model,
        Some((sched, map)),
        &mut st,
        plan.sync_points.len(),
    )?;
    Ok(st.finish(plan))
}

/// Latency of the tensor-parallelism baseline (paper §V baselines):
/// every device computes 1/n of every layer's FLOPs, bounded by the
/// slowest device, with a synchronous all-reduce per layer (2 per
/// block: attention output + MLP output) every step.
pub fn simulate_tensor_parallel(
    m_steps: usize,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
) -> Timeline {
    let n = cluster.len();
    let act_bytes = model.tokens_full * model.dim * 4;
    let reduces_per_step = 2 * model.layers;
    // Weight-split compute: the row-proportional FLOPs divide n ways,
    // but the *fixed* per-step cost (kernel dispatch, small-GEMM
    // inefficiency) stays per-device — splitting a layer does not
    // shrink its launch overhead, which is a big part of why TP
    // underperforms on diffusion models (paper §II-B "inefficient ...
    // due to large activations overhead" + per-layer sync).
    let slowest: f64 = cluster
        .iter()
        .map(|g| {
            (g.cost.fixed_s
                + g.cost.per_row_s * model.latent_h as f64 / n as f64)
                / g.effective_speed()
        })
        .fold(0.0, f64::max);
    let comm_per_step =
        reduces_per_step as f64 * all_reduce_cost(comm, act_bytes, n);
    let step = slowest + comm_per_step;
    let total = m_steps as f64 * step;
    let busy: Vec<f64> = cluster
        .iter()
        .map(|g| {
            m_steps as f64
                * (g.cost.fixed_s
                    + g.cost.per_row_s * model.latent_h as f64 / n as f64)
                / g.effective_speed()
        })
        .collect();
    let idle: Vec<f64> = busy.iter().map(|b| (total - b).max(0.0)).collect();
    let util =
        busy.iter().map(|b| b / total).sum::<f64>() / n.max(1) as f64;
    Timeline {
        total_s: total,
        busy_s: busy,
        idle_s: idle,
        comm_s: m_steps as f64 * comm_per_step,
        utilization: util,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CommConfig, DeviceConfig, StadiParams};
    use crate::device::{build_cluster, CostModel};
    use crate::model::schedule::Schedule;

    fn model() -> ModelInfo {
        ModelInfo {
            latent_h: 32, latent_w: 32, latent_c: 4, patch: 2, dim: 96,
            heads: 4, layers: 3, temb_dim: 64, row_granularity: 4,
            tokens_full: 256, param_count: 1, params_seed: 0,
        }
    }

    fn cluster(occ: &[f64]) -> Vec<SimGpu> {
        let devs: Vec<DeviceConfig> = occ
            .iter()
            .enumerate()
            .map(|(i, &o)| DeviceConfig::new(format!("g{i}"), 1.0, o))
            .collect();
        build_cluster(&devs, CostModel { fixed_s: 0.004, per_row_s: 0.0012 })
    }

    fn build_plan(speeds: &[f64], p: &StadiParams) -> Plan {
        let s = Schedule::scaled_linear(1000, 0.00085, 0.012);
        let names: Vec<String> =
            (0..speeds.len()).map(|i| format!("g{i}")).collect();
        Plan::build(&s, speeds, &names, p, 32, 4).unwrap()
    }

    #[test]
    fn homogeneous_cluster_has_high_utilization() {
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 1.0], &p);
        let tl = simulate(&plan, &cluster(&[0.0, 0.0]),
                          &CommConfig::default(), &model()).unwrap();
        assert!(tl.utilization > 0.9, "util {}", tl.utilization);
        assert!(tl.total_s > 0.0);
    }

    #[test]
    fn straggler_hurts_patch_parallelism_more_than_stadi() {
        // The paper's core claim in miniature.
        let speeds = [1.0, 0.4];
        let cl = cluster(&[0.0, 0.6]);
        let m = model();
        let comm = CommConfig::default();

        let mut pp = StadiParams::default();
        pp.temporal = false;
        pp.spatial = false;
        let t_pp =
            simulate(&build_plan(&speeds, &pp), &cl, &comm, &m).unwrap();

        let stadi = StadiParams::default();
        let t_st =
            simulate(&build_plan(&speeds, &stadi), &cl, &comm, &m).unwrap();

        assert!(
            t_st.total_s < t_pp.total_s * 0.8,
            "stadi {} vs pp {}",
            t_st.total_s,
            t_pp.total_s
        );
        assert!(t_st.utilization > t_pp.utilization);
    }

    #[test]
    fn idle_plus_busy_equals_total_for_included() {
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 0.5], &p);
        let tl = simulate(&plan, &cluster(&[0.0, 0.5]),
                          &CommConfig::default(), &model()).unwrap();
        for i in 0..2 {
            assert!(
                (tl.busy_s[i] + tl.idle_s[i] - tl.total_s).abs() < 1e-9
            );
        }
    }

    #[test]
    fn tensor_parallel_pays_per_layer_reduces() {
        let m = model();
        let cl = cluster(&[0.0, 0.0]);
        let comm = CommConfig::default();
        let tl = simulate_tensor_parallel(100, &cl, &comm, &m);
        assert!(tl.comm_s > 0.0);
        // 100 steps, 6 reduces each.
        let per_reduce = all_reduce_cost(&comm, 256 * 96 * 4, 2);
        assert!((tl.comm_s - 600.0 * per_reduce).abs() < 1e-9);
    }

    #[test]
    fn property_latency_monotone_in_occupancy_and_stadi_dominates() {
        use crate::util::proptest::{ensure, forall};
        let m = model();
        let comm = CommConfig::default();
        forall(
            41,
            150,
            |rng| (rng.next_f64() * 0.7, rng.next_f64() * 0.7),
            |&(o1, o2)| {
                let (lo, hi) = if o1 <= o2 { (o1, o2) } else { (o2, o1) };
                let p = StadiParams::default();
                // PP latency must not decrease when the straggler gets
                // busier.
                let mut pp = p.clone();
                pp.temporal = false;
                pp.spatial = false;
                let plan = build_plan(&[1.0, 1.0], &pp);
                let t_lo = simulate(&plan, &cluster(&[0.0, lo]), &comm, &m)
                    .map_err(|e| e.to_string())?;
                let t_hi = simulate(&plan, &cluster(&[0.0, hi]), &comm, &m)
                    .map_err(|e| e.to_string())?;
                ensure(
                    t_hi.total_s >= t_lo.total_s - 1e-9,
                    format!("monotonicity: {} < {}", t_hi.total_s, t_lo.total_s),
                )?;
                // STADI never loses to PP on the same cluster.
                let speeds = [1.0, 1.0 - hi];
                let stadi = match Plan::build(
                    &Schedule::scaled_linear(1000, 0.00085, 0.012),
                    &speeds,
                    &["g0".into(), "g1".into()],
                    &p,
                    32,
                    4,
                ) {
                    Ok(pl) => pl,
                    Err(_) => return Ok(()),
                };
                let t_st =
                    simulate(&stadi, &cluster(&[0.0, hi]), &comm, &m)
                        .map_err(|e| e.to_string())?;
                ensure(
                    t_st.total_s <= t_hi.total_s + 1e-9,
                    format!("stadi {} > pp {}", t_st.total_s, t_hi.total_s),
                )?;
                Ok(())
            },
        );
    }

    #[test]
    fn segmented_spans_match_the_whole_run_bit_exactly() {
        // The adaptive loop's segment partitioning must not move a
        // single float: state carries the clock, busy totals and
        // async-KV debt across span boundaries.
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 0.5], &p);
        let cl = cluster(&[0.0, 0.5]);
        let comm = CommConfig::default();
        let m = model();
        let whole = simulate(&plan, &cl, &comm, &m).unwrap();
        let mut st = SimState::new(2);
        let total = plan.sync_points.len();
        let mut done = 0;
        for span in [1usize, 4, 7, 2] {
            let span = span.min(total - done);
            simulate_span(&plan, &cl, &comm, &m, None, &mut st, span)
                .unwrap();
            done += span;
        }
        simulate_span(&plan, &cl, &comm, &m, None, &mut st, total - done)
            .unwrap();
        let seg = st.finish(&plan);
        assert_eq!(whole.total_s, seg.total_s);
        assert_eq!(whole.busy_s, seg.busy_s);
        assert_eq!(whole.comm_s, seg.comm_s);
        // Running past the end is a typed error, not a panic.
        let e = simulate_span(&plan, &cl, &comm, &m, None, &mut st, 1);
        assert!(e.is_err());
    }

    #[test]
    fn drift_slows_the_frozen_plan_and_constant_drift_is_identity() {
        use crate::device::OccupancySchedule;
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 1.0], &p);
        let cl = cluster(&[0.0, 0.0]);
        let comm = CommConfig::default();
        let m = model();
        let base = simulate(&plan, &cl, &comm, &m).unwrap();
        // A schedule pinning every device at its config occupancy is
        // the identity — same floats, not merely close.
        let flat = OccupancySchedule::parse("0@0;0@0").unwrap();
        let same =
            simulate_under_drift(&plan, &cl, &comm, &m, &flat, &[0, 1])
                .unwrap();
        assert_eq!(base.total_s, same.total_s);
        assert_eq!(base.busy_s, same.busy_s);
        // A mid-run ramp on device 1 strictly slows the frozen plan.
        let ramp = OccupancySchedule::parse("0@0;0@0,0.6@10").unwrap();
        let slow =
            simulate_under_drift(&plan, &cl, &comm, &m, &ramp, &[0, 1])
                .unwrap();
        assert!(slow.total_s > base.total_s * 1.2, "{}", slow.total_s);
        // The drift key is the *global* id through the map: remapping
        // device 1 to a flat schedule entry restores the baseline.
        let remapped =
            simulate_under_drift(&plan, &cl, &comm, &m, &ramp, &[0, 0])
                .unwrap();
        assert_eq!(base.total_s, remapped.total_s);
    }

    #[test]
    fn migration_charge_advances_clock_and_comm() {
        let comm = CommConfig::default();
        let mut st = SimState::new(2);
        st.charge_migration(&comm, 0);
        assert_eq!(st.now, 0.0);
        st.charge_migration(&comm, 1 << 20);
        assert!(st.now > 0.0);
        assert_eq!(st.now, st.comm_s);
    }

    #[test]
    fn deterministic() {
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 0.33], &p);
        let cl = cluster(&[0.0, 0.67]);
        let a = simulate(&plan, &cl, &CommConfig::default(), &model())
            .unwrap();
        let b = simulate(&plan, &cl, &CommConfig::default(), &model())
            .unwrap();
        assert_eq!(a.total_s, b.total_s);
        assert_eq!(a.busy_s, b.busy_s);
    }
}
