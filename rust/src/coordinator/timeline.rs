//! Timeline simulation: latency of a plan on a heterogeneous cluster.
//!
//! Replays the plan's sync-interval structure on a virtual clock with
//! calibrated per-step costs (DESIGN.md §4 "sim" mode) — single-core-
//! safe and deterministic, used for Figs. 2/8/9 and Table III.
//!
//! Model per sync interval (the span between consecutive sync points):
//! every included device runs its interval steps back-to-back
//! (1 for slow/warmup devices, up to 2 for fast devices); the sync
//! point completes when the last device arrives, then pays the
//! synchronous x all-gather. Warmup intervals also pay the KV exchange
//! synchronously (Alg. 1 line 11 "Update buffer synchronously");
//! afterwards KV publishes are asynchronous and overlap with the next
//! interval's compute, charging only their unmasked remainder — the
//! paper's "mask communication latency within computation".
//!
//! Displaced halo mode ([`HaloMode::Displaced`]) generalizes the
//! async-KV masking to the x exchange: a non-fallback sync publishes
//! both x and KV without blocking, and the transfer cost joins a
//! deadline-FIFO *debt queue*. Each subsequent interval drains the
//! queue under its minimum compute time (the transfer rides behind
//! whichever device finishes first); a debt that reaches its deadline
//! — the sync interval whose consumers need the data, `publish +
//! max_staleness` — surfaces its remainder as blocking comm. The
//! synchronous path is the single-entry, deadline-next-interval
//! special case of the same queue, float-identical to the original
//! arithmetic.

use crate::comm::{
    all_gather_cost, all_reduce_cost, displaced_exchange_cost, p2p_cost,
};
use crate::config::{CommConfig, HaloMode};
use crate::device::{OccupancySchedule, SimGpu};
use crate::error::{Error, Result};
use crate::runtime::artifacts::ModelInfo;
use crate::sched::plan::Plan;

/// Simulated latency breakdown.
#[derive(Debug, Clone)]
pub struct Timeline {
    /// End-to-end request latency (seconds, virtual).
    pub total_s: f64,
    /// Per-device compute-busy seconds.
    pub busy_s: Vec<f64>,
    /// Per-device idle seconds (waiting at sync points).
    pub idle_s: Vec<f64>,
    /// Blocking communication seconds on the critical path.
    pub comm_s: f64,
    /// Per-device transfer seconds hidden *behind* compute (async KV
    /// and displaced halo exchanges that never surfaced).
    pub overlap_s: Vec<f64>,
    /// Sync intervals that ran the displaced (non-blocking) exchange.
    pub halo_displaced: usize,
    /// Sync intervals that ran the blocking exchange (every interval
    /// under `HaloMode::Sync`).
    pub halo_fallback: usize,
    /// Mean utilization of included devices: busy / total.
    pub utilization: f64,
}

/// A drift source for the virtual clock: the deterministic occupancy
/// schedule plus the *global* device id of each local cluster index
/// (identity for whole-cluster runs, the lease map for gang sessions —
/// the schedule describes the fleet, not the gang).
pub type DriftCtx<'a> = (&'a OccupancySchedule, &'a [usize]);

/// Resumable virtual-clock state, so the adaptive execution loop can
/// simulate a request as a sequence of plan segments (re-plans switch
/// plans mid-request; the clock, per-device busy totals, async-KV debt
/// and drift step counters all carry across the switch).
#[derive(Debug, Clone)]
pub struct SimState {
    /// Per-device step cursor within the current plan.
    pub cursor: Vec<usize>,
    /// Per-device executed-step counters (the drift-schedule key);
    /// persist across plan switches.
    pub steps_done: Vec<usize>,
    /// Per-device compute-busy seconds.
    pub busy: Vec<f64>,
    /// Virtual clock.
    pub now: f64,
    /// Blocking communication seconds so far.
    pub comm_s: f64,
    /// Outstanding async-transfer debts, FIFO by publish order: each
    /// entry is `(deadline, remaining_s)` where `deadline` is the
    /// plan-local sync index by which the transfer must complete
    /// (consumers read the data there); remainders surface as blocking
    /// comm at the deadline. The synchronous path keeps at most one
    /// entry (the async-KV publish, deadline = next interval).
    pub debts: Vec<(usize, f64)>,
    /// Sync points completed within the current plan.
    pub synced: usize,
    /// Per-device transfer seconds hidden behind compute.
    pub overlap_s: Vec<f64>,
    /// Displaced / blocking exchange counters (see [`Timeline`]).
    pub halo_displaced: usize,
    pub halo_fallback: usize,
}

impl SimState {
    pub fn new(n: usize) -> Self {
        SimState {
            cursor: vec![0; n],
            steps_done: vec![0; n],
            busy: vec![0.0; n],
            now: 0.0,
            comm_s: 0.0,
            debts: Vec::new(),
            synced: 0,
            overlap_s: vec![0.0; n],
            halo_displaced: 0,
            halo_fallback: 0,
        }
    }

    /// A virtual clock resumed from a migrated checkpoint: the
    /// destination starts `elapsed_s` into the request's wall time
    /// (the sender's `now` at the handoff barrier, prefix comm
    /// included) with `comm_s` of that already attributed to
    /// communication. Per-device busy/overlap counters start at zero —
    /// utilization reports describe the destination span only; the
    /// makespan (`now`) spans the whole request.
    pub fn resumed(n: usize, elapsed_s: f64, comm_s: f64) -> Self {
        let mut st = SimState::new(n);
        st.now = elapsed_s;
        st.comm_s = comm_s;
        st
    }

    /// Switch to a re-planned continuation: per-plan positions reset,
    /// clocks and drift counters persist. Outstanding transfer debts
    /// survive the switch with their deadlines rebased into the new
    /// plan's sync coordinates (a deadline at or before the barrier
    /// becomes 0 — overdue, charged at the next interval).
    pub fn switch_plan(&mut self) {
        for c in self.cursor.iter_mut() {
            *c = 0;
        }
        for e in self.debts.iter_mut() {
            e.0 = e.0.saturating_sub(self.synced);
        }
        self.synced = 0;
    }

    /// Drop outstanding transfer debts and charge them as blocking
    /// comm *now* — the timeline side of a halo invalidation (a
    /// re-plan under displaced halos migrates rows, so published
    /// halos for them are void and a fresh blocking exchange runs).
    pub fn flush_debts(&mut self) {
        let due: f64 = self.debts.iter().map(|&(_, r)| r).sum();
        self.debts.clear();
        if due > 0.0 {
            self.now += due;
            self.comm_s += due;
        }
    }

    /// Charge the blocking full exchange a halo invalidation runs at a
    /// re-plan barrier (fresh x patches and KV blocks for `plan`'s —
    /// the *outgoing* plan's — row ownership).
    pub fn charge_refresh(
        &mut self,
        comm: &CommConfig,
        plan: &Plan,
        model: &ModelInfo,
    ) {
        let included: Vec<&crate::sched::plan::DevicePlan> =
            plan.included_devices().collect();
        let x_sizes: Vec<usize> = included
            .iter()
            .map(|d| d.rows.rows * model.latent_w * model.latent_c * 4)
            .collect();
        let kv_sizes: Vec<usize> = included
            .iter()
            .map(|d| {
                model.layers
                    * model.tokens_for_rows(d.rows.rows)
                    * 2
                    * model.dim
                    * 4
            })
            .collect();
        let cost = all_gather_cost(comm, &x_sizes)
            + all_gather_cost(comm, &kv_sizes);
        self.now += cost;
        self.comm_s += cost;
    }

    /// Charge a row-migration transfer at a re-plan barrier: the
    /// gained rows' x/KV state moves point-to-point before the next
    /// interval starts (conservative — see `sched::replan`).
    pub fn charge_migration(&mut self, comm: &CommConfig, bytes: u64) {
        if bytes == 0 {
            return;
        }
        let cost = p2p_cost(comm, bytes as usize);
        self.now += cost;
        self.comm_s += cost;
    }

    /// Finalize into a [`Timeline`]; idle/utilization are reported
    /// over `plan`'s included devices (for adaptive runs: the initial
    /// plan, so a mid-flight exclusion shows up as idle time).
    pub fn finish(&self, plan: &Plan) -> Timeline {
        let n = self.busy.len();
        let included: Vec<usize> = plan
            .devices
            .iter()
            .filter(|d| d.included())
            .map(|d| d.device)
            .collect();
        let now = self.now;
        let idle: Vec<f64> = (0..n)
            .map(|i| {
                if plan.devices[i].included() {
                    (now - self.busy[i]).max(0.0)
                } else {
                    0.0
                }
            })
            .collect();
        let util = if included.is_empty() || now <= 0.0 {
            0.0
        } else {
            included.iter().map(|&i| self.busy[i] / now).sum::<f64>()
                / included.len() as f64
        };
        Timeline {
            total_s: now,
            busy_s: self.busy.clone(),
            idle_s: idle,
            comm_s: self.comm_s,
            overlap_s: self.overlap_s.clone(),
            halo_displaced: self.halo_displaced,
            halo_fallback: self.halo_fallback,
            utilization: util,
        }
    }
}

/// Advance the virtual clock by `n_syncs` sync intervals of `plan`
/// from `st`'s position. With `drift`, each device's per-step time
/// follows the occupancy schedule at its own executed-step index;
/// without, this is arithmetic-identical to the original whole-plan
/// loop (the static `simulate` is a single full-length span). `halo`
/// selects the exchange model: `Sync` blocks on the x all-gather at
/// every sync point, `Displaced` queues non-fallback exchanges as
/// deadline debts that drain behind later compute (see module docs).
#[allow(clippy::too_many_arguments)]
pub fn simulate_span(
    plan: &Plan,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
    drift: Option<DriftCtx<'_>>,
    st: &mut SimState,
    n_syncs: usize,
    halo: HaloMode,
) -> Result<()> {
    let n = plan.devices.len();
    if cluster.len() != n || st.cursor.len() != n {
        return Err(Error::Sched("cluster/plan size mismatch".into()));
    }
    if let Some((_, map)) = drift {
        if map.len() != n {
            return Err(Error::Sched(format!(
                "drift map names {} devices, plan has {n}",
                map.len()
            )));
        }
    }
    let included: Vec<usize> = plan
        .devices
        .iter()
        .filter(|d| d.included())
        .map(|d| d.device)
        .collect();

    // Per-device byte sizes exchanged at syncs.
    let x_bytes: Vec<usize> = plan
        .devices
        .iter()
        .map(|d| d.rows.rows * model.latent_w * model.latent_c * 4)
        .collect();
    let kv_bytes: Vec<usize> = plan
        .devices
        .iter()
        .map(|d| {
            model.layers
                * model.tokens_for_rows(d.rows.rows)
                * 2
                * model.dim
                * 4
        })
        .collect();
    let x_sizes: Vec<usize> =
        included.iter().map(|&i| x_bytes[i]).collect();
    let kv_sizes: Vec<usize> =
        included.iter().map(|&i| kv_bytes[i]).collect();

    let budget = halo.max_staleness();
    for _ in 0..n_syncs {
        let si = st.synced;
        if si >= plan.sync_points.len() {
            return Err(Error::Sched("span past the last sync".into()));
        }
        let mut arrivals = Vec::with_capacity(included.len());
        let mut min_compute = f64::INFINITY;
        let mut is_warmup_interval = false;
        for &di in &included {
            let dp = &plan.devices[di];
            let mut t_dev = 0.0;
            loop {
                let step = dp.steps.get(st.cursor[di]).ok_or_else(|| {
                    Error::Sched("step underrun in timeline".into())
                })?;
                t_dev += match drift {
                    None => cluster[di].step_time(dp.rows.rows),
                    Some((sched, map)) => {
                        let v = sched.speed_at(
                            &cluster[di],
                            map[di],
                            st.steps_done[di],
                        );
                        cluster[di].cost.step_time(dp.rows.rows, v)
                    }
                };
                st.cursor[di] += 1;
                st.steps_done[di] += 1;
                if step.is_warmup {
                    is_warmup_interval = true;
                }
                if step.sync {
                    break;
                }
            }
            st.busy[di] += t_dev;
            min_compute = min_compute.min(t_dev);
            arrivals.push((di, t_dev));
        }
        // Outstanding transfer debts mask under this interval's
        // *minimum* compute (the first device to finish is the one
        // that could be blocked by unfinished transfers). Per-device
        // overlap accounting: each device hides up to its own compute
        // time of the outstanding transfers.
        let outstanding: f64 = st.debts.iter().map(|&(_, r)| r).sum();
        if outstanding > 0.0 {
            for &(di, t_dev) in &arrivals {
                st.overlap_s[di] += t_dev.min(outstanding);
            }
        }
        let mut drain = min_compute;
        for e in st.debts.iter_mut() {
            if drain <= 0.0 {
                break;
            }
            let d = e.1.min(drain);
            e.1 -= d;
            drain -= d;
        }
        // Debts at (or past) their deadline surface their remainder
        // as blocking comm; the final interval flushes everything
        // (trailing publishes cannot hide behind future compute).
        let last = si == plan.sync_points.len() - 1;
        let mut unmasked = 0.0;
        st.debts.retain(|&(deadline, remaining)| {
            if remaining <= 0.0 {
                return false;
            }
            if deadline <= si || last {
                unmasked += remaining;
                return false;
            }
            true
        });
        st.comm_s += unmasked;

        let barrier =
            arrivals.iter().map(|&(_, t)| t).fold(0.0, f64::max);
        let fallback =
            !halo.is_displaced() || plan.displaced_fallback(si, budget);
        if fallback {
            st.halo_fallback += 1;
            let x_cost = all_gather_cost(comm, &x_sizes);
            st.comm_s += x_cost;
            let mut t_interval = barrier + unmasked + x_cost;
            if is_warmup_interval || last {
                // Warmup: synchronous KV exchange (blocking). The
                // final interval cannot mask trailing publishes
                // either.
                let kv_cost = all_gather_cost(comm, &kv_sizes);
                st.comm_s += kv_cost;
                t_interval += kv_cost;
            } else {
                st.debts
                    .push((si + 1, all_gather_cost(comm, &kv_sizes)));
            }
            st.now += t_interval;
        } else {
            // Displaced: publish x and KV without blocking. Consumers
            // read this interval's halos at most `budget` syncs later,
            // so the transfer must land by then — queue it with that
            // deadline. Priced by the same α+β model as the blocking
            // path (see `comm::displaced_exchange_cost`).
            st.halo_displaced += 1;
            let async_cost = displaced_exchange_cost(comm, &x_sizes)
                + displaced_exchange_cost(comm, &kv_sizes);
            st.debts.push((si + budget, async_cost));
            st.now += barrier + unmasked;
        }
        st.synced += 1;
    }
    Ok(())
}

/// Simulate a STADI/patch-parallel plan under the synchronous halo
/// exchange (the paper's model; wrapper over [`simulate_with`]).
pub fn simulate(
    plan: &Plan,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
) -> Result<Timeline> {
    simulate_with(plan, cluster, comm, model, HaloMode::Sync)
}

/// Simulate a plan under an explicit halo-exchange mode.
pub fn simulate_with(
    plan: &Plan,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
    halo: HaloMode,
) -> Result<Timeline> {
    let mut st = SimState::new(plan.devices.len());
    simulate_span(
        plan,
        cluster,
        comm,
        model,
        None,
        &mut st,
        plan.sync_points.len(),
        halo,
    )?;
    Ok(st.finish(plan))
}

/// Price a plan executed as a **fused batch** of `batch` compatible
/// requests in lockstep on one gang: each device's row-proportional
/// compute scales by the batch size (B stacked latents per kernel
/// launch), while the fixed per-step cost and the halo/x exchange are
/// paid once per step — the sync schedule, halo debts and barrier
/// structure are those of the single shared plan. That amortization
/// (fixed + B·per_row·rows instead of B·(fixed + per_row·rows), comm
/// once instead of B times) is the throughput lever of cross-request
/// batching; `batch == 1` is float-identical to [`simulate_with`], so
/// solo pricing is the degenerate case, not a separate code path.
pub fn simulate_batched(
    plan: &Plan,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
    halo: HaloMode,
    batch: usize,
) -> Result<Timeline> {
    if batch == 0 {
        return Err(Error::Sched("batch size must be >= 1".into()));
    }
    if batch == 1 {
        return simulate_with(plan, cluster, comm, model, halo);
    }
    let scaled =
        crate::device::scale_cluster_per_row(cluster, batch as f64);
    simulate_with(plan, &scaled, comm, model, halo)
}

/// Replay a *frozen* plan under an injected occupancy drift: the
/// baseline the mid-flight re-planner is measured against. `map`
/// names each local device's global id in the schedule.
pub fn simulate_under_drift(
    plan: &Plan,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
    sched: &OccupancySchedule,
    map: &[usize],
) -> Result<Timeline> {
    let mut st = SimState::new(plan.devices.len());
    simulate_span(
        plan,
        cluster,
        comm,
        model,
        Some((sched, map)),
        &mut st,
        plan.sync_points.len(),
        HaloMode::Sync,
    )?;
    Ok(st.finish(plan))
}

/// Latency of the tensor-parallelism baseline (paper §V baselines):
/// every device computes 1/n of every layer's FLOPs, bounded by the
/// slowest device, with a synchronous all-reduce per layer (2 per
/// block: attention output + MLP output) every step.
pub fn simulate_tensor_parallel(
    m_steps: usize,
    cluster: &[SimGpu],
    comm: &CommConfig,
    model: &ModelInfo,
) -> Timeline {
    let n = cluster.len();
    let act_bytes = model.tokens_full * model.dim * 4;
    let reduces_per_step = 2 * model.layers;
    // Weight-split compute: the row-proportional FLOPs divide n ways,
    // but the *fixed* per-step cost (kernel dispatch, small-GEMM
    // inefficiency) stays per-device — splitting a layer does not
    // shrink its launch overhead, which is a big part of why TP
    // underperforms on diffusion models (paper §II-B "inefficient ...
    // due to large activations overhead" + per-layer sync).
    let slowest: f64 = cluster
        .iter()
        .map(|g| {
            (g.cost.fixed_s
                + g.cost.per_row_s * model.latent_h as f64 / n as f64)
                / g.effective_speed()
        })
        .fold(0.0, f64::max);
    let comm_per_step =
        reduces_per_step as f64 * all_reduce_cost(comm, act_bytes, n);
    let step = slowest + comm_per_step;
    let total = m_steps as f64 * step;
    let busy: Vec<f64> = cluster
        .iter()
        .map(|g| {
            m_steps as f64
                * (g.cost.fixed_s
                    + g.cost.per_row_s * model.latent_h as f64 / n as f64)
                / g.effective_speed()
        })
        .collect();
    let idle: Vec<f64> = busy.iter().map(|b| (total - b).max(0.0)).collect();
    let util =
        busy.iter().map(|b| b / total).sum::<f64>() / n.max(1) as f64;
    Timeline {
        total_s: total,
        busy_s: busy,
        idle_s: idle,
        comm_s: m_steps as f64 * comm_per_step,
        overlap_s: vec![0.0; n],
        halo_displaced: 0,
        halo_fallback: 0,
        utilization: util,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CommConfig, DeviceConfig, StadiParams};
    use crate::device::{build_cluster, CostModel};
    use crate::model::schedule::Schedule;

    fn model() -> ModelInfo {
        ModelInfo {
            latent_h: 32, latent_w: 32, latent_c: 4, patch: 2, dim: 96,
            heads: 4, layers: 3, temb_dim: 64, row_granularity: 4,
            tokens_full: 256, param_count: 1, params_seed: 0,
        }
    }

    fn cluster(occ: &[f64]) -> Vec<SimGpu> {
        let devs: Vec<DeviceConfig> = occ
            .iter()
            .enumerate()
            .map(|(i, &o)| DeviceConfig::new(format!("g{i}"), 1.0, o))
            .collect();
        build_cluster(&devs, CostModel { fixed_s: 0.004, per_row_s: 0.0012 })
    }

    fn build_plan(speeds: &[f64], p: &StadiParams) -> Plan {
        let s = Schedule::scaled_linear(1000, 0.00085, 0.012);
        let names: Vec<String> =
            (0..speeds.len()).map(|i| format!("g{i}")).collect();
        Plan::build(&s, speeds, &names, p, 32, 4).unwrap()
    }

    #[test]
    fn homogeneous_cluster_has_high_utilization() {
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 1.0], &p);
        let tl = simulate(&plan, &cluster(&[0.0, 0.0]),
                          &CommConfig::default(), &model()).unwrap();
        assert!(tl.utilization > 0.9, "util {}", tl.utilization);
        assert!(tl.total_s > 0.0);
    }

    #[test]
    fn straggler_hurts_patch_parallelism_more_than_stadi() {
        // The paper's core claim in miniature.
        let speeds = [1.0, 0.4];
        let cl = cluster(&[0.0, 0.6]);
        let m = model();
        let comm = CommConfig::default();

        let mut pp = StadiParams::default();
        pp.temporal = false;
        pp.spatial = false;
        let t_pp =
            simulate(&build_plan(&speeds, &pp), &cl, &comm, &m).unwrap();

        let stadi = StadiParams::default();
        let t_st =
            simulate(&build_plan(&speeds, &stadi), &cl, &comm, &m).unwrap();

        assert!(
            t_st.total_s < t_pp.total_s * 0.8,
            "stadi {} vs pp {}",
            t_st.total_s,
            t_pp.total_s
        );
        assert!(t_st.utilization > t_pp.utilization);
    }

    #[test]
    fn idle_plus_busy_equals_total_for_included() {
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 0.5], &p);
        let tl = simulate(&plan, &cluster(&[0.0, 0.5]),
                          &CommConfig::default(), &model()).unwrap();
        for i in 0..2 {
            assert!(
                (tl.busy_s[i] + tl.idle_s[i] - tl.total_s).abs() < 1e-9
            );
        }
    }

    #[test]
    fn tensor_parallel_pays_per_layer_reduces() {
        let m = model();
        let cl = cluster(&[0.0, 0.0]);
        let comm = CommConfig::default();
        let tl = simulate_tensor_parallel(100, &cl, &comm, &m);
        assert!(tl.comm_s > 0.0);
        // 100 steps, 6 reduces each.
        let per_reduce = all_reduce_cost(&comm, 256 * 96 * 4, 2);
        assert!((tl.comm_s - 600.0 * per_reduce).abs() < 1e-9);
    }

    #[test]
    fn property_latency_monotone_in_occupancy_and_stadi_dominates() {
        use crate::util::proptest::{ensure, forall};
        let m = model();
        let comm = CommConfig::default();
        forall(
            41,
            150,
            |rng| (rng.next_f64() * 0.7, rng.next_f64() * 0.7),
            |&(o1, o2)| {
                let (lo, hi) = if o1 <= o2 { (o1, o2) } else { (o2, o1) };
                let p = StadiParams::default();
                // PP latency must not decrease when the straggler gets
                // busier.
                let mut pp = p.clone();
                pp.temporal = false;
                pp.spatial = false;
                let plan = build_plan(&[1.0, 1.0], &pp);
                let t_lo = simulate(&plan, &cluster(&[0.0, lo]), &comm, &m)
                    .map_err(|e| e.to_string())?;
                let t_hi = simulate(&plan, &cluster(&[0.0, hi]), &comm, &m)
                    .map_err(|e| e.to_string())?;
                ensure(
                    t_hi.total_s >= t_lo.total_s - 1e-9,
                    format!("monotonicity: {} < {}", t_hi.total_s, t_lo.total_s),
                )?;
                // STADI never loses to PP on the same cluster.
                let speeds = [1.0, 1.0 - hi];
                let stadi = match Plan::build(
                    &Schedule::scaled_linear(1000, 0.00085, 0.012),
                    &speeds,
                    &["g0".into(), "g1".into()],
                    &p,
                    32,
                    4,
                ) {
                    Ok(pl) => pl,
                    Err(_) => return Ok(()),
                };
                let t_st =
                    simulate(&stadi, &cluster(&[0.0, hi]), &comm, &m)
                        .map_err(|e| e.to_string())?;
                ensure(
                    t_st.total_s <= t_hi.total_s + 1e-9,
                    format!("stadi {} > pp {}", t_st.total_s, t_hi.total_s),
                )?;
                Ok(())
            },
        );
    }

    #[test]
    fn batched_pricing_amortizes_fixed_cost_and_comm() {
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 0.5], &p);
        let cl = cluster(&[0.0, 0.5]);
        let comm = CommConfig::default();
        let m = model();
        let solo = simulate_with(&plan, &cl, &comm, &m, HaloMode::Sync)
            .unwrap();
        // Batch of 1 is the solo path, bit-exact.
        let b1 = simulate_batched(&plan, &cl, &comm, &m, HaloMode::Sync, 1)
            .unwrap();
        assert_eq!(b1.total_s.to_bits(), solo.total_s.to_bits());
        assert_eq!(b1.comm_s.to_bits(), solo.comm_s.to_bits());
        // A batch of B serves B requests in strictly less than B solo
        // runs (fixed per-step cost and the exchange are paid once),
        // but strictly more than one (the per-row work is real).
        for b in [2usize, 4, 8] {
            let tb =
                simulate_batched(&plan, &cl, &comm, &m, HaloMode::Sync, b)
                    .unwrap();
            assert!(
                tb.total_s < b as f64 * solo.total_s,
                "batch {b}: {} !< {}",
                tb.total_s,
                b as f64 * solo.total_s
            );
            assert!(tb.total_s > solo.total_s, "batch {b} not slower");
            // Comm is per-plan, not per-member.
            assert!((tb.comm_s - solo.comm_s).abs() < 1e-12);
        }
        // Per-request amortized latency improves monotonically in B.
        let per = |b: usize| {
            simulate_batched(&plan, &cl, &comm, &m, HaloMode::Sync, b)
                .unwrap()
                .total_s
                / b as f64
        };
        assert!(per(2) < per(1) && per(4) < per(2) && per(8) < per(4));
        // Batch 0 is a typed error.
        assert!(
            simulate_batched(&plan, &cl, &comm, &m, HaloMode::Sync, 0)
                .is_err()
        );
    }

    #[test]
    fn segmented_spans_match_the_whole_run_bit_exactly() {
        // The adaptive loop's segment partitioning must not move a
        // single float: state carries the clock, busy totals and
        // async-KV debt across span boundaries.
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 0.5], &p);
        let cl = cluster(&[0.0, 0.5]);
        let comm = CommConfig::default();
        let m = model();
        let whole = simulate(&plan, &cl, &comm, &m).unwrap();
        let mut st = SimState::new(2);
        let total = plan.sync_points.len();
        let mut done = 0;
        for span in [1usize, 4, 7, 2] {
            let span = span.min(total - done);
            simulate_span(
                &plan,
                &cl,
                &comm,
                &m,
                None,
                &mut st,
                span,
                HaloMode::Sync,
            )
            .unwrap();
            done += span;
        }
        simulate_span(
            &plan,
            &cl,
            &comm,
            &m,
            None,
            &mut st,
            total - done,
            HaloMode::Sync,
        )
        .unwrap();
        let seg = st.finish(&plan);
        assert_eq!(whole.total_s, seg.total_s);
        assert_eq!(whole.busy_s, seg.busy_s);
        assert_eq!(whole.comm_s, seg.comm_s);
        // Running past the end is a typed error, not a panic.
        let e = simulate_span(
            &plan,
            &cl,
            &comm,
            &m,
            None,
            &mut st,
            1,
            HaloMode::Sync,
        );
        assert!(e.is_err());
    }

    #[test]
    fn displaced_budget_zero_is_float_identical_to_sync() {
        // Budget 0 ≡ sync: every interval falls back, so the queue
        // degenerates to today's single-entry arithmetic — same
        // floats, same counters.
        let p = StadiParams::default();
        for speeds in [[1.0, 1.0], [1.0, 0.5], [1.0, 0.33]] {
            let plan = build_plan(&speeds, &p);
            let cl = cluster(&[0.0, 1.0 - speeds[1]]);
            let comm = CommConfig::default();
            let m = model();
            let sync = simulate(&plan, &cl, &comm, &m).unwrap();
            let disp = simulate_with(
                &plan,
                &cl,
                &comm,
                &m,
                HaloMode::Displaced { max_staleness: 0 },
            )
            .unwrap();
            assert_eq!(sync.total_s, disp.total_s);
            assert_eq!(sync.busy_s, disp.busy_s);
            assert_eq!(sync.comm_s, disp.comm_s);
            assert_eq!(sync.idle_s, disp.idle_s);
            assert_eq!(sync.overlap_s, disp.overlap_s);
            assert_eq!(sync.halo_displaced, disp.halo_displaced);
            assert_eq!(sync.halo_fallback, disp.halo_fallback);
            assert_eq!(sync.halo_displaced, 0);
            assert_eq!(sync.halo_fallback, plan.sync_points.len());
        }
    }

    /// A slow-interconnect config where the sync exchange is
    /// comm-bound (the x gather is a large fraction of each interval).
    fn slow_comm() -> CommConfig {
        CommConfig {
            latency_s: 0.02,
            bandwidth_bytes_per_s: 2e7,
            uneven_strategy: crate::config::UnevenStrategy::PadAllGather,
        }
    }

    #[test]
    fn displaced_beats_sync_on_comm_bound_cluster() {
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 0.5], &p);
        let cl = cluster(&[0.0, 0.5]);
        let comm = slow_comm();
        let m = model();
        let sync = simulate(&plan, &cl, &comm, &m).unwrap();
        // Comm-bound under sync: blocking comm is a real fraction.
        assert!(
            sync.comm_s > 0.2 * sync.total_s,
            "fixture not comm-bound: comm {} of {}",
            sync.comm_s,
            sync.total_s
        );
        let mut prev = sync.total_s;
        for budget in [1usize, 2] {
            let disp = simulate_with(
                &plan,
                &cl,
                &comm,
                &m,
                HaloMode::Displaced { max_staleness: budget },
            )
            .unwrap();
            // Strictly beats sync; never loses to a smaller budget
            // (equal is fine — with uniform interval times the
            // steady-state unmasked remainder is inflow minus drain
            // capacity regardless of deadline depth).
            assert!(
                disp.total_s < sync.total_s,
                "budget {budget}: {} !< {}",
                disp.total_s,
                sync.total_s
            );
            assert!(disp.total_s <= prev + 1e-12);
            assert!(disp.comm_s < sync.comm_s);
            assert!(disp.halo_displaced > 0);
            // Overlap accounting surfaces the hidden transfers.
            assert!(disp.overlap_s.iter().sum::<f64>() > 0.0);
            // Same compute either way — only the comm charging moved.
            assert_eq!(disp.busy_s, sync.busy_s);
            prev = disp.total_s;
        }
    }

    #[test]
    fn displaced_segmented_spans_match_whole_run_bit_exactly() {
        // Debts carry across span boundaries (and their deadlines are
        // plan-local, so segmentation must not shift them).
        let p = StadiParams::default();
        let halo = HaloMode::Displaced { max_staleness: 2 };
        let plan = build_plan(&[1.0, 0.5], &p);
        let cl = cluster(&[0.0, 0.5]);
        let comm = slow_comm();
        let m = model();
        let whole = simulate_with(&plan, &cl, &comm, &m, halo).unwrap();
        let mut st = SimState::new(2);
        let total = plan.sync_points.len();
        let mut done = 0;
        for span in [3usize, 1, 9, 2] {
            let span = span.min(total - done);
            simulate_span(&plan, &cl, &comm, &m, None, &mut st, span, halo)
                .unwrap();
            done += span;
        }
        simulate_span(
            &plan,
            &cl,
            &comm,
            &m,
            None,
            &mut st,
            total - done,
            halo,
        )
        .unwrap();
        let seg = st.finish(&plan);
        assert_eq!(whole.total_s, seg.total_s);
        assert_eq!(whole.busy_s, seg.busy_s);
        assert_eq!(whole.comm_s, seg.comm_s);
        assert_eq!(whole.overlap_s, seg.overlap_s);
        assert_eq!(whole.halo_displaced, seg.halo_displaced);
    }

    #[test]
    fn flush_debts_charges_outstanding_transfers() {
        let mut st = SimState::new(2);
        st.flush_debts();
        assert_eq!(st.now, 0.0);
        st.debts.push((3, 0.25));
        st.debts.push((5, 0.5));
        st.flush_debts();
        assert!(st.debts.is_empty());
        assert_eq!(st.now, 0.75);
        assert_eq!(st.comm_s, 0.75);
    }

    #[test]
    fn drift_slows_the_frozen_plan_and_constant_drift_is_identity() {
        use crate::device::OccupancySchedule;
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 1.0], &p);
        let cl = cluster(&[0.0, 0.0]);
        let comm = CommConfig::default();
        let m = model();
        let base = simulate(&plan, &cl, &comm, &m).unwrap();
        // A schedule pinning every device at its config occupancy is
        // the identity — same floats, not merely close.
        let flat = OccupancySchedule::parse("0@0;0@0").unwrap();
        let same =
            simulate_under_drift(&plan, &cl, &comm, &m, &flat, &[0, 1])
                .unwrap();
        assert_eq!(base.total_s, same.total_s);
        assert_eq!(base.busy_s, same.busy_s);
        // A mid-run ramp on device 1 strictly slows the frozen plan.
        let ramp = OccupancySchedule::parse("0@0;0@0,0.6@10").unwrap();
        let slow =
            simulate_under_drift(&plan, &cl, &comm, &m, &ramp, &[0, 1])
                .unwrap();
        assert!(slow.total_s > base.total_s * 1.2, "{}", slow.total_s);
        // The drift key is the *global* id through the map: remapping
        // device 1 to a flat schedule entry restores the baseline.
        let remapped =
            simulate_under_drift(&plan, &cl, &comm, &m, &ramp, &[0, 0])
                .unwrap();
        assert_eq!(base.total_s, remapped.total_s);
    }

    #[test]
    fn migration_charge_advances_clock_and_comm() {
        let comm = CommConfig::default();
        let mut st = SimState::new(2);
        st.charge_migration(&comm, 0);
        assert_eq!(st.now, 0.0);
        st.charge_migration(&comm, 1 << 20);
        assert!(st.now > 0.0);
        assert_eq!(st.now, st.comm_s);
    }

    #[test]
    fn deterministic() {
        let p = StadiParams::default();
        let plan = build_plan(&[1.0, 0.33], &p);
        let cl = cluster(&[0.0, 0.67]);
        let a = simulate(&plan, &cl, &CommConfig::default(), &model())
            .unwrap();
        let b = simulate(&plan, &cl, &CommConfig::default(), &model())
            .unwrap();
        assert_eq!(a.total_s, b.total_s);
        assert_eq!(a.busy_s, b.busy_s);
    }
}
