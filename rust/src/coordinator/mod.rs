//! Request coordination: Algorithm 1 end to end.
//!
//! * `buffers` — per-device latent + stale-KV state;
//! * `dataflow` — deterministic single-threaded executor (quality
//!   experiments, golden tests);
//! * `threaded` — real worker threads over the collective bus
//!   (serving runtime; bit-equal numerics to dataflow);
//! * `timeline` — virtual-clock latency simulation (latency figures);
//! * `engine` — the public API tying it all together.

pub mod buffers;
pub mod dataflow;
pub mod engine;
pub mod threaded;
pub mod timeline;

pub use engine::{Engine, Generation, Request};
