//! Request coordination: Algorithm 1 end to end.
//!
//! * `buffers` — per-device latent + stale-KV state;
//! * `dataflow` — deterministic single-threaded executor (quality
//!   experiments, golden tests);
//! * `threaded` — real worker threads over the collective bus
//!   (serving runtime; bit-equal numerics to dataflow);
//! * `timeline` — virtual-clock latency simulation (latency figures);
//! * `core` — the shared planner core (`EngineCore`): artifacts,
//!   cluster, cost model, profiler, schedule, behind fine-grained
//!   locks;
//! * `session` — per-request execution (`Session`): snapshots a plan
//!   from the core, executes it, feeds timings back.

pub mod buffers;
pub mod core;
pub mod dataflow;
pub mod session;
pub mod threaded;
pub mod timeline;

// `self::` disambiguates from the built-in `core` crate (E0659).
pub use self::core::{EngineCore, Generation};
pub use self::session::{
    BarrierCheckpoint, FusedJoiner, FusedOutcome, ReplanEvent, ResumePoint,
    Session,
};
