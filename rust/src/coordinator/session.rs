//! Per-request execution sessions.
//!
//! A `Session` is the unit of request execution: it holds an `Arc` to
//! the shared [`EngineCore`], a pinned [`Plan`] and a cluster
//! snapshot, and nothing else — so any number of sessions can execute
//! concurrently. All PJRT work funnels through the core's single
//! execution-service thread (the physical substrate), but everything
//! around it — sampler updates, halo scatter/gather, serialization —
//! runs on the session's own thread, which is exactly the overlap a
//! concurrent serving front-end exploits.
//!
//! Locking rules (see rust/DESIGN_SERVE.md): a session takes no core
//! lock while executing; it touches the shared profiler only in
//! `execute`'s epilogue, via [`EngineCore::record_step`].

use std::sync::Arc;

use crate::config::ExecMode;
use crate::coordinator::core::{EngineCore, Generation};
use crate::coordinator::{dataflow, threaded, timeline};
use crate::device::SimGpu;
use crate::error::Result;
use crate::model::latents::{seeded_cond, seeded_noise};
use crate::runtime::artifacts::{ModelInfo, ResKey};
use crate::sched::plan::Plan;
use crate::spec::GenerationSpec;

/// A lightweight execution session: plan snapshot + cluster snapshot,
/// bound to the resolution whose artifacts it executes.
pub struct Session {
    core: Arc<EngineCore>,
    plan: Plan,
    cluster: Vec<SimGpu>,
    /// Local plan/cluster index -> global device id, for profiler
    /// feedback. Identity for whole-cluster sessions; the leased
    /// subset for gang sessions opened via `EngineCore::session_on`.
    device_map: Vec<usize>,
    /// Which registered resolution this session executes against.
    res: ResKey,
    /// The model geometry re-based onto that resolution (native
    /// sessions carry the base model unchanged).
    model: ModelInfo,
}

impl Session {
    pub(crate) fn new(
        core: Arc<EngineCore>,
        plan: Plan,
        cluster: Vec<SimGpu>,
        res: ResKey,
        model: ModelInfo,
    ) -> Self {
        let device_map = (0..cluster.len()).collect();
        Session { core, plan, cluster, device_map, res, model }
    }

    /// A session over a device subset: `plan`/`cluster` are indexed
    /// locally (0..k), `device_map[local]` names the global device.
    pub(crate) fn with_map(
        core: Arc<EngineCore>,
        plan: Plan,
        cluster: Vec<SimGpu>,
        device_map: Vec<usize>,
        res: ResKey,
        model: ModelInfo,
    ) -> Self {
        debug_assert_eq!(cluster.len(), device_map.len());
        Session { core, plan, cluster, device_map, res, model }
    }

    /// The plan this session executes (pinned at session creation).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Global device ids this session runs on, in local index order.
    pub fn devices(&self) -> &[usize] {
        &self.device_map
    }

    /// The resolution this session executes (latent rows x cols).
    pub fn resolution(&self) -> ResKey {
        self.res
    }

    /// Execute one request through the pinned plan: Algorithm 1 via
    /// the dataflow or threaded executor (per config), then feed
    /// measured per-step compute back into the shared profiler and
    /// simulate the heterogeneous-cluster timeline.
    ///
    /// Only the spec's `seed` matters here — the shape-determining
    /// fields (steps, size) were consumed when the session's plan was
    /// built by [`EngineCore::session_for`].
    pub fn execute(&self, spec: &GenerationSpec) -> Result<Generation> {
        self.execute_seeded(spec.seed)
    }

    /// Execute from a bare seed.
    pub fn execute_seeded(&self, seed: u64) -> Result<Generation> {
        let exec = self.core.exec();
        let model = self.model.clone();
        // Pre-compile every artifact the plan needs so compilation
        // never lands inside measured step times (it would poison the
        // profiler's effective-speed estimates — a freshly-compiling
        // device would look 100x slower and get itself excluded).
        let heights: Vec<usize> = self
            .plan
            .included_devices()
            .map(|d| d.rows.rows)
            .collect();
        exec.warm_res(self.res, &heights)?;
        let noise = seeded_noise(&model, seed);
        let cond = seeded_cond(&model, seed);
        let out = match self.core.mode() {
            ExecMode::Dataflow => dataflow::execute_at(
                exec, self.res, &model, &self.plan, &noise, &cond,
            )?,
            ExecMode::Threaded => threaded::execute_at(
                exec,
                self.res,
                &model,
                &self.plan,
                &self.cluster,
                &noise,
                &cond,
                true,
            )?,
        };
        // Feed measured per-step compute back into the shared profiler
        // ("historical inference time profiles", paper §V) so
        // concurrent requests keep refining effective speeds. Plan
        // indices are session-local; the device map names the global
        // device (identity for whole-cluster sessions, the leased
        // subset for gang sessions).
        //
        // Rows are normalized to *native-width equivalents* first: the
        // profiler's seconds-per-row estimate is native-calibrated,
        // and a wider canvas does proportionally more work per row
        // (tokens ratio) — without this, mixed-width traffic would
        // make every device that serves it look slower to the shared
        // planner.
        let width_ratio = self.model.latent_w as f64
            / exec.manifest().model.latent_w as f64;
        for d in self.plan.included_devices() {
            if out.stats.steps_run[d.device] > 0 {
                let rows_run =
                    d.rows.rows * out.stats.steps_run[d.device];
                let rows_eq = ((rows_run as f64 * width_ratio).round()
                    as usize)
                    .max(1);
                self.core.record_step(
                    self.device_map[d.device],
                    rows_eq,
                    out.stats.compute_s[d.device],
                );
            }
        }
        // The reported timeline prices width exactly like the
        // admission-time predictor (same helper, same ratio), so
        // predicted and reported latency cannot drift apart for
        // non-native-width sessions. Native sessions scale by 1.0 —
        // float-identical to the pre-multi-resolution path.
        let tl_cluster = crate::device::scale_cluster_per_row(
            &self.cluster,
            width_ratio,
        );
        let tl = timeline::simulate(
            &self.plan,
            &tl_cluster,
            &self.core.config().comm,
            &model,
        )?;
        Ok(Generation {
            latent: out.latent,
            plan: self.plan.clone(),
            stats: out.stats,
            timeline: tl,
        })
    }
}
