//! Per-request execution sessions.
//!
//! A `Session` is the unit of request execution: it holds an `Arc` to
//! the shared [`EngineCore`], a pinned [`Plan`] and a cluster
//! snapshot, and nothing else — so any number of sessions can execute
//! concurrently. All PJRT work funnels through the core's single
//! execution-service thread (the physical substrate), but everything
//! around it — sampler updates, halo scatter/gather, serialization —
//! runs on the session's own thread, which is exactly the overlap a
//! concurrent serving front-end exploits.
//!
//! Locking rules (see rust/DESIGN_SERVE.md): a session takes no core
//! lock while executing; it touches the shared profiler only in
//! `execute`'s epilogue, via [`EngineCore::record_step`].

use std::sync::Arc;

use crate::config::{ExecMode, HaloMode};
use crate::coordinator::core::{EngineCore, Generation};
use crate::coordinator::{dataflow, threaded, timeline};
use crate::device::SimGpu;
use crate::error::{Error, Result};
use crate::model::latents::{seeded_cond, seeded_noise};
use crate::runtime::artifacts::{ModelInfo, ResKey};
use crate::runtime::Tensor;
use crate::sched::plan::Plan;
use crate::sched::replan::{
    drift_detected, live_speeds, replan_at_sync, requantize_plan_at_sync,
    RePlan, RowMove,
};
use crate::spec::GenerationSpec;

/// One mid-flight re-plan applied by a session's adaptive loop.
#[derive(Debug, Clone)]
pub struct ReplanEvent {
    /// Global sync-point count (across plan switches) at the barrier.
    pub at_sync: usize,
    /// The barrier's post-state timestep.
    pub t_now: Option<usize>,
    /// Live speeds the re-plan was built from (local device order,
    /// normalized to max 1).
    pub live_speeds: Vec<f64>,
    /// Rows whose owning device changed.
    pub migrated_rows: usize,
    /// Conservative migration transfer charged on the virtual clock.
    pub migration_bytes: u64,
    /// Did any device change step class (Full/Half/Excluded)?
    pub classes_changed: bool,
}

/// A request joining an in-flight fused session at a sync barrier.
/// The token is opaque to the executor — the serve layer uses it to
/// route the joiner's generation back to its connection.
#[derive(Debug, Clone, Copy)]
pub struct FusedJoiner {
    pub token: u64,
    pub seed: u64,
}

/// Result of a fused (cross-request batched) session run.
#[derive(Debug)]
pub struct FusedOutcome {
    /// Generations of the founding members, in input-seed order.
    pub members: Vec<Generation>,
    /// Generations of barrier joiners, tagged by their tokens, in
    /// join order.
    pub joined: Vec<(u64, Generation)>,
}

/// A request frozen at a sync barrier with the fully-fresh invariant
/// restored: every included device holds the identical gathered latent
/// and fully-published KV stack, so `exec.bufs[i]` of any included `i`
/// plus the plan's remaining fast-grid suffix fully determine the
/// continuation — on this cluster or any other. Produced by
/// [`Session::execute_to_barrier`]; serialized for cross-node transfer
/// by [`MigrationEnvelope`](crate::federation::MigrationEnvelope).
#[derive(Debug)]
pub struct BarrierCheckpoint {
    /// Execution state at the barrier (buffers fresh, cursors past
    /// `synced` sync points of the session's plan).
    pub exec: dataflow::ExecState,
    /// The virtual clock at the barrier (prefix compute + comm).
    pub sim: timeline::SimState,
    /// Sync points of the session's plan completed at the barrier.
    pub synced: usize,
}

/// The receiving half of a barrier handoff: a fully-fresh `(x, kv)`
/// snapshot plus the clock to resume under. `transfer_bytes` is the
/// envelope payload the destination charges on its timeline before
/// the first resumed step ([`timeline::SimState::charge_migration`]) —
/// zero for an intra-process handoff that moved nothing.
#[derive(Debug, Clone, Copy)]
pub struct ResumePoint<'a> {
    /// Gathered full latent at the barrier.
    pub x: &'a Tensor,
    /// Fully-published KV stack at the barrier.
    pub kv: &'a Tensor,
    /// Sender's wall clock (`SimState::now`) at the handoff.
    pub elapsed_s: f64,
    /// Portion of `elapsed_s` the sender attributed to communication.
    pub comm_s: f64,
    /// Envelope payload bytes to charge as a migration transfer.
    pub transfer_bytes: u64,
}

/// A lightweight execution session: plan snapshot + cluster snapshot,
/// bound to the resolution whose artifacts it executes.
pub struct Session {
    core: Arc<EngineCore>,
    plan: Plan,
    cluster: Vec<SimGpu>,
    /// Local plan/cluster index -> global device id, for profiler
    /// feedback. Identity for whole-cluster sessions; the leased
    /// subset for gang sessions opened via `EngineCore::session_on`.
    device_map: Vec<usize>,
    /// Which registered resolution this session executes against.
    res: ResKey,
    /// The model geometry re-based onto that resolution (native
    /// sessions carry the base model unchanged).
    model: ModelInfo,
    /// Effective halo mode: the engine's configured mode, tightened by
    /// the request's quality tier (see [`EngineCore::effective_halo`]).
    halo: HaloMode,
}

impl Session {
    pub(crate) fn new(
        core: Arc<EngineCore>,
        plan: Plan,
        cluster: Vec<SimGpu>,
        res: ResKey,
        model: ModelInfo,
        halo: HaloMode,
    ) -> Self {
        let device_map = (0..cluster.len()).collect();
        Session { core, plan, cluster, device_map, res, model, halo }
    }

    /// A session over a device subset: `plan`/`cluster` are indexed
    /// locally (0..k), `device_map[local]` names the global device.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn with_map(
        core: Arc<EngineCore>,
        plan: Plan,
        cluster: Vec<SimGpu>,
        device_map: Vec<usize>,
        res: ResKey,
        model: ModelInfo,
        halo: HaloMode,
    ) -> Self {
        debug_assert_eq!(cluster.len(), device_map.len());
        Session { core, plan, cluster, device_map, res, model, halo }
    }

    /// The plan this session executes (pinned at session creation).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// The halo mode this session executes under.
    pub fn halo(&self) -> HaloMode {
        self.halo
    }

    /// Global device ids this session runs on, in local index order.
    pub fn devices(&self) -> &[usize] {
        &self.device_map
    }

    /// The resolution this session executes (latent rows x cols).
    pub fn resolution(&self) -> ResKey {
        self.res
    }

    /// The model geometry this session executes against (re-based onto
    /// its resolution; native sessions carry the base model unchanged).
    pub fn model(&self) -> &ModelInfo {
        &self.model
    }

    /// Execute one request through the pinned plan: Algorithm 1 via
    /// the dataflow or threaded executor (per config), then feed
    /// measured per-step compute back into the shared profiler and
    /// simulate the heterogeneous-cluster timeline.
    ///
    /// Only the spec's `seed` matters here — the shape-determining
    /// fields (steps, size) were consumed when the session's plan was
    /// built by [`EngineCore::session_for`].
    pub fn execute(&self, spec: &GenerationSpec) -> Result<Generation> {
        self.execute_seeded(spec.seed)
    }

    /// Execute from a bare seed. With `replan.enabled` the execution
    /// loop is adaptive (see [`Self::execute_adaptive_seeded`]);
    /// otherwise this is the frozen-plan path, byte-identical to
    /// pre-replan behavior.
    pub fn execute_seeded(&self, seed: u64) -> Result<Generation> {
        if self.core.config().replan.enabled {
            return self.execute_adaptive_seeded(seed);
        }
        let exec = self.core.exec();
        let model = self.model.clone();
        // Pre-compile every artifact the plan needs so compilation
        // never lands inside measured step times (it would poison the
        // profiler's effective-speed estimates — a freshly-compiling
        // device would look 100x slower and get itself excluded).
        let heights: Vec<usize> = self
            .plan
            .included_devices()
            .map(|d| d.rows.rows)
            .collect();
        exec.warm_res(self.res, &heights)?;
        let noise = seeded_noise(&model, seed);
        let cond = seeded_cond(&model, seed);
        let out = match self.core.mode() {
            ExecMode::Dataflow => dataflow::execute_at(
                exec, self.res, &model, &self.plan, &noise, &cond,
                self.halo,
            )?,
            ExecMode::Threaded => threaded::execute_at(
                exec,
                self.res,
                &model,
                &self.plan,
                &self.cluster,
                &noise,
                &cond,
                true,
                self.halo,
            )?,
        };
        // Feed measured per-step compute back into the shared profiler
        // ("historical inference time profiles", paper §V) so
        // concurrent requests keep refining effective speeds. Plan
        // indices are session-local; the device map names the global
        // device (identity for whole-cluster sessions, the leased
        // subset for gang sessions).
        //
        // Rows are normalized to *native-width equivalents* first: the
        // profiler's seconds-per-row estimate is native-calibrated,
        // and a wider canvas does proportionally more work per row
        // (tokens ratio) — without this, mixed-width traffic would
        // make every device that serves it look slower to the shared
        // planner.
        let width_ratio = self.model.latent_w as f64
            / exec.manifest().model.latent_w as f64;
        for d in self.plan.included_devices() {
            if out.stats.steps_run[d.device] > 0 {
                let rows_run =
                    d.rows.rows * out.stats.steps_run[d.device];
                let rows_eq = ((rows_run as f64 * width_ratio).round()
                    as usize)
                    .max(1);
                self.core.record_step(
                    self.device_map[d.device],
                    rows_eq,
                    out.stats.compute_s[d.device],
                );
            }
        }
        // The reported timeline prices width exactly like the
        // admission-time predictor (same helper, same ratio), so
        // predicted and reported latency cannot drift apart for
        // non-native-width sessions. Native sessions scale by 1.0 —
        // float-identical to the pre-multi-resolution path.
        let tl_cluster = crate::device::scale_cluster_per_row(
            &self.cluster,
            width_ratio,
        );
        let tl = timeline::simulate_with(
            &self.plan,
            &tl_cluster,
            &self.core.config().comm,
            &model,
            self.halo,
        )?;
        Ok(Generation {
            latent: out.latent,
            plan: self.plan.clone(),
            stats: out.stats,
            timeline: tl,
            replans: Vec::new(),
        })
    }

    /// Execute a **fused session**: several compatible requests (same
    /// plan — same resolution, step grids and halo budget, see
    /// [`Plan::fuses_with`]) run in lockstep on this session's gang,
    /// one sync-barrier round at a time. Every member owns an
    /// independent [`dataflow::ExecState`], so a member's numerics
    /// never see another member's latents — each request's output is
    /// byte-identical to its solo run by construction (pinned by
    /// `tests/integration_batch.rs`). What fusing buys is *scheduling*:
    /// one gang lease, one kernel warm-up, and per-step costs priced
    /// batched ([`timeline::simulate_batched`]) instead of B disjoint
    /// leases.
    ///
    /// `poll` is the join gate: called with `true` after every barrier
    /// round while members are still in flight, returning requests that
    /// attach *at that barrier* with a fresh lagging cursor (they run
    /// their full grids, offset by however many rounds late they
    /// joined). When all members have drained it is called once with
    /// `false` — the closing handshake — and any stragglers it returns
    /// are adopted and run to completion before the session ends, so an
    /// offered request is never dropped.
    ///
    /// Threaded execution mode and adaptive re-planning degrade to
    /// sequential solo runs on the same lease (real thread pools and
    /// per-member re-plans don't lockstep); the outcome shape and the
    /// never-dropped guarantee are identical.
    pub fn execute_fused_seeded(
        &self,
        seeds: &[u64],
        mut poll: Option<&mut dyn FnMut(bool) -> Vec<FusedJoiner>>,
    ) -> Result<FusedOutcome> {
        if seeds.is_empty() {
            return Err(crate::error::Error::Sched(
                "fused session needs at least one member".into(),
            ));
        }
        // Fallback: modes whose executors can't interleave per-barrier
        // rounds run members sequentially on this session's lease.
        if self.core.mode() == ExecMode::Threaded
            || self.core.config().replan.enabled
        {
            let mut members = Vec::with_capacity(seeds.len());
            for &s in seeds {
                members.push(self.execute_seeded(s)?);
            }
            let mut joined = Vec::new();
            if let Some(p) = poll.as_mut() {
                for j in p(false) {
                    joined.push((j.token, self.execute_seeded(j.seed)?));
                }
            }
            return Ok(FusedOutcome { members, joined });
        }

        let exec = self.core.exec();
        let model = self.model.clone();
        let heights: Vec<usize> = self
            .plan
            .included_devices()
            .map(|d| d.rows.rows)
            .collect();
        exec.warm_res(self.res, &heights)?;

        struct Member {
            token: Option<u64>,
            seed: u64,
            /// Batch occupancy when this member started — the honest
            /// price of its own steps (later joins speed nobody up
            /// retroactively; the pricing stays conservative for the
            /// joiner, who shares a busier gang).
            batch: usize,
            st: Option<dataflow::ExecState>,
            out: Option<dataflow::RequestOutput>,
        }
        let n = self.plan.devices.len();
        let total_syncs = self.plan.sync_points.len();
        let mut members: Vec<Member> = seeds
            .iter()
            .map(|&seed| Member {
                token: None,
                seed,
                batch: seeds.len(),
                st: Some(dataflow::ExecState::new(
                    &model,
                    n,
                    &seeded_noise(&model, seed),
                )),
                out: None,
            })
            .collect();

        loop {
            let active: Vec<usize> = members
                .iter()
                .enumerate()
                .filter(|(_, m)| m.out.is_none())
                .map(|(i, _)| i)
                .collect();
            if active.is_empty() {
                // Closing handshake: one final poll(false); stragglers
                // it hands back are adopted, the gate never reopens.
                let stragglers = match poll.take() {
                    Some(p) => p(false),
                    None => Vec::new(),
                };
                if stragglers.is_empty() {
                    break;
                }
                let b = stragglers.len();
                for j in stragglers {
                    members.push(Member {
                        token: Some(j.token),
                        seed: j.seed,
                        batch: b,
                        st: Some(dataflow::ExecState::new(
                            &model,
                            n,
                            &seeded_noise(&model, j.seed),
                        )),
                        out: None,
                    });
                }
                continue;
            }
            // One lockstep barrier round per active member.
            for &i in &active {
                let cond = seeded_cond(&model, members[i].seed);
                let st = members[i].st.as_mut().unwrap();
                dataflow::run_span(
                    exec, self.res, &model, &self.plan, st, 1, &cond,
                    self.halo,
                )?;
                if st.synced >= total_syncs {
                    let st = members[i].st.take().unwrap();
                    members[i].out =
                        Some(dataflow::finish(&self.plan, st)?);
                }
            }
            // Drain the join gate at the barrier while still in flight.
            let in_flight =
                members.iter().filter(|m| m.out.is_none()).count();
            if in_flight > 0 {
                if let Some(p) = poll.as_mut() {
                    for j in p(true) {
                        let b = members
                            .iter()
                            .filter(|m| m.out.is_none())
                            .count()
                            + 1;
                        members.push(Member {
                            token: Some(j.token),
                            seed: j.seed,
                            batch: b,
                            st: Some(dataflow::ExecState::new(
                                &model,
                                n,
                                &seeded_noise(&model, j.seed),
                            )),
                            out: None,
                        });
                    }
                }
            }
        }

        // Epilogue per member: profiler feedback (identical to the solo
        // path) and the batched timeline at the member's occupancy.
        let width_ratio = self.model.latent_w as f64
            / exec.manifest().model.latent_w as f64;
        let tl_cluster = crate::device::scale_cluster_per_row(
            &self.cluster,
            width_ratio,
        );
        let comm = &self.core.config().comm;
        let mut founders = Vec::new();
        let mut joined = Vec::new();
        for m in members {
            let out = m.out.expect("all members drained");
            for d in self.plan.included_devices() {
                if out.stats.steps_run[d.device] > 0 {
                    let rows_run =
                        d.rows.rows * out.stats.steps_run[d.device];
                    let rows_eq = ((rows_run as f64 * width_ratio)
                        .round() as usize)
                        .max(1);
                    self.core.record_step(
                        self.device_map[d.device],
                        rows_eq,
                        out.stats.compute_s[d.device],
                    );
                }
            }
            let tl = timeline::simulate_batched(
                &self.plan,
                &tl_cluster,
                comm,
                &model,
                self.halo,
                m.batch.max(1),
            )?;
            let generation = Generation {
                latent: out.latent,
                plan: self.plan.clone(),
                stats: out.stats,
                timeline: tl,
                replans: Vec::new(),
            };
            match m.token {
                Some(t) => joined.push((t, generation)),
                None => founders.push(generation),
            }
        }
        Ok(FusedOutcome { members: founders, joined })
    }

    /// Adaptive execution: structure the request into the warmup phase
    /// plus post-warmup epochs. At the warmup barrier and every
    /// `every_k_syncs` sync points after it, re-read this request's
    /// *own* measured per-step timings, and when live speeds drift
    /// past the threshold re-run the Eq. 4 suffix re-quantization and
    /// the Eq. 5 elastic re-split over the remaining steps, migrating
    /// patch boundaries at the barrier (where every included device's
    /// buffers are fully fresh, so ownership moves are numerically
    /// free — the timeline still charges the conservative transfer).
    ///
    /// Measurement source: with a deterministic drift schedule
    /// injected (stub manifest / `STADI_DRIFT`), per-step seconds are
    /// *virtual* — synthesized from the calibrated cost model and the
    /// schedule — so drift scenarios are byte-reproducible on any
    /// build; without one, real wall-clock step timings drive
    /// detection. Everything here is indexed by session-local device
    /// ids; the lease map translates to global ids only at the drift
    /// schedule and profiler boundaries (a lease-restricted session
    /// must react to drift on *its own* global devices, not on
    /// whichever devices share its local indices).
    pub fn execute_adaptive_seeded(&self, seed: u64) -> Result<Generation> {
        let rcfg = self.core.config().replan.clone();
        let k = rcfg.every_k_syncs.max(1);
        let exec = self.core.exec();
        let model = self.model.clone();
        let schedule = self.core.schedule();
        let comm = &self.core.config().comm;
        let drift = self.core.drift_schedule();
        let granularity = model.row_granularity;
        let n = self.plan.devices.len();

        // Width pricing identical to the static path: the virtual
        // clocks run on the per-row-scaled cluster, so reported and
        // predicted latency cannot drift apart.
        let width_ratio = self.model.latent_w as f64
            / exec.manifest().model.latent_w as f64;
        let tl_cluster =
            crate::device::scale_cluster_per_row(&self.cluster, width_ratio);
        let tl_costs: Vec<crate::device::CostModel> =
            tl_cluster.iter().map(|g| g.cost).collect();

        // Pre-compile every height the initial plan needs; re-plans
        // warm new heights at their barrier (below), so compilation
        // never lands inside measured step times.
        let mut warmed: std::collections::BTreeSet<usize> = self
            .plan
            .included_devices()
            .map(|d| d.rows.rows)
            .collect();
        let heights: Vec<usize> = warmed.iter().copied().collect();
        exec.warm_res(self.res, &heights)?;

        let noise = seeded_noise(&model, seed);
        let cond = seeded_cond(&model, seed);

        let mut st = dataflow::ExecState::new(&model, n, &noise);
        let mut sim = timeline::SimState::new(n);
        let mut cur = self.plan.clone();
        let mut events: Vec<ReplanEvent> = Vec::new();
        let mut rows_run = vec![0usize; n];
        let mut synced_in_cur = 0usize;
        let mut global_sync = 0usize;
        let warmup_syncs = cur.params.m_warmup;
        let mut next_replan =
            if warmup_syncs > 0 { warmup_syncs } else { k };

        loop {
            let remaining = cur.sync_points.len() - synced_in_cur;
            if remaining == 0 {
                break;
            }
            let span = next_replan
                .saturating_sub(global_sync)
                .max(1)
                .min(remaining);

            let steps_before = st.stats.steps_run.clone();
            let busy_before = sim.busy.clone();
            let wall_before = st.stats.compute_s.clone();

            match self.core.mode() {
                ExecMode::Dataflow => dataflow::run_span(
                    exec, self.res, &model, &cur, &mut st, span, &cond,
                    self.halo,
                )?,
                ExecMode::Threaded => threaded::run_span_at(
                    exec,
                    self.res,
                    &model,
                    &cur,
                    &self.cluster,
                    &cond,
                    &mut st,
                    span,
                    true,
                    self.halo,
                )?,
            }
            timeline::simulate_span(
                &cur,
                &tl_cluster,
                comm,
                &model,
                drift.map(|d| (d, self.device_map.as_slice())),
                &mut sim,
                span,
                self.halo,
            )?;
            for d in cur.included_devices() {
                let delta =
                    st.stats.steps_run[d.device] - steps_before[d.device];
                rows_run[d.device] += d.rows.rows * delta;
            }
            global_sync += span;
            synced_in_cur += span;

            if synced_in_cur >= cur.sync_points.len() {
                break;
            }
            if global_sync < next_replan {
                continue;
            }
            next_replan = global_sync + k;

            // In-request drift detection on this segment's timings.
            let sec_delta: Vec<f64> = (0..n)
                .map(|i| {
                    if drift.is_some() {
                        sim.busy[i] - busy_before[i]
                    } else {
                        st.stats.compute_s[i] - wall_before[i]
                    }
                })
                .collect();
            let live = live_speeds(
                &cur,
                &tl_costs,
                &steps_before,
                &st.stats.steps_run,
                &sec_delta,
            );
            if !drift_detected(&cur, &live, rcfg.drift_threshold) {
                continue;
            }
            // The same (unscaled) cost model the static planner's
            // cost-aware allocator used — zero drift must reproduce
            // its split exactly, width-scaled timelines or not.
            let cost_ref = if cur.params.cost_aware {
                Some(&self.cluster[0].cost)
            } else {
                None
            };
            let rp = match replan_at_sync(
                schedule,
                &cur,
                synced_in_cur,
                &live,
                cost_ref,
                granularity,
            )? {
                Some(rp) => rp,
                None => {
                    // Parity deferral: the very next barrier fits.
                    next_replan = global_sync + 1;
                    continue;
                }
            };
            if rp.is_structural_noop() {
                continue;
            }
            // Warm newly-introduced patch heights before their steps
            // are measured.
            let mut fresh = Vec::new();
            for d in rp.plan.included_devices() {
                if warmed.insert(d.rows.rows) {
                    fresh.push(d.rows.rows);
                }
            }
            if !fresh.is_empty() {
                exec.warm_res(self.res, &fresh)?;
            }
            let bytes = rp.migration_bytes(&model);
            sim.charge_migration(comm, bytes);
            events.push(ReplanEvent {
                at_sync: global_sync,
                t_now: cur.sync_points[synced_in_cur - 1],
                live_speeds: live,
                migrated_rows: rp.migrated_rows,
                migration_bytes: bytes,
                classes_changed: rp.classes_changed,
            });
            // Re-plans invalidate published halos: with a positive
            // staleness budget the barrier may sit on a *displaced*
            // sync point, where peer rows are stale — migrating row
            // ownership there would bake staleness into the new
            // owners. Restore the fully-fresh invariant with a
            // blocking full exchange (a numeric no-op when the barrier
            // happened to be a fallback sync), flush the in-flight
            // displaced transfers onto the clock, and drop the history
            // (`reset_cursors` below) so the new plan's first `budget`
            // sync points re-fill it via fallback.
            if self.halo.max_staleness() > 0 {
                dataflow::refresh_buffers(&model, &cur, &mut st);
                sim.flush_debts();
                sim.charge_refresh(comm, &cur, &model);
            }
            cur = rp.plan;
            synced_in_cur = 0;
            st.reset_cursors();
            sim.switch_plan();
        }

        let out = dataflow::finish(&cur, st)?;
        // Profiler feedback under *global* ids, rows normalized to
        // native-width equivalents — identical to the static path.
        for i in 0..n {
            if rows_run[i] > 0 {
                let rows_eq = ((rows_run[i] as f64 * width_ratio).round()
                    as usize)
                    .max(1);
                self.core.record_step(
                    self.device_map[i],
                    rows_eq,
                    out.stats.compute_s[i],
                );
            }
        }
        let tl = sim.finish(&self.plan);
        Ok(Generation {
            latent: out.latent,
            plan: self.plan.clone(),
            stats: out.stats,
            timeline: tl,
            replans: events,
        })
    }

    /// Degraded execution: the *pressure* twin of
    /// [`Self::execute_adaptive_seeded`]. The request runs to the
    /// warmup barrier in one span, then stops at every subsequent sync
    /// barrier and asks `should_requantize` (the serve layer's
    /// pressure ladder — see [`crate::serve::degrade`]) whether
    /// backlog pressure has crossed the top threshold. The first
    /// barrier where it says yes — and the suffix parity allows it —
    /// swaps the continuation onto the
    /// [`requantize_plan_at_sync`] coarse grid: every other remaining
    /// fast step, both endpoints kept, so the remaining work roughly
    /// halves while the final transition stays aligned. Exactly one
    /// re-quantization per request (one mid-flight ladder rung), so
    /// the quality delta stays bounded; parity deferrals retry at the
    /// next barrier, exactly like a drift demotion.
    ///
    /// Row moves are accounted and charged on the virtual clock like a
    /// drift re-plan (`charge_migration`), published halos are
    /// refreshed at the swap barrier under a positive staleness
    /// budget, and each applied re-quantization is reported as a
    /// [`ReplanEvent`] on the returned generation (what
    /// `RouterStats::requantized` counts). When `should_requantize`
    /// never fires, the chunked execution is latent-byte-identical to
    /// [`Self::execute_seeded`] (the same span invariant the adaptive
    /// path pins).
    pub fn execute_degraded_seeded(
        &self,
        seed: u64,
        should_requantize: &mut dyn FnMut() -> bool,
    ) -> Result<Generation> {
        let exec = self.core.exec();
        let model = self.model.clone();
        let schedule = self.core.schedule();
        let comm = &self.core.config().comm;
        let drift = self.core.drift_schedule();
        let granularity = model.row_granularity;
        let n = self.plan.devices.len();

        let width_ratio = self.model.latent_w as f64
            / exec.manifest().model.latent_w as f64;
        let tl_cluster =
            crate::device::scale_cluster_per_row(&self.cluster, width_ratio);

        let mut warmed: std::collections::BTreeSet<usize> = self
            .plan
            .included_devices()
            .map(|d| d.rows.rows)
            .collect();
        let heights: Vec<usize> = warmed.iter().copied().collect();
        exec.warm_res(self.res, &heights)?;

        let noise = seeded_noise(&model, seed);
        let cond = seeded_cond(&model, seed);

        let mut st = dataflow::ExecState::new(&model, n, &noise);
        let mut sim = timeline::SimState::new(n);
        let mut cur = self.plan.clone();
        let mut events: Vec<ReplanEvent> = Vec::new();
        let mut rows_run = vec![0usize; n];
        let mut synced_in_cur = 0usize;
        let mut global_sync = 0usize;
        let warmup_syncs = cur.params.m_warmup;
        let mut requantized = false;

        loop {
            let remaining = cur.sync_points.len() - synced_in_cur;
            if remaining == 0 {
                break;
            }
            // Never thin the warmup phase (early steps set global
            // structure — the same rule the displaced-halo fallback
            // enforces): run to the warmup barrier in one span, then
            // barrier-by-barrier until the one-shot fires.
            let span = if requantized {
                remaining
            } else if global_sync < warmup_syncs {
                (warmup_syncs - global_sync).min(remaining)
            } else {
                1
            };

            let steps_before = st.stats.steps_run.clone();
            match self.core.mode() {
                ExecMode::Dataflow => dataflow::run_span(
                    exec, self.res, &model, &cur, &mut st, span, &cond,
                    self.halo,
                )?,
                ExecMode::Threaded => threaded::run_span_at(
                    exec,
                    self.res,
                    &model,
                    &cur,
                    &self.cluster,
                    &cond,
                    &mut st,
                    span,
                    true,
                    self.halo,
                )?,
            }
            timeline::simulate_span(
                &cur,
                &tl_cluster,
                comm,
                &model,
                drift.map(|d| (d, self.device_map.as_slice())),
                &mut sim,
                span,
                self.halo,
            )?;
            for d in cur.included_devices() {
                let delta =
                    st.stats.steps_run[d.device] - steps_before[d.device];
                rows_run[d.device] += d.rows.rows * delta;
            }
            global_sync += span;
            synced_in_cur += span;

            if synced_in_cur >= cur.sync_points.len() {
                break;
            }
            if requantized
                || global_sync < warmup_syncs
                || !should_requantize()
            {
                continue;
            }
            let cost_ref = if cur.params.cost_aware {
                Some(&self.cluster[0].cost)
            } else {
                None
            };
            let newp = match requantize_plan_at_sync(
                schedule,
                &cur,
                synced_in_cur,
                cost_ref,
                granularity,
            )? {
                Some(p) => p,
                // Parity deferral (or only the final step remains):
                // the very next barrier is re-checked anyway.
                None => continue,
            };
            // Row-move accounting, shaped exactly like a drift
            // re-plan's, so the virtual clock charges the same
            // conservative transfer for migrated ownership.
            let moves: Vec<RowMove> = cur
                .devices
                .iter()
                .zip(&newp.devices)
                .filter(|(o, p)| o.rows != p.rows)
                .map(|(o, p)| RowMove {
                    device: o.device,
                    old: o.rows,
                    new: p.rows,
                })
                .collect();
            let rp = RePlan {
                speeds: cur
                    .devices
                    .iter()
                    .map(|d| if d.included() { d.speed } else { 0.0 })
                    .collect(),
                migrated_rows: moves.iter().map(|m| m.gained_rows()).sum(),
                classes_changed: cur
                    .devices
                    .iter()
                    .zip(&newp.devices)
                    .any(|(o, p)| o.class != p.class),
                moves,
                plan: newp,
            };
            let mut fresh = Vec::new();
            for d in rp.plan.included_devices() {
                if warmed.insert(d.rows.rows) {
                    fresh.push(d.rows.rows);
                }
            }
            if !fresh.is_empty() {
                exec.warm_res(self.res, &fresh)?;
            }
            let bytes = rp.migration_bytes(&model);
            sim.charge_migration(comm, bytes);
            events.push(ReplanEvent {
                at_sync: global_sync,
                t_now: cur.sync_points[synced_in_cur - 1],
                live_speeds: rp.speeds.clone(),
                migrated_rows: rp.migrated_rows,
                migration_bytes: bytes,
                classes_changed: rp.classes_changed,
            });
            // Same halo rule as a drift re-plan: the coarse grid's
            // sync schedule is new, so published-but-unconsumed
            // displaced halos are refreshed and charged here.
            if self.halo.max_staleness() > 0 {
                dataflow::refresh_buffers(&model, &cur, &mut st);
                sim.flush_debts();
                sim.charge_refresh(comm, &cur, &model);
            }
            cur = rp.plan;
            synced_in_cur = 0;
            st.reset_cursors();
            sim.switch_plan();
            requantized = true;
        }

        let out = dataflow::finish(&cur, st)?;
        for i in 0..n {
            if rows_run[i] > 0 {
                let rows_eq = ((rows_run[i] as f64 * width_ratio).round()
                    as usize)
                    .max(1);
                self.core.record_step(
                    self.device_map[i],
                    rows_eq,
                    out.stats.compute_s[i],
                );
            }
        }
        let tl = sim.finish(&self.plan);
        Ok(Generation {
            latent: out.latent,
            plan: self.plan.clone(),
            stats: out.stats,
            timeline: tl,
            replans: events,
        })
    }

    /// Execute the first `n_syncs` sync intervals of this session's
    /// plan and stop at the barrier with the fully-fresh invariant
    /// restored — the sending half of a cross-node migration or a
    /// device re-admission handoff.
    ///
    /// Under [`HaloMode::Sync`] the restoring exchange is a numeric
    /// no-op (the barrier's all-gather just ran); under a positive
    /// displaced-staleness budget the barrier may sit on a displaced
    /// sync point with stale peer rows, so the refresh is a real
    /// blocking exchange, flushed and charged on the virtual clock
    /// exactly like the adaptive re-plan path does.
    ///
    /// `n_syncs` must leave work behind: `0 < n_syncs <
    /// plan.sync_points.len()`. Prefix timings are fed back into the
    /// shared profiler here, since the destination never sees them.
    pub fn execute_to_barrier(
        &self,
        seed: u64,
        n_syncs: usize,
    ) -> Result<BarrierCheckpoint> {
        let total = self.plan.sync_points.len();
        if n_syncs == 0 || n_syncs >= total {
            return Err(Error::Sched(format!(
                "checkpoint barrier {n_syncs} out of range (plan has \
                 {total} sync points; the handoff must leave work)"
            )));
        }
        let exec = self.core.exec();
        let model = self.model.clone();
        let comm = &self.core.config().comm;
        let drift = self.core.drift_schedule();
        let n = self.plan.devices.len();
        let heights: Vec<usize> = self
            .plan
            .included_devices()
            .map(|d| d.rows.rows)
            .collect();
        exec.warm_res(self.res, &heights)?;
        let width_ratio = self.model.latent_w as f64
            / exec.manifest().model.latent_w as f64;
        let tl_cluster = crate::device::scale_cluster_per_row(
            &self.cluster,
            width_ratio,
        );
        let noise = seeded_noise(&model, seed);
        let cond = seeded_cond(&model, seed);
        let mut st = dataflow::ExecState::new(&model, n, &noise);
        let mut sim = timeline::SimState::new(n);
        match self.core.mode() {
            ExecMode::Dataflow => dataflow::run_span(
                exec, self.res, &model, &self.plan, &mut st, n_syncs,
                &cond, self.halo,
            )?,
            ExecMode::Threaded => threaded::run_span_at(
                exec,
                self.res,
                &model,
                &self.plan,
                &self.cluster,
                &cond,
                &mut st,
                n_syncs,
                true,
                self.halo,
            )?,
        }
        timeline::simulate_span(
            &self.plan,
            &tl_cluster,
            comm,
            &model,
            drift.map(|d| (d, self.device_map.as_slice())),
            &mut sim,
            n_syncs,
            self.halo,
        )?;
        // Restore the fully-fresh invariant the checkpoint contract
        // promises (numeric no-op under `HaloMode::Sync`); displaced
        // halos pay for the blocking exchange on the clock.
        dataflow::refresh_buffers(&model, &self.plan, &mut st);
        if self.halo.max_staleness() > 0 {
            sim.flush_debts();
            sim.charge_refresh(comm, &self.plan, &model);
        }
        for d in self.plan.included_devices() {
            if st.stats.steps_run[d.device] > 0 {
                let rows_run =
                    d.rows.rows * st.stats.steps_run[d.device];
                let rows_eq = ((rows_run as f64 * width_ratio).round()
                    as usize)
                    .max(1);
                self.core.record_step(
                    self.device_map[d.device],
                    rows_eq,
                    st.stats.compute_s[d.device],
                );
            }
        }
        Ok(BarrierCheckpoint { exec: st, sim, synced: n_syncs })
    }

    /// Resume a migrated request: this session's plan must be the
    /// *continuation* plan over the checkpoint's remaining fast-grid
    /// suffix (built by
    /// [`plan_suffix_on`](crate::sched::replan::plan_suffix_on) at the
    /// destination's live speeds). Every device starts from the
    /// transferred fully-fresh buffers, the envelope transfer is
    /// charged on the resumed clock before the first step, and the
    /// returned generation's timeline spans the *whole* request
    /// (sender prefix + transfer + local suffix).
    ///
    /// When the destination's speeds match the sender's, the
    /// continuation programs are the ones the sender would have run
    /// (the zero-drift re-plan invariant), so the rendered latent is
    /// byte-identical to the unmigrated run — pinned by
    /// `tests/integration_federation.rs`.
    pub fn resume_seeded(
        &self,
        seed: u64,
        rp: &ResumePoint<'_>,
    ) -> Result<Generation> {
        let exec = self.core.exec();
        let model = self.model.clone();
        let comm = &self.core.config().comm;
        let drift = self.core.drift_schedule();
        let n = self.plan.devices.len();
        let heights: Vec<usize> = self
            .plan
            .included_devices()
            .map(|d| d.rows.rows)
            .collect();
        exec.warm_res(self.res, &heights)?;
        let width_ratio = self.model.latent_w as f64
            / exec.manifest().model.latent_w as f64;
        let tl_cluster = crate::device::scale_cluster_per_row(
            &self.cluster,
            width_ratio,
        );
        let cond = seeded_cond(&model, seed);
        let mut st = dataflow::ExecState::from_fresh(&model, n, rp.x, rp.kv);
        let mut sim =
            timeline::SimState::resumed(n, rp.elapsed_s, rp.comm_s);
        sim.charge_migration(comm, rp.transfer_bytes);
        let n_syncs = self.plan.sync_points.len();
        match self.core.mode() {
            ExecMode::Dataflow => dataflow::run_span(
                exec, self.res, &model, &self.plan, &mut st, n_syncs,
                &cond, self.halo,
            )?,
            ExecMode::Threaded => threaded::run_span_at(
                exec,
                self.res,
                &model,
                &self.plan,
                &self.cluster,
                &cond,
                &mut st,
                n_syncs,
                true,
                self.halo,
            )?,
        }
        timeline::simulate_span(
            &self.plan,
            &tl_cluster,
            comm,
            &model,
            drift.map(|d| (d, self.device_map.as_slice())),
            &mut sim,
            n_syncs,
            self.halo,
        )?;
        let out = dataflow::finish(&self.plan, st)?;
        for d in self.plan.included_devices() {
            if out.stats.steps_run[d.device] > 0 {
                let rows_run =
                    d.rows.rows * out.stats.steps_run[d.device];
                let rows_eq = ((rows_run as f64 * width_ratio).round()
                    as usize)
                    .max(1);
                self.core.record_step(
                    self.device_map[d.device],
                    rows_eq,
                    out.stats.compute_s[d.device],
                );
            }
        }
        let tl = sim.finish(&self.plan);
        Ok(Generation {
            latent: out.latent,
            plan: self.plan.clone(),
            stats: out.stats,
            timeline: tl,
            replans: Vec::new(),
        })
    }
}
