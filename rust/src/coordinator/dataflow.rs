//! Deterministic dataflow executor for Algorithm 1.
//!
//! Executes a plan's exact dataflow — who computes which steps on which
//! rows with which (possibly stale) buffers, and what is exchanged at
//! each sync point — as a single-threaded loop over sync intervals.
//! Numerics are bit-identical to the threaded engine (integration
//! tests assert this) because staleness is a property of the *plan*,
//! not of wall-clock races: between two sync points a device only sees
//! peer state from the previous sync.
//!
//! Timing is NOT modeled here (see `timeline.rs`); this path produces
//! the images for the quality experiments (Table II, Fig. 7) and the
//! golden cross-checks, and records real compute seconds for the
//! profiler/cost calibration.

use std::time::Instant;

use crate::config::HaloMode;
use crate::error::{Error, Result};
use crate::model::latents::token_range;
use crate::model::sampler;
use crate::runtime::artifacts::{ModelInfo, ResKey};
use crate::runtime::tensor::Tensor;
use crate::runtime::ExecHandle;
use crate::sched::plan::Plan;

use super::buffers::DeviceBuffers;

/// Execution statistics of one request.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    /// Real seconds spent in PJRT execution, per device.
    pub compute_s: Vec<f64>,
    /// Denoiser invocations per device.
    pub steps_run: Vec<usize>,
    /// Bytes a real cluster would move at sync points (x patches).
    pub x_bytes: u64,
    /// Bytes of async KV publishes.
    pub kv_bytes: u64,
    /// Number of sync points executed.
    pub syncs: usize,
    /// Sync points served by displaced (stale, non-blocking) halos.
    pub halo_displaced: usize,
    /// Sync points served by the blocking exchange (all of them under
    /// [`HaloMode::Sync`] or a zero staleness budget).
    pub halo_fallback: usize,
}

/// Result of one request.
#[derive(Debug, Clone)]
pub struct RequestOutput {
    /// Final clean latent [H, W, C].
    pub latent: Tensor,
    pub stats: ExecStats,
}

/// Run one request through the plan's dataflow at the native
/// resolution (the legacy entry point).
///
/// `noise` is the shared initial latent x_{t0}; `cond` the conditioning
/// vector.
pub fn execute(
    exec: &ExecHandle,
    plan: &Plan,
    noise: &Tensor,
    cond: &[f32],
) -> Result<RequestOutput> {
    let native = exec.registry().native();
    execute_at(
        exec,
        native.key,
        &native.model,
        plan,
        noise,
        cond,
        HaloMode::Sync,
    )
}

/// Run one request through the plan's dataflow against a registered
/// resolution's artifacts. `model` is that resolution's geometry (the
/// session resolves it once from the registry).
pub fn execute_at(
    exec: &ExecHandle,
    res: ResKey,
    model: &ModelInfo,
    plan: &Plan,
    noise: &Tensor,
    cond: &[f32],
    halo: HaloMode,
) -> Result<RequestOutput> {
    let mut st = ExecState::new(model, plan.devices.len(), noise);
    run_span(
        exec,
        res,
        model,
        plan,
        &mut st,
        plan.sync_points.len(),
        cond,
        halo,
    )?;
    finish(plan, st)
}

/// One device's boundary payload at a sync point: the fresh x patch
/// and the KV block covering its token range.
#[derive(Clone)]
pub struct HaloPayload {
    pub device: usize,
    pub x_patch: Tensor,
    pub kv_block: Tensor,
}

/// Published payloads of one sync point, retained so later displaced
/// sync points can consume them stale.
pub struct HaloEntry {
    /// Plan-local sync index the payloads were published at.
    pub sync: usize,
    pub payloads: Vec<HaloPayload>,
}

/// Checkpointable executor state: full per-device buffers, per-plan
/// step cursors and cumulative stats. At a sync barrier every included
/// device's buffers are fully fresh (the exchange just ran), which is
/// exactly what lets a mid-flight re-plan migrate row ownership and
/// continue on the same state — see `Session::execute`'s adaptive
/// loop. Shared by the dataflow and threaded executors.
pub struct ExecState {
    pub bufs: Vec<DeviceBuffers>,
    /// Per-device step cursor within the *current* plan.
    pub cursor: Vec<usize>,
    pub stats: ExecStats,
    /// Plan-local sync points completed. Resets with the cursors on a
    /// re-plan — the halo history below is indexed by this counter.
    pub synced: usize,
    /// Recent sync points' published payloads, newest last. Only
    /// populated under a positive staleness budget; a displaced sync
    /// point `si` consumes the entry published at `si - budget`.
    pub halo: Vec<HaloEntry>,
}

impl ExecState {
    pub fn new(model: &ModelInfo, n_dev: usize, noise: &Tensor) -> Self {
        ExecState {
            bufs: (0..n_dev)
                .map(|_| DeviceBuffers::new(model, noise))
                .collect(),
            cursor: vec![0; n_dev],
            stats: ExecStats {
                compute_s: vec![0.0; n_dev],
                steps_run: vec![0; n_dev],
                ..Default::default()
            },
            synced: 0,
            halo: Vec::new(),
        }
    }

    /// Rebuild execution state from a *fully-fresh* barrier snapshot —
    /// the receiving half of a
    /// [`MigrationEnvelope`](crate::federation::MigrationEnvelope)
    /// transfer. At a sync barrier every included device holds the
    /// identical gathered latent and fully-published KV stack, so one
    /// `(x, kv)` pair seeds *any* destination device count: a sibling
    /// node's cluster, or this node's own cluster with a recovered
    /// device re-admitted. Cursors start at 0 for the suffix plan;
    /// stats start empty (the sender's stats travel separately in the
    /// envelope).
    pub fn from_fresh(
        model: &ModelInfo,
        n_dev: usize,
        x: &Tensor,
        kv: &Tensor,
    ) -> Self {
        let mut st = ExecState::new(model, n_dev, x);
        for b in st.bufs.iter_mut() {
            b.kv = kv.clone();
        }
        st
    }

    /// Switch to a re-planned continuation: cursors reset, buffers and
    /// stats persist (the new plan's devices line up index-for-index).
    /// Published halos are invalidated — migrated rows make the old
    /// payload row ranges meaningless, so the first post-re-plan sync
    /// points fall back to the blocking exchange until the history
    /// refills.
    pub fn reset_cursors(&mut self) {
        for c in self.cursor.iter_mut() {
            *c = 0;
        }
        self.synced = 0;
        self.halo.clear();
    }
}

/// Run `n_syncs` sync intervals of `plan` from `st`'s position.
///
/// Under [`HaloMode::Displaced`] with a positive budget, sync points
/// the plan marks safe ([`Plan::displaced_fallback`] is false) consume
/// peers' payloads published `budget` sync points ago instead of the
/// fresh ones — the numerical face of the non-blocking exchange the
/// timeline overlaps with compute. Every sync point still *publishes*
/// fresh payloads, so staleness never exceeds the budget. A zero
/// budget (or `HaloMode::Sync`) is byte-identical to the legacy
/// blocking exchange.
#[allow(clippy::too_many_arguments)]
pub fn run_span(
    exec: &ExecHandle,
    res: ResKey,
    model: &ModelInfo,
    plan: &Plan,
    st: &mut ExecState,
    n_syncs: usize,
    cond: &[f32],
    halo: HaloMode,
) -> Result<()> {
    let included: Vec<usize> = plan
        .devices
        .iter()
        .filter(|d| d.included())
        .map(|d| d.device)
        .collect();
    if included.is_empty() {
        return Err(Error::Sched("no included devices".into()));
    }
    if st.bufs.len() != plan.devices.len() {
        return Err(Error::Sched("state/plan size mismatch".into()));
    }
    let budget = halo.max_staleness();
    let ExecState { bufs, cursor, stats, synced, halo: history } = st;

    for _ in 0..n_syncs {
        let si = *synced;
        let mut published: Vec<HaloPayload> =
            Vec::with_capacity(included.len());
        for &di in &included {
            let dp = &plan.devices[di];
            let (t0, t1) = token_range(model, dp.rows);
            // Run local steps up to and including the next sync step.
            loop {
                let step = dp.steps.get(cursor[di]).ok_or_else(|| {
                    Error::Sched(format!(
                        "device {} ran out of steps",
                        dp.name
                    ))
                })?;
                let x_patch = bufs[di].x.slice_rows(dp.rows.row0, dp.rows.rows);
                let t_start = Instant::now();
                let out = exec.denoise_at(
                    res,
                    dp.rows.rows,
                    &x_patch,
                    &bufs[di].kv,
                    dp.rows.row0,
                    step.t_from as f64,
                    cond,
                )?;
                stats.compute_s[di] += t_start.elapsed().as_secs_f64();
                stats.steps_run[di] += 1;

                // Own KV slice is now fresh locally.
                bufs[di].scatter_kv(t0, &out.kv_fresh);
                // DDIM-advance own rows only (Alg. 1: peers' regions
                // are reused from the last sync, lines 20-21).
                sampler::ddim_update_rows(
                    &mut bufs[di].x,
                    &out.eps_patch,
                    dp.rows.row0,
                    step.coef,
                );
                cursor[di] += 1;

                if step.sync {
                    published.push(HaloPayload {
                        device: di,
                        x_patch: bufs[di]
                            .x
                            .slice_rows(dp.rows.row0, dp.rows.rows),
                        kv_block: bufs[di].gather_kv(t0, t1 - t0),
                    });
                    break;
                }
            }
        }

        // The same payloads move either way — displaced ones just move
        // off the critical path (the timeline prices the difference).
        for p in &published {
            stats.x_bytes += p.x_patch.byte_len() as u64;
            stats.kv_bytes += p.kv_block.byte_len() as u64;
        }

        let scatter = |bufs: &mut Vec<DeviceBuffers>, p: &HaloPayload| {
            let dp = &plan.devices[p.device];
            let (t0, _) = token_range(model, dp.rows);
            for &dj in &included {
                if dj == p.device {
                    continue;
                }
                bufs[dj].x.scatter_rows(dp.rows.row0, &p.x_patch);
                bufs[dj].scatter_kv(t0, &p.kv_block);
            }
        };

        if plan.displaced_fallback(si, budget) {
            // Blocking exchange: every device receives every peer's
            // fresh x patch and KV block at the barrier.
            stats.halo_fallback += 1;
            for p in &published {
                scatter(bufs, p);
            }
        } else {
            // Displaced exchange: consume the peers' payloads from
            // `budget` sync points ago; the fresh ones were published
            // asynchronously and will be consumed later.
            stats.halo_displaced += 1;
            let entry = history
                .iter()
                .find(|e| e.sync == si - budget)
                .ok_or_else(|| {
                    Error::Sched(format!(
                        "displaced sync {si}: no published halo for sync {}",
                        si - budget
                    ))
                })?;
            for p in &entry.payloads {
                scatter(bufs, p);
            }
        }

        if budget > 0 {
            history.push(HaloEntry { sync: si, payloads: published });
            while history.len() > budget + 1 {
                history.remove(0);
            }
        }
        stats.syncs += 1;
        *synced += 1;
    }
    Ok(())
}

/// Restore the fully-fresh buffer invariant: exchange every included
/// device's own rows and KV block with all peers, as one blocking
/// barrier would. Used before a mid-flight re-plan migrates row
/// ownership while displaced halos are in flight — a numeric no-op
/// when the buffers were already fresh (e.g. the barrier landed on a
/// fallback sync point).
pub fn refresh_buffers(model: &ModelInfo, plan: &Plan, st: &mut ExecState) {
    let included: Vec<usize> = plan
        .devices
        .iter()
        .filter(|d| d.included())
        .map(|d| d.device)
        .collect();
    for &di in &included {
        let dp = &plan.devices[di];
        let (t0, t1) = token_range(model, dp.rows);
        let x_patch = st.bufs[di].x.slice_rows(dp.rows.row0, dp.rows.rows);
        let kv_block = st.bufs[di].gather_kv(t0, t1 - t0);
        for &dj in &included {
            if dj == di {
                continue;
            }
            st.bufs[dj].x.scatter_rows(dp.rows.row0, &x_patch);
            st.bufs[dj].scatter_kv(t0, &kv_block);
        }
    }
}

/// Drain-check the final plan and extract the finished request.
pub fn finish(plan: &Plan, st: ExecState) -> Result<RequestOutput> {
    // All devices drained their (current-plan) programs.
    for d in plan.included_devices() {
        if st.cursor[d.device] != d.steps.len() {
            return Err(Error::Sched(format!(
                "device {} finished with {}/{} steps",
                d.name,
                st.cursor[d.device],
                d.steps.len()
            )));
        }
    }
    // Final latent: any device's x is fully fresh after the last
    // gather; take the first included one.
    let first = plan
        .included_devices()
        .next()
        .ok_or_else(|| Error::Sched("no included devices".into()))?;
    let latent = st.bufs[first.device].x.clone();
    Ok(RequestOutput { latent, stats: st.stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StadiParams;
    use crate::model::latents::{seeded_cond, seeded_noise};
    use crate::model::schedule::Schedule;
    use crate::runtime::ExecService;
    use std::path::PathBuf;

    fn runtime() -> Option<ExecService> {
        if !cfg!(feature = "xla-backend") {
            eprintln!("skipping: built without xla-backend");
            return None;
        }
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(ExecService::spawn(dir).unwrap())
    }

    fn tiny_params(m_base: usize) -> StadiParams {
        StadiParams { m_base, m_warmup: 2, ..StadiParams::default() }
    }

    fn plan(rt: &ExecHandle, speeds: &[f64], p: &StadiParams) -> Plan {
        let sched = Schedule::from_info(&rt.manifest().schedule);
        let names: Vec<String> =
            (0..speeds.len()).map(|i| format!("g{i}")).collect();
        Plan::build(
            &sched,
            speeds,
            &names,
            p,
            rt.manifest().model.latent_h,
            rt.manifest().model.row_granularity,
        )
        .unwrap()
    }

    #[test]
    fn single_device_runs_all_steps() {
        let Some(svc) = runtime() else { return };
        let rt = svc.handle();
        let p = tiny_params(6);
        let plan = plan(&rt, &[1.0], &p);
        let model = rt.manifest().model.clone();
        let noise = seeded_noise(&model, 42);
        let cond = seeded_cond(&model, 42);
        let out = execute(&rt, &plan, &noise, &cond).unwrap();
        assert_eq!(out.stats.steps_run[0], 6);
        assert_eq!(out.stats.syncs, 6);
        assert_eq!(out.latent.shape, model.latent_shape());
        assert!(out.latent.abs_sum() > 0.0);
    }

    #[test]
    fn two_equal_devices_match_origin_when_buffers_fresh_every_step() {
        // With uniform grids (no TA trigger) patch parallelism syncs
        // every step; outputs still differ slightly from Origin because
        // within a step each device sees *last-step* KV for peers. The
        // drift must be small (temporal redundancy, Thm. 1) but
        // generally nonzero.
        let Some(svc) = runtime() else { return };
        let rt = svc.handle();
        let p = tiny_params(8);
        let model = rt.manifest().model.clone();
        let noise = seeded_noise(&model, 7);
        let cond = seeded_cond(&model, 7);

        let origin = execute(&rt, &plan(&rt, &[1.0], &p), &noise, &cond)
            .unwrap();
        let pp = execute(&rt, &plan(&rt, &[1.0, 1.0], &p), &noise, &cond)
            .unwrap();
        let rmse = pp.latent.mse(&origin.latent).sqrt();
        let scale = (origin
            .latent
            .data
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            / origin.latent.len() as f64)
            .sqrt();
        assert!(rmse > 0.0, "patch parallelism identical to origin?");
        assert!(
            rmse / scale < 0.25,
            "relative drift too large: {rmse} vs scale {scale}"
        );
    }

    #[test]
    fn heterogeneous_stadi_runs_mixed_step_counts() {
        let Some(svc) = runtime() else { return };
        let rt = svc.handle();
        let p = tiny_params(10); // warmup 2 -> slow steps = 6
        let plan = plan(&rt, &[1.0, 0.5], &p);
        let model = rt.manifest().model.clone();
        let noise = seeded_noise(&model, 9);
        let cond = seeded_cond(&model, 9);
        let out = execute(&rt, &plan, &noise, &cond).unwrap();
        assert_eq!(out.stats.steps_run[0], 10);
        assert_eq!(out.stats.steps_run[1], 6);
        // Fewer syncs than fast steps: 2 warmup(shared prefix is 1
        // transition... just assert equals the plan).
        assert_eq!(out.stats.syncs, plan.sync_points.len());
        assert!(out.latent.abs_sum() > 0.0);
    }

    #[test]
    fn excluded_device_contributes_nothing() {
        let Some(svc) = runtime() else { return };
        let rt = svc.handle();
        let p = tiny_params(6);
        let model = rt.manifest().model.clone();
        let noise = seeded_noise(&model, 11);
        let cond = seeded_cond(&model, 11);
        let solo = execute(&rt, &plan(&rt, &[1.0], &p), &noise, &cond)
            .unwrap();
        let with_excluded =
            execute(&rt, &plan(&rt, &[1.0, 0.1], &p), &noise, &cond)
                .unwrap();
        assert_eq!(with_excluded.stats.steps_run[1], 0);
        // Identical numerics to running alone.
        assert_eq!(solo.latent, with_excluded.latent);
    }
}
