//! The shared planner core: configure once, serve many — concurrently.
//!
//! `EngineCore` owns everything that outlives a single request: the
//! PJRT execution service, the simulated cluster, the online profiler
//! and the diffusion schedule. It is shared behind an `Arc` and every
//! method takes `&self`; the two pieces of mutable state use their own
//! fine-grained locks:
//!
//! * `profiler: Mutex<Profiler>` — touched at plan time (read) and at
//!   session completion (write), never held across execution;
//! * `cluster: RwLock<Vec<SimGpu>>` — replaced wholesale by
//!   [`EngineCore::calibrate`], snapshotted (cloned) by sessions.
//!
//! Per-request state lives in [`super::Session`]: a session snapshots
//! a [`Plan`] (Eq. 4 + 5 against *current* effective speeds) plus the
//! cluster, executes Algorithm 1 without holding any core lock, and
//! feeds measured step times back so concurrent requests keep
//! refining the shared speed estimates ("historical inference time
//! profiles", paper §V).

use std::sync::{Arc, Mutex, RwLock, Weak};

use crate::config::{EngineConfig, ExecMode};
use crate::coordinator::{dataflow, timeline, Session};
use crate::device::{build_cluster, CostModel, SimGpu};
use crate::error::{Error, Result};
use crate::fleet::{FleetManager, GpuLease};
use crate::model::schedule::Schedule;
use crate::runtime::tensor::Tensor;
use crate::runtime::{ExecHandle, ExecService};
use crate::sched::plan::Plan;
use crate::sched::Profiler;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Seeds the initial noise and the conditioning vector (the
    /// prompt-embedding stand-in, DESIGN.md §3).
    pub seed: u64,
}

/// Full result of one request.
#[derive(Debug)]
pub struct Generation {
    pub latent: Tensor,
    pub plan: Plan,
    pub stats: dataflow::ExecStats,
    /// Simulated heterogeneous-cluster latency for this plan.
    pub timeline: timeline::Timeline,
}

/// Shared planning/profiling state of the STADI engine.
pub struct EngineCore {
    config: EngineConfig,
    /// Keeps the PJRT service thread alive.
    _service: ExecService,
    exec: ExecHandle,
    schedule: Schedule,
    cluster: RwLock<Vec<SimGpu>>,
    profiler: Mutex<Profiler>,
    /// Handle to our own `Arc` (constructors only hand out `Arc`s), so
    /// `&self` methods can mint owned clones for sessions without the
    /// unstable `self: &Arc<Self>` receiver.
    self_ref: Weak<EngineCore>,
}

impl EngineCore {
    /// Load artifacts and build the shared core. Uses the uncalibrated
    /// cost model; call [`EngineCore::calibrate`] (or
    /// `with_cost_model`) for timing-faithful timelines.
    pub fn new(config: EngineConfig) -> Result<Arc<Self>> {
        Self::with_cost_model(config, CostModel::uncalibrated())
    }

    pub fn with_cost_model(
        config: EngineConfig,
        cost: CostModel,
    ) -> Result<Arc<Self>> {
        config.validate()?;
        let service = ExecService::spawn(&config.artifacts_dir)?;
        let exec = service.handle();
        let cluster = build_cluster(&config.devices, cost);
        let profiler = Profiler::new(&config.devices);
        let schedule = Schedule::from_info(&exec.manifest().schedule);
        Ok(Arc::new_cyclic(|self_ref| EngineCore {
            config,
            _service: service,
            exec,
            schedule,
            cluster: RwLock::new(cluster),
            profiler: Mutex::new(profiler),
            self_ref: self_ref.clone(),
        }))
    }

    /// Re-calibrate the per-step cost model from real PJRT timings and
    /// swap in a rebuilt cluster. Sessions opened before this keep
    /// their snapshot (mid-flight requests are never re-planned).
    pub fn calibrate(&self, reps: usize) -> Result<CostModel> {
        let cost = self.exec.calibrate(reps)?;
        *self.cluster.write().unwrap() =
            build_cluster(&self.config.devices, cost);
        Ok(cost)
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Handle to the execution service (manifest, features, ...).
    pub fn exec(&self) -> &ExecHandle {
        &self.exec
    }

    /// Snapshot of the simulated cluster.
    pub fn cluster(&self) -> Vec<SimGpu> {
        self.cluster.read().unwrap().clone()
    }

    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// Current effective speeds from the shared profiler.
    pub fn effective_speeds(&self) -> Vec<f64> {
        self.profiler.lock().unwrap().effective_speeds()
    }

    /// Feed one measured step back into the shared profiler (sessions
    /// call this on completion; exposed for benches that execute plans
    /// through the low-level executors).
    pub fn record_step(&self, device: usize, rows: usize, seconds: f64) {
        self.profiler.lock().unwrap().record_step(device, rows, seconds);
    }

    /// Build the joint plan for current effective speeds.
    pub fn plan(&self) -> Result<Plan> {
        self.plan_for(&self.cluster())
    }

    /// Plan against an explicit cluster snapshot, so a session's plan
    /// and cluster stay mutually consistent even if [`Self::calibrate`]
    /// swaps the shared cluster between the two reads.
    fn plan_for(&self, cluster: &[SimGpu]) -> Result<Plan> {
        let speeds = self.effective_speeds();
        let names: Vec<String> =
            self.config.devices.iter().map(|d| d.name.clone()).collect();
        self.plan_parts(cluster, &speeds, &names)
    }

    /// Plan over explicit (cluster, speeds, names) triples — the
    /// subset-agnostic core both whole-cluster and gang sessions use.
    /// Eq. 4 normalizes to the slice's own v_max and Eq. 5 mends
    /// patches over whatever devices it is given, so a gang plans
    /// exactly like a small cluster.
    fn plan_parts(
        &self,
        cluster: &[SimGpu],
        speeds: &[f64],
        names: &[String],
    ) -> Result<Plan> {
        let m = &self.exec.manifest().model;
        if self.config.stadi.cost_aware && self.config.stadi.spatial {
            return Plan::build_cost_aware(
                &self.schedule,
                speeds,
                names,
                &self.config.stadi,
                &cluster[0].cost,
                m.latent_h,
                m.row_granularity,
            );
        }
        Plan::build(
            &self.schedule,
            speeds,
            names,
            &self.config.stadi,
            m.latent_h,
            m.row_granularity,
        )
    }

    /// Select the (cluster, speeds, names) restriction for a device
    /// subset, from one consistent snapshot.
    fn subset_parts(
        &self,
        devices: &[usize],
    ) -> Result<(Vec<SimGpu>, Vec<f64>, Vec<String>)> {
        let cluster = self.cluster();
        if devices.is_empty() {
            return Err(Error::Sched("empty device subset".into()));
        }
        for &d in devices {
            if d >= cluster.len() {
                return Err(Error::Sched(format!(
                    "leased device {d} out of range (cluster has {})",
                    cluster.len()
                )));
            }
        }
        let all_speeds = self.effective_speeds();
        let sub_cluster: Vec<SimGpu> =
            devices.iter().map(|&d| cluster[d].clone()).collect();
        let speeds: Vec<f64> =
            devices.iter().map(|&d| all_speeds[d]).collect();
        let names: Vec<String> = devices
            .iter()
            .map(|&d| self.config.devices[d].name.clone())
            .collect();
        Ok((sub_cluster, speeds, names))
    }

    fn owned(&self) -> Arc<EngineCore> {
        self.self_ref
            .upgrade()
            .expect("EngineCore is only constructed inside an Arc")
    }

    /// Open an execution session on a freshly-built plan. The plan and
    /// the session's cluster derive from one snapshot.
    pub fn session(&self) -> Result<Session> {
        let cluster = self.cluster();
        let plan = self.plan_for(&cluster)?;
        Ok(Session::new(self.owned(), plan, cluster))
    }

    /// Open an execution session on an explicit plan — the escape
    /// hatch for callers that build plans themselves (sweeping explicit
    /// plans, replaying a saved plan). The serving path does not use
    /// it: every request plans freshly via [`Self::session`].
    pub fn session_with_plan(&self, plan: Plan) -> Session {
        Session::new(self.owned(), plan, self.cluster())
    }

    /// Open a session restricted to a leased device subset: Eq. 4 /
    /// Eq. 5 allocate over the gang only, so disjoint leases execute
    /// truly concurrently. Plan, sub-cluster and speeds derive from
    /// one snapshot; measured timings feed back under *global* device
    /// ids via the session's device map.
    pub fn session_on(&self, lease: &GpuLease) -> Result<Session> {
        let (sub, speeds, names) = self.subset_parts(lease.devices())?;
        let plan = self.plan_parts(&sub, &speeds, &names)?;
        Ok(Session::with_map(
            self.owned(),
            plan,
            sub,
            lease.devices().to_vec(),
        ))
    }

    /// A fresh fleet ledger sized to this core's cluster.
    pub fn fleet(&self) -> FleetManager {
        FleetManager::new(self.config.devices.len())
    }

    /// Predicted end-to-end latency of one request on a device subset:
    /// plan the gang at current effective speeds and replay it on the
    /// simulated timeline. This is the gang-policy predictor — the
    /// same model the latency figures use, so admission decisions and
    /// reported numbers can't drift apart.
    pub fn predict_latency(&self, devices: &[usize]) -> Result<f64> {
        let (sub, speeds, names) = self.subset_parts(devices)?;
        let plan = self.plan_parts(&sub, &speeds, &names)?;
        let tl = timeline::simulate(
            &plan,
            &sub,
            &self.config.comm,
            &self.exec.manifest().model,
        )?;
        Ok(tl.total_s)
    }

    /// Plan + execute one request (one-shot convenience).
    pub fn generate(&self, req: &Request) -> Result<Generation> {
        self.session()?.execute(req)
    }

    /// Convenience: generate from a bare seed.
    pub fn generate_seeded(&self, seed: u64) -> Result<Generation> {
        self.generate(&Request { seed })
    }

    /// Latency-only simulation of a plan (no numerics) against the
    /// current cluster.
    pub fn simulate_latency(&self, plan: &Plan) -> Result<timeline::Timeline> {
        let cluster = self.cluster.read().unwrap();
        timeline::simulate(
            plan,
            &cluster,
            &self.config.comm,
            &self.exec.manifest().model,
        )
    }

    /// Which executor sessions will use (from config).
    pub fn mode(&self) -> ExecMode {
        self.config.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StadiParams;
    use std::path::PathBuf;

    fn config(occ: &[f64]) -> Option<EngineConfig> {
        if !cfg!(feature = "xla-backend") {
            eprintln!("skipping: built without xla-backend");
            return None;
        }
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let mut cfg = EngineConfig::two_gpu_default(dir, occ);
        cfg.stadi = StadiParams {
            m_base: 8,
            m_warmup: 2,
            ..StadiParams::default()
        };
        Some(cfg)
    }

    #[test]
    fn end_to_end_generate() {
        let Some(cfg) = config(&[0.0, 0.4]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        let g = core.generate_seeded(1).unwrap();
        assert_eq!(g.latent.shape, vec![32, 32, 4]);
        assert!(g.timeline.total_s > 0.0);
        assert!(g.stats.steps_run.iter().sum::<usize>() > 0);
    }

    #[test]
    fn same_seed_same_plan_same_image() {
        let Some(cfg) = config(&[0.0, 0.0]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        // Pin the plan: execution feeds measured timings back into the
        // profiler, so back-to-back auto-planned runs may legally pick
        // different patch splits (and thus different images — Table II
        // shows outputs are split-dependent). Goes through the
        // explicit-plan escape hatch to exercise it.
        let plan = core.plan().unwrap();
        let session = core.session_with_plan(plan);
        let a = session.execute(&Request { seed: 5 }).unwrap();
        let b = session.execute(&Request { seed: 5 }).unwrap();
        assert_eq!(a.latent, b.latent);
        let c = session.execute(&Request { seed: 6 }).unwrap();
        assert!(a.latent.max_abs_diff(&c.latent) > 1e-3);
    }

    #[test]
    fn profiler_learns_from_runs() {
        let Some(cfg) = config(&[0.0, 0.6]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        core.generate_seeded(1).unwrap();
        let v = core.effective_speeds();
        // Both devices ran on the same physical substrate without
        // stretching (dataflow mode) so measured speeds converge —
        // the point is just that history flows through.
        assert_eq!(v.len(), 2);
        assert!(v.iter().all(|&x| x > 0.0 && x <= 1.0));
    }

    #[test]
    fn gang_session_plans_and_executes_on_leased_subset() {
        let Some(cfg) = config(&[0.0, 0.4]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        let fleet = core.fleet();
        let lease = fleet.try_acquire(&[1]).unwrap().unwrap();
        let session = core.session_on(&lease).unwrap();
        // The plan is restricted to the gang: one device carrying the
        // whole latent, reported under its global identity.
        assert_eq!(session.devices(), &[1]);
        assert_eq!(session.plan().devices.len(), 1);
        assert_eq!(session.plan().total_rows(), 32);
        assert_eq!(session.plan().devices[0].name, "gpu1");
        let g = session.execute(&Request { seed: 9 }).unwrap();
        assert_eq!(g.latent.shape, vec![32, 32, 4]);
        assert!(g.timeline.total_s > 0.0);
        // Profiler feedback lands under global ids: the full-cluster
        // speed vector is intact and a whole-cluster plan still works.
        assert_eq!(core.effective_speeds().len(), 2);
        core.session().unwrap();
        // Prediction agrees in shape: a 1-device gang must not be
        // faster than the full cluster on an idle testbed.
        let full = core.predict_latency(&[0, 1]).unwrap();
        let solo = core.predict_latency(&[1]).unwrap();
        assert!(full > 0.0 && solo > full);
    }

    #[test]
    fn concurrent_sessions_share_one_core() {
        let Some(cfg) = config(&[0.0, 0.3]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        let mut handles = Vec::new();
        for i in 0..2u64 {
            let core = Arc::clone(&core);
            handles.push(std::thread::spawn(move || {
                core.generate_seeded(100 + i).unwrap()
            }));
        }
        let outs: Vec<Generation> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(outs.len(), 2);
        // Distinct seeds -> distinct images; both fed the profiler.
        assert!(outs[0].latent.max_abs_diff(&outs[1].latent) > 1e-6);
        assert_eq!(core.effective_speeds().len(), 2);
    }
}
