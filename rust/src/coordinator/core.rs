//! The shared planner core: configure once, serve many — concurrently.
//!
//! `EngineCore` owns everything that outlives a single request: the
//! PJRT execution service, the simulated cluster, the online profiler
//! and the diffusion schedule. It is shared behind an `Arc` and every
//! method takes `&self`; the two pieces of mutable state use their own
//! fine-grained locks:
//!
//! * `profiler: Mutex<Profiler>` — touched at plan time (read) and at
//!   session completion (write), never held across execution;
//! * `cluster: RwLock<Vec<SimGpu>>` — replaced wholesale by
//!   [`EngineCore::calibrate`], snapshotted (cloned) by sessions.
//!
//! Per-request state lives in [`super::Session`]: a session snapshots
//! a [`Plan`] (Eq. 4 + 5 against *current* effective speeds) plus the
//! cluster, executes Algorithm 1 without holding any core lock, and
//! feeds measured step times back so concurrent requests keep
//! refining the shared speed estimates ("historical inference time
//! profiles", paper §V).

use std::sync::{Arc, Mutex, RwLock, Weak};

use crate::config::{EngineConfig, ExecMode, HaloMode, StadiParams};
use crate::coordinator::session::ReplanEvent;
use crate::coordinator::{dataflow, timeline, Session};
use crate::device::{build_cluster, CostModel, OccupancySchedule, SimGpu};
use crate::error::{Error, Result};
use crate::fleet::{FleetManager, GpuLease};
use crate::model::schedule::Schedule;
use crate::runtime::artifacts::ResKey;
use crate::runtime::tensor::Tensor;
use crate::runtime::{ExecHandle, ExecService};
use crate::sched::plan::{Plan, PlanCache, PlanCacheStats, PlanKey};
use crate::sched::{spatial, Profiler};
use crate::spec::{GenerationSpec, VAE_FACTOR};

/// Bound on cached plans: the working set is "request shapes currently
/// in the traffic mix" per device subset — far below this.
const PLAN_CACHE_CAPACITY: usize = 128;

/// Full result of one request.
#[derive(Debug)]
pub struct Generation {
    pub latent: Tensor,
    /// The plan the request *started* on (re-plans, if any, are
    /// described by `replans`).
    pub plan: Plan,
    pub stats: dataflow::ExecStats,
    /// Simulated heterogeneous-cluster latency: the static plan's
    /// timeline, or — for adaptive runs — the drift-aware virtual
    /// timeline of the path actually executed, migration transfers
    /// included.
    pub timeline: timeline::Timeline,
    /// Mid-flight re-plans applied during execution (empty on the
    /// static path and whenever no drift crossed the threshold).
    pub replans: Vec<ReplanEvent>,
}

/// One consistent set of planning inputs: the cache epoch (read
/// first, to fence stale plans out of the cache if `calibrate` races
/// the build), the (sub-)cluster, the global ids of its devices, and
/// their effective speeds/names in the same local order.
struct PlanSnapshot {
    epoch: u64,
    cluster: Vec<SimGpu>,
    devices: Vec<usize>,
    speeds: Vec<f64>,
    names: Vec<String>,
}

/// Shared planning/profiling state of the STADI engine.
pub struct EngineCore {
    config: EngineConfig,
    /// Keeps the PJRT service thread alive.
    _service: ExecService,
    exec: ExecHandle,
    schedule: Schedule,
    cluster: RwLock<Vec<SimGpu>>,
    profiler: Mutex<Profiler>,
    /// Request-shape keyed plan cache: repeated (steps, rows, gang,
    /// quantized speeds) shapes skip Eq. 4/5. Cleared on `calibrate`.
    plans: PlanCache,
    /// Deterministic occupancy drift for the virtual clocks:
    /// `STADI_DRIFT` env override first, else the (stub) manifest's
    /// `"drift"` table. None on real deployments — sessions then
    /// detect drift from their own wall-clock step timings.
    drift: Option<OccupancySchedule>,
    /// Handle to our own `Arc` (constructors only hand out `Arc`s), so
    /// `&self` methods can mint owned clones for sessions without the
    /// unstable `self: &Arc<Self>` receiver.
    self_ref: Weak<EngineCore>,
}

impl EngineCore {
    /// Load artifacts and build the shared core. Uses the uncalibrated
    /// cost model; call [`EngineCore::calibrate`] (or
    /// `with_cost_model`) for timing-faithful timelines.
    pub fn new(config: EngineConfig) -> Result<Arc<Self>> {
        Self::with_cost_model(config, CostModel::uncalibrated())
    }

    pub fn with_cost_model(
        config: EngineConfig,
        cost: CostModel,
    ) -> Result<Arc<Self>> {
        config.validate()?;
        let service = ExecService::spawn(&config.artifacts_dir)?;
        let exec = service.handle();
        let cluster = build_cluster(&config.devices, cost);
        let profiler = Profiler::new(&config.devices);
        let schedule = Schedule::from_info(&exec.manifest().schedule);
        let drift = match OccupancySchedule::from_env()? {
            Some(s) => Some(s),
            None => exec.manifest().drift.clone(),
        };
        Ok(Arc::new_cyclic(|self_ref| EngineCore {
            config,
            _service: service,
            exec,
            schedule,
            cluster: RwLock::new(cluster),
            profiler: Mutex::new(profiler),
            plans: PlanCache::new(PLAN_CACHE_CAPACITY),
            drift,
            self_ref: self_ref.clone(),
        }))
    }

    /// Re-calibrate the per-step cost model from real PJRT timings and
    /// swap in a rebuilt cluster. Sessions opened before this keep
    /// their snapshot (mid-flight requests are never re-planned);
    /// cached plans are dropped (the cost-aware allocator depends on
    /// the cost model).
    pub fn calibrate(&self, reps: usize) -> Result<CostModel> {
        let cost = self.exec.calibrate(reps)?;
        *self.cluster.write().unwrap() =
            build_cluster(&self.config.devices, cost);
        self.plans.clear();
        Ok(cost)
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Handle to the execution service (manifest, features, ...).
    pub fn exec(&self) -> &ExecHandle {
        &self.exec
    }

    /// Registered execution resolutions (latent rows x cols), native
    /// first — what `session_for` will accept.
    pub fn resolutions(&self) -> Vec<ResKey> {
        self.exec.registry().registered()
    }

    /// Snapshot of the simulated cluster.
    pub fn cluster(&self) -> Vec<SimGpu> {
        self.cluster.read().unwrap().clone()
    }

    /// The deterministic occupancy drift schedule driving this
    /// engine's virtual clocks (env `STADI_DRIFT` over the manifest's
    /// `"drift"` table), if any.
    pub fn drift_schedule(&self) -> Option<&OccupancySchedule> {
        self.drift.as_ref()
    }

    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// Current effective speeds from the shared profiler.
    pub fn effective_speeds(&self) -> Vec<f64> {
        self.profiler.lock().unwrap().effective_speeds()
    }

    /// The halo mode a request runs under: per-request quality tiers
    /// can only *tighten* the configured staleness budget, so a
    /// high-quality request on a displaced engine runs with budget 0 —
    /// the byte-identical synchronous path. `None` (no spec) keeps the
    /// engine's configured budget.
    pub fn effective_halo(&self, spec: Option<&GenerationSpec>) -> HaloMode {
        match self.config.halo {
            HaloMode::Sync => HaloMode::Sync,
            HaloMode::Displaced { max_staleness } => {
                let budget = match spec {
                    Some(s) => {
                        max_staleness.min(s.quality.staleness_budget())
                    }
                    None => max_staleness,
                };
                HaloMode::Displaced { max_staleness: budget }
            }
        }
    }

    /// Feed one measured step back into the shared profiler (sessions
    /// call this on completion; exposed for benches that execute plans
    /// through the low-level executors).
    pub fn record_step(&self, device: usize, rows: usize, seconds: f64) {
        self.profiler.lock().unwrap().record_step(device, rows, seconds);
    }

    /// Build the joint plan for current effective speeds under the
    /// default spec (the engine's global configuration).
    pub fn plan(&self) -> Result<Plan> {
        self.plan_for(&GenerationSpec::default())
    }

    /// Request-shaped planning: M_base / warmup derive from the spec's
    /// step budget (quality tier included) and the spatial row split
    /// from the spec's height — not from the engine's global schedule.
    /// Cached by [`PlanKey`], so repeated shapes skip Eq. 4/5.
    pub fn plan_for(&self, spec: &GenerationSpec) -> Result<Plan> {
        let snap = self.whole_cluster_parts();
        self.plan_snapshot(spec, &snap)
    }

    /// One consistent whole-cluster planning snapshot. The cache epoch
    /// is read *first*: if `calibrate` swaps the cost model (and
    /// clears the cache) after this snapshot, plans built from it are
    /// returned to their caller but fenced out of the cache.
    fn whole_cluster_parts(&self) -> PlanSnapshot {
        let epoch = self.plans.epoch();
        let cluster = self.cluster();
        let devices: Vec<usize> = (0..cluster.len()).collect();
        let speeds = self.effective_speeds();
        let names: Vec<String> =
            self.config.devices.iter().map(|d| d.name.clone()).collect();
        PlanSnapshot { epoch, cluster, devices, speeds, names }
    }

    /// Resolve a spec against this engine: re-based STADI params
    /// (normalized warmup) and the latent rows the request plans over.
    fn spec_params(
        &self,
        spec: &GenerationSpec,
    ) -> Result<(StadiParams, usize)> {
        spec.validate()?;
        let m = &self.exec.manifest().model;
        let params = self
            .config
            .stadi
            .for_steps(spec.effective_steps(self.config.stadi.m_base));
        let rows = spec.latent_rows(m.latent_h);
        if rows == 0 || rows % m.row_granularity != 0 {
            return Err(Error::Spec(format!(
                "height {}px maps to {rows} latent rows — needs a \
                 positive multiple of {} rows ({}px)",
                spec.height_px.unwrap_or(m.latent_h * VAE_FACTOR),
                m.row_granularity,
                m.row_granularity * VAE_FACTOR,
            )));
        }
        // Width must tile into patch columns too — otherwise the
        // token count truncates and the predictor would silently
        // price a canvas the model cannot tile at all.
        let cols = spec.latent_cols(m.latent_w);
        if cols == 0 || cols % m.patch != 0 {
            return Err(Error::Spec(format!(
                "width {}px maps to {cols} latent columns — needs a \
                 positive multiple of {} columns ({}px)",
                spec.width_px.unwrap_or(m.latent_w * VAE_FACTOR),
                m.patch,
                m.patch * VAE_FACTOR,
            )));
        }
        Ok((params, rows))
    }

    /// The latent resolution a spec renders at (native dims for unset
    /// fields).
    fn spec_res(&self, spec: &GenerationSpec) -> ResKey {
        let m = &self.exec.manifest().model;
        ResKey {
            h: spec.latent_rows(m.latent_h),
            w: spec.latent_cols(m.latent_w),
        }
    }

    /// Plan a spec over one [`PlanSnapshot`] — the subset-agnostic
    /// core both whole-cluster and gang planning use. Eq. 4 normalizes
    /// to the slice's own v_max and Eq. 5 mends patches over whatever
    /// devices it is given, so a gang plans exactly like a small
    /// cluster. `snap.devices` are the global ids (the cache key
    /// identity of the slice).
    fn plan_snapshot(
        &self,
        spec: &GenerationSpec,
        snap: &PlanSnapshot,
    ) -> Result<Plan> {
        let (params, rows) = self.spec_params(spec)?;
        let m = &self.exec.manifest().model;
        let granularity = m.row_granularity;
        // Native specs keep the pre-multi-resolution key (res: None),
        // so the cache stays warm across the upgrade; other sizes get
        // their own keyspace (two widths can share a row count).
        let res = self.spec_res(spec);
        let res_key = if res == ResKey::of_model(m) {
            None
        } else {
            Some((res.h, res.w))
        };
        let halo = self.effective_halo(Some(spec));
        let key = PlanKey::new(&params, rows, &snap.devices, &snap.speeds)
            .with_res(res_key)
            .with_halo(halo);
        self.plans.get_or_build_at(snap.epoch, key, || {
            if params.cost_aware && params.spatial {
                // Displaced-halo engines price the split comm-aware:
                // sync-effective plans carry the blocking x-gather
                // term, displaced plans drop it (the transfers mask
                // under compute). Sync engines keep the legacy
                // compute-only allocator, byte for byte.
                if self.config.halo.is_displaced() {
                    let bytes_per_row =
                        spec.latent_cols(m.latent_w) * m.latent_c * 4;
                    return Plan::build_cost_aware_with_comm(
                        &self.schedule,
                        &snap.speeds,
                        &snap.names,
                        &params,
                        &snap.cluster[0].cost,
                        &self.config.comm,
                        halo,
                        bytes_per_row,
                        rows,
                        granularity,
                    );
                }
                return Plan::build_cost_aware(
                    &self.schedule,
                    &snap.speeds,
                    &snap.names,
                    &params,
                    &snap.cluster[0].cost,
                    rows,
                    granularity,
                );
            }
            Plan::build(
                &self.schedule,
                &snap.speeds,
                &snap.names,
                &params,
                rows,
                granularity,
            )
        })
    }

    /// Plan-cache hit/miss counters (benches assert repeated shapes
    /// stop re-running Eq. 4/5).
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        self.plans.stats()
    }

    /// Largest gang a spec's latent can feed (every device needs at
    /// least one granule-aligned patch row range).
    pub fn max_gang_for(&self, spec: &GenerationSpec) -> Result<usize> {
        let (_, rows) = self.spec_params(spec)?;
        Ok(spatial::max_gang(
            rows,
            self.exec.manifest().model.row_granularity,
        ))
    }

    /// Execution (unlike planning/prediction) is bound to resolutions
    /// with compiled artifacts: any registered size executes, anything
    /// else is a typed spec rejection (wire code `bad_spec`).
    fn check_executable(&self, spec: &GenerationSpec) -> Result<ResKey> {
        let res = self.spec_res(spec);
        let registry = self.exec.registry();
        if registry.is_registered(res) {
            return Ok(res);
        }
        let registered: Vec<String> = registry
            .registered()
            .iter()
            .map(|r| format!("{}x{}", r.h * VAE_FACTOR, r.w * VAE_FACTOR))
            .collect();
        Err(Error::Spec(format!(
            "resolution {}x{} is not executable: no compiled artifacts \
             for it (registered: {}; other sizes are plan/predict-only)",
            res.h * VAE_FACTOR,
            res.w * VAE_FACTOR,
            registered.join(", "),
        )))
    }

    /// Full admission-time validation of a spec: field ranges, model
    /// alignment, and executability. The serve stack calls this when a
    /// request is parsed, so an inexecutable request is shed with
    /// `bad_spec` *before* it queues or acquires a fleet lease.
    pub fn check_spec(&self, spec: &GenerationSpec) -> Result<()> {
        self.spec_params(spec)?;
        self.check_executable(spec)?;
        Ok(())
    }

    /// Select the planning snapshot restricted to a device subset,
    /// from one consistent read (cache epoch first, as in
    /// [`Self::whole_cluster_parts`]).
    fn subset_parts(&self, devices: &[usize]) -> Result<PlanSnapshot> {
        let epoch = self.plans.epoch();
        let cluster = self.cluster();
        if devices.is_empty() {
            return Err(Error::Sched("empty device subset".into()));
        }
        for &d in devices {
            if d >= cluster.len() {
                return Err(Error::Sched(format!(
                    "leased device {d} out of range (cluster has {})",
                    cluster.len()
                )));
            }
        }
        let all_speeds = self.effective_speeds();
        let sub_cluster: Vec<SimGpu> =
            devices.iter().map(|&d| cluster[d].clone()).collect();
        let speeds: Vec<f64> =
            devices.iter().map(|&d| all_speeds[d]).collect();
        let names: Vec<String> = devices
            .iter()
            .map(|&d| self.config.devices[d].name.clone())
            .collect();
        Ok(PlanSnapshot {
            epoch,
            cluster: sub_cluster,
            devices: devices.to_vec(),
            speeds,
            names,
        })
    }

    fn owned(&self) -> Arc<EngineCore> {
        self.self_ref
            .upgrade()
            .expect("EngineCore is only constructed inside an Arc")
    }

    /// Open an execution session under the default spec.
    pub fn session(&self) -> Result<Session> {
        self.session_for(&GenerationSpec::default())
    }

    /// Open an execution session on a freshly-built request-shaped
    /// plan. The plan and the session's cluster derive from one
    /// snapshot. Any *registered* resolution executes (the registry
    /// lazily loads its artifact set); specs without compiled
    /// artifacts are rejected with a typed [`Error::Spec`].
    pub fn session_for(&self, spec: &GenerationSpec) -> Result<Session> {
        let res = self.check_executable(spec)?;
        let model = self.exec.registry().get(res)?.model.clone();
        let snap = self.whole_cluster_parts();
        let plan = self.plan_snapshot(spec, &snap)?;
        Ok(Session::new(
            self.owned(),
            plan,
            snap.cluster,
            res,
            model,
            self.effective_halo(Some(spec)),
        ))
    }

    /// Open an execution session on an explicit plan — the escape
    /// hatch for callers that build plans themselves (sweeping explicit
    /// plans, replaying a saved plan). The serving path does not use
    /// it: every request plans freshly via [`Self::session_for`].
    /// Explicit plans execute at the native resolution.
    pub fn session_with_plan(&self, plan: Plan) -> Session {
        let native = self.exec.registry().native();
        Session::new(
            self.owned(),
            plan,
            self.cluster(),
            native.key,
            native.model.clone(),
            self.effective_halo(None),
        )
    }

    /// Open a default-spec session restricted to a leased subset.
    pub fn session_on(&self, lease: &GpuLease) -> Result<Session> {
        self.session_for_on(&GenerationSpec::default(), lease)
    }

    /// The lease variant of [`Self::session_for`]: Eq. 4 / Eq. 5
    /// allocate the spec's steps and rows over the gang only, so
    /// disjoint leases execute truly concurrently. Plan, sub-cluster
    /// and speeds derive from one snapshot; measured timings feed back
    /// under *global* device ids via the session's device map.
    pub fn session_for_on(
        &self,
        spec: &GenerationSpec,
        lease: &GpuLease,
    ) -> Result<Session> {
        let res = self.check_executable(spec)?;
        let model = self.exec.registry().get(res)?.model.clone();
        let snap = self.subset_parts(lease.devices())?;
        let plan = self.plan_snapshot(spec, &snap)?;
        Ok(Session::with_map(
            self.owned(),
            plan,
            snap.cluster,
            lease.devices().to_vec(),
            res,
            model,
            self.effective_halo(Some(spec)),
        ))
    }

    /// A fresh fleet ledger sized to this core's cluster.
    pub fn fleet(&self) -> FleetManager {
        FleetManager::new(self.config.devices.len())
    }

    /// Predicted default-spec latency on a device subset.
    pub fn predict_latency(&self, devices: &[usize]) -> Result<f64> {
        self.predict_latency_for(&GenerationSpec::default(), devices)
    }

    /// Predicted end-to-end latency of one *spec-shaped* request on a
    /// device subset: plan the gang at current effective speeds and
    /// replay it on the simulated timeline. This is the gang-policy
    /// predictor — the same model the latency figures use, so
    /// admission decisions and reported numbers can't drift apart, and
    /// it prices the request's own steps, rows and width (a
    /// draft-quality 128px request costs a fraction of a native one),
    /// which is what lets policies size gangs per request. Works for
    /// any granularity-aligned size, registered or not — prediction
    /// is how capacity planning asks "what if we compiled this size?".
    pub fn predict_latency_for(
        &self,
        spec: &GenerationSpec,
        devices: &[usize],
    ) -> Result<f64> {
        let snap = self.subset_parts(devices)?;
        let plan = self.plan_snapshot(spec, &snap)?;
        // Rows flow through the plan; width scales each step's
        // row-proportional cost by the tokens-per-row ratio and
        // reshapes the sync-exchange byte counts via the re-based
        // model. Native specs hit the exact pre-upgrade path (ratio 1,
        // same floats).
        let native = &self.exec.manifest().model;
        let res = self.spec_res(spec);
        // The predictor prices the request's halo mode too: displaced
        // exchanges mostly mask under compute, so a displaced engine
        // admits comm-bound shapes a sync engine would refuse.
        let halo = self.effective_halo(Some(spec));
        if res.w == native.latent_w {
            let tl = timeline::simulate_with(
                &plan,
                &snap.cluster,
                &self.config.comm,
                native,
                halo,
            )?;
            return Ok(tl.total_s);
        }
        let model = native.with_resolution(res.h, res.w);
        let ratio = res.w as f64 / native.latent_w as f64;
        let cluster =
            crate::device::scale_cluster_per_row(&snap.cluster, ratio);
        let tl = timeline::simulate_with(
            &plan,
            &cluster,
            &self.config.comm,
            &model,
            halo,
        )?;
        Ok(tl.total_s)
    }

    /// The batched variant of [`Self::predict_latency_for`]: price the
    /// spec's plan executed as a fused batch of `batch` compatible
    /// requests on the gang ([`timeline::simulate_batched`] — per-row
    /// compute xB, fixed cost and exchange paid once). This is what
    /// keeps the router's deadline/EDF decisions honest under
    /// batching: a member of a batch of 4 is admitted against its
    /// *fused* completion time, not the solo fiction. `batch <= 1` is
    /// float-identical to the solo predictor.
    pub fn predict_latency_for_batched(
        &self,
        spec: &GenerationSpec,
        devices: &[usize],
        batch: usize,
    ) -> Result<f64> {
        if batch <= 1 {
            return self.predict_latency_for(spec, devices);
        }
        let snap = self.subset_parts(devices)?;
        let plan = self.plan_snapshot(spec, &snap)?;
        let native = &self.exec.manifest().model;
        let res = self.spec_res(spec);
        let halo = self.effective_halo(Some(spec));
        if res.w == native.latent_w {
            let tl = timeline::simulate_batched(
                &plan,
                &snap.cluster,
                &self.config.comm,
                native,
                halo,
                batch,
            )?;
            return Ok(tl.total_s);
        }
        let model = native.with_resolution(res.h, res.w);
        let ratio = res.w as f64 / native.latent_w as f64;
        let cluster =
            crate::device::scale_cluster_per_row(&snap.cluster, ratio);
        let tl = timeline::simulate_batched(
            &plan,
            &cluster,
            &self.config.comm,
            &model,
            halo,
            batch,
        )?;
        Ok(tl.total_s)
    }

    /// The batching-compatibility signature of a spec on this engine:
    /// (latent rows, latent cols, effective M_base, normalized warmup,
    /// halo staleness budget). Two admissible specs with equal
    /// signatures resolve to the same `PlanKey` on any given gang —
    /// same resolution, same Eq. 4 step grids (the grid-alignment
    /// property pinned in `sched::temporal`), same exchange schedule —
    /// so their plans satisfy [`Plan::fuses_with`] and their latents
    /// stay byte-identical whether run fused or solo. The serve-side
    /// `FuseKey` wraps exactly this tuple.
    pub fn fuse_signature(
        &self,
        spec: &GenerationSpec,
    ) -> Result<(usize, usize, usize, usize, usize)> {
        let (params, rows) = self.spec_params(spec)?;
        let m = &self.exec.manifest().model;
        let cols = spec.latent_cols(m.latent_w);
        let budget = self.effective_halo(Some(spec)).max_staleness();
        Ok((rows, cols, params.m_base, params.m_warmup, budget))
    }

    /// Plan + execute one spec-shaped request (one-shot convenience).
    pub fn generate(&self, spec: &GenerationSpec) -> Result<Generation> {
        self.session_for(spec)?.execute(spec)
    }

    /// Convenience: generate under the default spec from a bare seed
    /// (the v1 request shape).
    pub fn generate_seeded(&self, seed: u64) -> Result<Generation> {
        self.generate(&GenerationSpec::new().seed(seed))
    }

    /// Latency-only simulation of a plan (no numerics) against the
    /// current cluster.
    pub fn simulate_latency(&self, plan: &Plan) -> Result<timeline::Timeline> {
        let cluster = self.cluster.read().unwrap();
        timeline::simulate_with(
            plan,
            &cluster,
            &self.config.comm,
            &self.exec.manifest().model,
            self.effective_halo(None),
        )
    }

    /// Which executor sessions will use (from config).
    pub fn mode(&self) -> ExecMode {
        self.config.mode
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StadiParams;
    use std::path::PathBuf;

    fn config(occ: &[f64]) -> Option<EngineConfig> {
        if !cfg!(feature = "xla-backend") {
            eprintln!("skipping: built without xla-backend");
            return None;
        }
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let mut cfg = EngineConfig::two_gpu_default(dir, occ);
        cfg.stadi = StadiParams {
            m_base: 8,
            m_warmup: 2,
            ..StadiParams::default()
        };
        Some(cfg)
    }

    #[test]
    fn end_to_end_generate() {
        let Some(cfg) = config(&[0.0, 0.4]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        let g = core.generate_seeded(1).unwrap();
        assert_eq!(g.latent.shape, vec![32, 32, 4]);
        assert!(g.timeline.total_s > 0.0);
        assert!(g.stats.steps_run.iter().sum::<usize>() > 0);
    }

    #[test]
    fn same_seed_same_plan_same_image() {
        let Some(cfg) = config(&[0.0, 0.0]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        // Pin the plan: execution feeds measured timings back into the
        // profiler, so back-to-back auto-planned runs may legally pick
        // different patch splits (and thus different images — Table II
        // shows outputs are split-dependent). Goes through the
        // explicit-plan escape hatch to exercise it.
        let plan = core.plan().unwrap();
        let session = core.session_with_plan(plan);
        let a = session.execute_seeded(5).unwrap();
        let b = session.execute_seeded(5).unwrap();
        assert_eq!(a.latent, b.latent);
        let c = session.execute_seeded(6).unwrap();
        assert!(a.latent.max_abs_diff(&c.latent) > 1e-3);
    }

    #[test]
    fn profiler_learns_from_runs() {
        let Some(cfg) = config(&[0.0, 0.6]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        core.generate_seeded(1).unwrap();
        let v = core.effective_speeds();
        // Both devices ran on the same physical substrate without
        // stretching (dataflow mode) so measured speeds converge —
        // the point is just that history flows through.
        assert_eq!(v.len(), 2);
        assert!(v.iter().all(|&x| x > 0.0 && x <= 1.0));
    }

    #[test]
    fn gang_session_plans_and_executes_on_leased_subset() {
        let Some(cfg) = config(&[0.0, 0.4]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        let fleet = core.fleet();
        let lease = fleet.try_acquire(&[1]).unwrap().unwrap();
        let session = core.session_on(&lease).unwrap();
        // The plan is restricted to the gang: one device carrying the
        // whole latent, reported under its global identity.
        assert_eq!(session.devices(), &[1]);
        assert_eq!(session.plan().devices.len(), 1);
        assert_eq!(session.plan().total_rows(), 32);
        assert_eq!(session.plan().devices[0].name, "gpu1");
        let g = session.execute_seeded(9).unwrap();
        assert_eq!(g.latent.shape, vec![32, 32, 4]);
        assert!(g.timeline.total_s > 0.0);
        // Profiler feedback lands under global ids: the full-cluster
        // speed vector is intact and a whole-cluster plan still works.
        assert_eq!(core.effective_speeds().len(), 2);
        core.session().unwrap();
        // Prediction agrees in shape: a 1-device gang must not be
        // faster than the full cluster on an idle testbed.
        let full = core.predict_latency(&[0, 1]).unwrap();
        let solo = core.predict_latency(&[1]).unwrap();
        assert!(full > 0.0 && solo > full);
    }

    #[test]
    fn spec_shapes_the_plan_and_default_spec_matches_global() {
        use crate::spec::Quality;
        let Some(cfg) = config(&[0.0, 0.4]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        // Default spec == the global schedule path, bit for bit.
        let global = core.plan().unwrap();
        let via_spec = core.plan_for(&GenerationSpec::default()).unwrap();
        assert_eq!(global.params.m_base, via_spec.params.m_base);
        assert_eq!(global.total_rows(), via_spec.total_rows());
        assert_eq!(global.sync_points, via_spec.sync_points);
        // An explicit step budget re-bases M_base; height re-shapes
        // the row split (16 latent rows from 128px at VAE factor 8).
        let spec = GenerationSpec::new().steps(6).size(128, 256);
        let p = core.plan_for(&spec).unwrap();
        assert_eq!(p.params.m_base, 6);
        assert!(p.params.m_warmup < 6);
        assert_eq!(p.total_rows(), 16);
        // Quality tiers scale the configured budget (m_base is 8 in
        // this fixture, so draft = 4).
        let p = core
            .plan_for(&GenerationSpec::new().quality(Quality::Draft))
            .unwrap();
        assert_eq!(p.params.m_base, 4);
        // Misaligned height is a typed spec error.
        let e = core
            .plan_for(&GenerationSpec::new().size(8, 256))
            .unwrap_err();
        assert!(matches!(e, Error::Spec(_)), "{e}");
    }

    #[test]
    fn repeated_spec_shapes_hit_the_plan_cache() {
        let Some(cfg) = config(&[0.0, 0.4]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        let spec = GenerationSpec::new().steps(6);
        core.plan_for(&spec).unwrap();
        let after_first = core.plan_cache_stats();
        assert_eq!(after_first.misses, 1);
        for _ in 0..3 {
            core.plan_for(&spec).unwrap();
        }
        let s = core.plan_cache_stats();
        assert_eq!(s.misses, 1, "repeated shape re-ran Eq. 4/5");
        assert_eq!(s.hits, 3);
        // A different shape misses; calibrate clears the cache.
        core.plan_for(&GenerationSpec::new().steps(8)).unwrap();
        assert_eq!(core.plan_cache_stats().misses, 2);
        core.calibrate(1).unwrap();
        core.plan_for(&spec).unwrap();
        assert_eq!(core.plan_cache_stats().misses, 3);
    }

    #[test]
    fn non_native_specs_predict_but_do_not_execute() {
        let Some(cfg) = config(&[0.0, 0.4]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        let small = GenerationSpec::new().steps(4).size(128, 256);
        // Planning and prediction work — and price the smaller,
        // shorter request below the native default.
        let t_small = core.predict_latency_for(&small, &[0, 1]).unwrap();
        let t_full = core.predict_latency(&[0, 1]).unwrap();
        assert!(
            t_small < t_full,
            "small spec {t_small}s not cheaper than native {t_full}s"
        );
        // Execution is AOT-bound: typed rejection, not a wrong image.
        let e = core.session_for(&small).unwrap_err();
        assert!(matches!(e, Error::Spec(_)), "{e}");
        let e = core.generate(&small).unwrap_err();
        assert!(matches!(e, Error::Spec(_)), "{e}");
        // max_gang_for reflects the small latent: 16 rows / 4 = 4.
        assert_eq!(core.max_gang_for(&small).unwrap(), 4);
    }

    #[test]
    fn spec_session_on_lease_plans_spec_steps() {
        let Some(cfg) = config(&[0.0, 0.4]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        let fleet = core.fleet();
        let lease = fleet.try_acquire(&[1]).unwrap().unwrap();
        let spec = GenerationSpec::new().seed(3).steps(4);
        let session = core.session_for_on(&spec, &lease).unwrap();
        assert_eq!(session.plan().params.m_base, 4);
        assert_eq!(session.devices(), &[1]);
        let g = session.execute(&spec).unwrap();
        assert_eq!(g.latent.shape, vec![32, 32, 4]);
        assert_eq!(g.plan.devices.len(), 1);
    }

    #[test]
    fn concurrent_sessions_share_one_core() {
        let Some(cfg) = config(&[0.0, 0.3]) else { return };
        let core = EngineCore::new(cfg).unwrap();
        let mut handles = Vec::new();
        for i in 0..2u64 {
            let core = Arc::clone(&core);
            handles.push(std::thread::spawn(move || {
                core.generate_seeded(100 + i).unwrap()
            }));
        }
        let outs: Vec<Generation> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(outs.len(), 2);
        // Distinct seeds -> distinct images; both fed the profiler.
        assert!(outs[0].latent.max_abs_diff(&outs[1].latent) > 1e-6);
        assert_eq!(core.effective_speeds().len(), 2);
    }
}
