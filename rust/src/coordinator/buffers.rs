//! Per-device state buffers: the full latent copy and the per-layer
//! stale-KV stack that patch parallelism exchanges between devices
//! (DistriFusion's "activation buffer", paper §II-B / Alg. 1).

use crate::runtime::artifacts::ModelInfo;
use crate::runtime::tensor::Tensor;

/// One device's view of the request state.
#[derive(Debug, Clone)]
pub struct DeviceBuffers {
    /// Full latent [H, W, C]: own rows always fresh, peer rows as of
    /// the last sync.
    pub x: Tensor,
    /// Full per-layer KV stack [L, T_full, 2D]: own token slice fresh,
    /// peer slices as of their last publish (stale in between).
    pub kv: Tensor,
    layers: usize,
    tokens_full: usize,
    kv_width: usize,
}

impl DeviceBuffers {
    pub fn new(model: &ModelInfo, init_x: &Tensor) -> Self {
        DeviceBuffers {
            x: init_x.clone(),
            kv: Tensor::zeros(&model.kv_shape()),
            layers: model.layers,
            tokens_full: model.tokens_full,
            kv_width: 2 * model.dim,
        }
    }

    /// Scatter a fresh KV block [L, T_own, 2D] into the full stack at
    /// token offset `t0`.
    pub fn scatter_kv(&mut self, t0: usize, kv_block: &Tensor) {
        assert_eq!(kv_block.shape.len(), 3);
        assert_eq!(kv_block.shape[0], self.layers);
        assert_eq!(kv_block.shape[2], self.kv_width);
        let t_own = kv_block.shape[1];
        assert!(t0 + t_own <= self.tokens_full);
        let layer_stride = self.tokens_full * self.kv_width;
        let block_stride = t_own * self.kv_width;
        for l in 0..self.layers {
            let dst0 = l * layer_stride + t0 * self.kv_width;
            let src0 = l * block_stride;
            self.kv.data[dst0..dst0 + block_stride]
                .copy_from_slice(&kv_block.data[src0..src0 + block_stride]);
        }
    }

    /// Extract the KV block [L, T_own, 2D] for tokens [t0, t0+t_own).
    pub fn gather_kv(&self, t0: usize, t_own: usize) -> Tensor {
        let layer_stride = self.tokens_full * self.kv_width;
        let block_stride = t_own * self.kv_width;
        let mut out = Tensor::zeros(&[self.layers, t_own, self.kv_width]);
        for l in 0..self.layers {
            let src0 = l * layer_stride + t0 * self.kv_width;
            out.data[l * block_stride..(l + 1) * block_stride]
                .copy_from_slice(&self.kv.data[src0..src0 + block_stride]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::NormalGen;

    fn model() -> ModelInfo {
        ModelInfo {
            latent_h: 8, latent_w: 8, latent_c: 2, patch: 2, dim: 4,
            heads: 2, layers: 2, temb_dim: 8, row_granularity: 2,
            tokens_full: 16, param_count: 1, params_seed: 0,
        }
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let m = model();
        let x0 = Tensor::zeros(&m.latent_shape());
        let mut b = DeviceBuffers::new(&m, &x0);
        let mut g = NormalGen::new(1);
        let block = Tensor::new(vec![2, 4, 8], g.vec_f32(64)).unwrap();
        b.scatter_kv(8, &block);
        assert_eq!(b.gather_kv(8, 4), block);
        // Other regions untouched (still zero).
        let other = b.gather_kv(0, 8);
        assert!(other.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn scatter_respects_layer_strides() {
        let m = model();
        let x0 = Tensor::zeros(&m.latent_shape());
        let mut b = DeviceBuffers::new(&m, &x0);
        // Distinct values per layer.
        let mut block = Tensor::zeros(&[2, 2, 8]);
        for i in 0..16 {
            block.data[i] = 1.0; // layer 0
            block.data[16 + i] = 2.0; // layer 1
        }
        b.scatter_kv(0, &block);
        // Layer 0 tokens 0..2 are 1.0; layer 1 tokens 0..2 are 2.0.
        let l0 = &b.kv.data[0..16];
        let l1 = &b.kv.data[16 * 8..16 * 8 + 16];
        assert!(l0.iter().all(|&v| v == 1.0));
        assert!(l1.iter().all(|&v| v == 2.0));
    }

    #[test]
    #[should_panic]
    fn scatter_out_of_range_panics() {
        let m = model();
        let x0 = Tensor::zeros(&m.latent_shape());
        let mut b = DeviceBuffers::new(&m, &x0);
        let block = Tensor::zeros(&[2, 10, 8]);
        b.scatter_kv(8, &block); // 8 + 10 > 16 tokens
    }
}
