//! Typed configuration for the STADI engine, loadable from JSON files
//! (`--config cluster.json`) or built programmatically. Mirrors the
//! paper's experimental knobs: M_base, M_warmup, a, b (§V
//! "Implementation Details"), per-device capability c_i and occupancy
//! rho_i (§III-B), and the communication cost model.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// One (simulated) GPU: relative capability `c_i` (fastest = 1.0) and
/// background occupancy `rho_i` in [0, 1] (paper §III-B).
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    pub name: String,
    pub capability: f64,
    pub occupancy: f64,
}

impl DeviceConfig {
    pub fn new(name: impl Into<String>, capability: f64, occupancy: f64) -> Self {
        DeviceConfig { name: name.into(), capability, occupancy }
    }

    /// Effective speed v_i = c_i * (1 - rho_i) — the quantity Eq. 4 and
    /// Eq. 5 consume. The profiler refines this with measured history.
    pub fn effective_speed(&self) -> f64 {
        self.capability * (1.0 - self.occupancy)
    }
}

/// STADI scheduling hyperparameters (paper Eq. 4 and §V defaults).
#[derive(Debug, Clone)]
pub struct StadiParams {
    /// Base step count assigned to the fastest GPU (paper: 100).
    pub m_base: usize,
    /// Shared warmup steps (paper: 4).
    pub m_warmup: usize,
    /// Temporal-adaptation threshold `a` (paper: 0.75): devices with
    /// v_i > a*v_max keep M_base steps.
    pub a: f64,
    /// Exclusion threshold `b` (paper: 0.25): devices with
    /// v_i <= b*v_max are excluded from the cluster.
    pub b: f64,
    /// Ablation toggles (Table III): temporal adaptation (+TA) and
    /// spatial adaptation (+SA).
    pub temporal: bool,
    pub spatial: bool,
    /// EXTENSION: cost-aware patch mending (affine step-cost model
    /// instead of Eq. 5's linear assumption — fixes the paper's
    /// Fig. 9 caveat under heavy load gaps). Off by default for
    /// paper fidelity.
    pub cost_aware: bool,
}

impl Default for StadiParams {
    fn default() -> Self {
        StadiParams {
            m_base: 100,
            m_warmup: 4,
            a: 0.75,
            b: 0.25,
            temporal: true,
            spatial: true,
            cost_aware: false,
        }
    }
}

impl StadiParams {
    /// These params re-based onto a per-request step budget: M_base
    /// becomes `steps` and M_warmup is normalized to keep the grid
    /// invariants (warmup < steps, even remainder) — the bridge from
    /// a `GenerationSpec` step budget to a plannable parameter set.
    pub fn for_steps(&self, steps: usize) -> StadiParams {
        let steps = steps.max(2);
        StadiParams {
            m_base: steps,
            m_warmup: crate::sched::temporal::normalize_warmup(
                steps,
                self.m_warmup,
            ),
            ..self.clone()
        }
    }
}

/// Strategy for the uneven-size all-gather (paper §V "All-Gather for
/// uneven sized tensors"): pad to max then regular all-gather, or
/// emulate with per-rank broadcasts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnevenStrategy {
    PadAllGather,
    MultiBroadcast,
}

impl UnevenStrategy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pad" | "pad_all_gather" => Ok(UnevenStrategy::PadAllGather),
            "broadcast" | "multi_broadcast" => Ok(UnevenStrategy::MultiBroadcast),
            _ => Err(Error::Config(format!("unknown uneven strategy {s:?}"))),
        }
    }
}

/// alpha-beta communication cost model standing in for NCCL/PCIe
/// (DESIGN.md §3): transfer(n bytes) = latency + n / bandwidth.
#[derive(Debug, Clone)]
pub struct CommConfig {
    pub latency_s: f64,
    pub bandwidth_bytes_per_s: f64,
    pub uneven_strategy: UnevenStrategy,
}

impl Default for CommConfig {
    fn default() -> Self {
        // PCIe 4.0 x16-ish: ~20 GB/s effective, ~20 µs per collective
        // hop (matches the 2x RTX 4090 PCIe testbed of Table I).
        CommConfig {
            latency_s: 20e-6,
            bandwidth_bytes_per_s: 20e9,
            uneven_strategy: UnevenStrategy::PadAllGather,
        }
    }
}

/// Mid-flight re-planning knobs (EXTENSION past the paper's frozen
/// plans). When enabled, a session re-reads its *own* measured
/// per-step timings at the warmup barrier and every `every_k_syncs`
/// sync points after it; when the live speeds drift past
/// `drift_threshold` (max relative change vs the speeds the current
/// plan was built from), it re-runs the Eq. 4 suffix re-quantization
/// and the Eq. 5 elastic re-split over the *remaining* steps and
/// continues with migrated patch boundaries. Disabled by default: the
/// static path stays byte-identical to pre-replan behavior, and a
/// zero-drift re-plan is a structural no-op (golden-pinned).
#[derive(Debug, Clone)]
pub struct ReplanConfig {
    pub enabled: bool,
    /// Re-plan cadence after the warmup barrier, in sync points.
    pub every_k_syncs: usize,
    /// Max relative per-device speed change that still counts as
    /// "no drift". 0.0 re-evaluates at every re-plan point.
    pub drift_threshold: f64,
}

impl Default for ReplanConfig {
    fn default() -> Self {
        ReplanConfig {
            enabled: false,
            every_k_syncs: 4,
            drift_threshold: 0.05,
        }
    }
}

/// Cross-request batching knobs (EXTENSION past the paper's
/// one-request-per-gang serving). When enabled, the serve worker that
/// pops a request holds it in a bounded **admission window**
/// (`window_ms`) and gathers up to `max_batch - 1` further compatible
/// requests — same resolution, same effective step grid, same
/// effective halo budget (see `serve::batch::FuseKey`) — into one
/// *fused session*: a single lease, a single plan, per-request
/// seeds/latents executed in lockstep at the plan's sync barriers.
/// Disabled by default: the solo path stays byte-identical to
/// pre-batching behavior (pinned by `tests/integration_batch.rs`).
#[derive(Debug, Clone)]
pub struct BatchConfig {
    pub enabled: bool,
    /// Admission-window length in milliseconds: the longest a popped
    /// request may be parked waiting for compatible companions. 0
    /// fuses only requests already queued at pop time.
    pub window_ms: u64,
    /// Largest fused session (1 = batching off in all but name).
    pub max_batch: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { enabled: false, window_ms: 5, max_batch: 4 }
    }
}

/// Federated serving knobs (EXTENSION past the paper's single-process
/// coordinator). `nodes > 1` shards the serve front-end across that
/// many coordinator nodes — each wrapping its own engine core and
/// fleet slice — routed by `shard_policy` with spill-over admission
/// when the home node is saturated; `migrate` additionally allows an
/// in-flight request to move to a sibling node at a sync barrier via
/// a serialized [`MigrationEnvelope`](crate::federation), e.g. when
/// its node saturates or a device dies. The default (`nodes: 1`,
/// `migrate: false`) is the pre-federation single-node path, bit-exact
/// (pinned by `tests/integration_federation.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FederationConfig {
    /// Coordinator nodes in the front tier (1 = federation off).
    pub nodes: usize,
    /// Shard policy: `"least-loaded"` (backlog, then predicted
    /// latency) or `"hash"` (consistent-hash affinity for plan-cache
    /// warmth). Parsed by `federation::parse_shard_policy`.
    pub shard_policy: String,
    /// Allow barrier-checkpoint migration of in-flight requests.
    pub migrate: bool,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            nodes: 1,
            shard_policy: "least-loaded".into(),
            migrate: false,
        }
    }
}

/// Graceful-degradation knobs (EXTENSION past the paper's fixed
/// per-request quality). When enabled, the serve workers compute a
/// backlog-pressure signal (router backlog over capacity, plus the
/// latency predictor's deadline-budget deficit) and walk a demotion
/// ladder instead of shedding: crossing the k-th entry of
/// `pressure_thresholds` arms k rungs of admission-time quality-tier
/// demotion (high → standard → draft, re-keying the plan through the
/// `GenerationSpec` path) and, past the top threshold, mid-flight
/// step-suffix re-quantization at the next sync barrier (the drift
/// machinery's `requantize_suffix`, driven by queueing pressure).
/// Every rung is priced against the request's remaining deadline
/// budget by `predict_latency_for` — a request that still fits its
/// SLO is never degraded — and `floor` is the tier no request is
/// demoted below. Disabled by default: the serve path stays
/// bit-exact to pre-degradation behavior (pinned by
/// `tests/integration_degrade.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeConfig {
    pub enabled: bool,
    /// Strictly increasing pressure levels; crossing the k-th arms k
    /// ladder rungs. Pressure 0 (idle) is always below the first.
    pub pressure_thresholds: Vec<f64>,
    /// Quality tier demotion never crosses (ladder lower bound).
    pub floor: crate::spec::Quality,
}

impl Default for DegradeConfig {
    fn default() -> Self {
        DegradeConfig {
            enabled: false,
            pressure_thresholds: vec![1.0, 2.0],
            floor: crate::spec::Quality::Draft,
        }
    }
}

/// Halo-exchange mode at sync points (EXTENSION, DistriFusion-style
/// displaced patch parallelism adapted to STADI's sync schedule).
///
/// `Sync` is the paper's behavior: every sync point blocks on a full
/// x/KV all-gather. `Displaced { max_staleness }` publishes the local
/// boundary data without blocking and consumes the peers' most recent
/// *published* halos, as long as they are at most `max_staleness` sync
/// intervals old; warmup syncs, the first `max_staleness` intervals
/// (nothing old enough published yet) and the final sync (the gathered
/// clean image must be fresh) always fall back to the blocking
/// exchange. `Displaced { max_staleness: 0 }` is required — and tested
/// — to be byte-identical to `Sync`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum HaloMode {
    #[default]
    Sync,
    Displaced { max_staleness: usize },
}

impl HaloMode {
    /// The staleness budget this mode tolerates (0 for `Sync`).
    pub fn max_staleness(self) -> usize {
        match self {
            HaloMode::Sync => 0,
            HaloMode::Displaced { max_staleness } => max_staleness,
        }
    }

    /// True when the mode can ever skip a blocking exchange. A
    /// displaced mode with budget 0 is behaviorally `Sync` (and the
    /// executors treat it so), but keeps its spelled identity for
    /// round-trips.
    pub fn is_displaced(self) -> bool {
        matches!(self, HaloMode::Displaced { .. })
    }

    /// `"sync"` | `"displaced"` | `"displaced:N"`.
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s == "sync" {
            return Ok(HaloMode::Sync);
        }
        if s == "displaced" {
            return Ok(HaloMode::Displaced { max_staleness: 1 });
        }
        if let Some(n) = s.strip_prefix("displaced:") {
            let max_staleness = n.trim().parse::<usize>().map_err(|_| {
                Error::Config(format!(
                    "bad halo staleness budget {n:?} (expected \
                     displaced:<uint>)"
                ))
            })?;
            return Ok(HaloMode::Displaced { max_staleness });
        }
        Err(Error::Config(format!(
            "unknown halo mode {s:?} (expected sync | displaced | \
             displaced:N)"
        )))
    }

    pub fn as_string(self) -> String {
        match self {
            HaloMode::Sync => "sync".into(),
            HaloMode::Displaced { max_staleness } => {
                format!("displaced:{max_staleness}")
            }
        }
    }
}

/// How the engine executes a request (DESIGN.md §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Deterministic single-threaded dataflow execution (exact
    /// numerics; timing from the calibrated virtual clock).
    Dataflow,
    /// Real `std::thread` workers with channel-based collectives;
    /// heterogeneity imposed by stretching step durations.
    Threaded,
}

/// How the serve front-end drives its connections (CLI `--io`).
///
/// `Events` is the default: one poll thread owns a bounded connection
/// table (nonblocking sockets, `poll(2)`, per-connection buffers and
/// response reordering) while workers drain the router unchanged.
/// `Threads` keeps the pre-event-loop reader/reorder-writer thread
/// pair per connection — selectable for one release as the
/// byte-identical fallback, then retired (see DESIGN_SERVE.md
/// "Event-driven serving").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IoMode {
    #[default]
    Events,
    Threads,
}

impl IoMode {
    /// `"events"` | `"threads"`.
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim() {
            "events" => Ok(IoMode::Events),
            "threads" => Ok(IoMode::Threads),
            other => Err(Error::Config(format!(
                "unknown io mode {other:?} (expected events | threads)"
            ))),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            IoMode::Events => "events",
            IoMode::Threads => "threads",
        }
    }
}

/// Top-level engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: PathBuf,
    pub devices: Vec<DeviceConfig>,
    pub stadi: StadiParams,
    pub comm: CommConfig,
    pub mode: ExecMode,
    pub replan: ReplanConfig,
    /// Halo-exchange mode at sync points. Per-request quality tiers
    /// can only *tighten* the budget (effective budget =
    /// `min(config, tier)`), never loosen it.
    pub halo: HaloMode,
    /// Cross-request batching (fused sessions); off by default.
    pub batch: BatchConfig,
    /// Multi-node federated serving; off (single node) by default.
    pub federation: FederationConfig,
    /// Pressure-driven quality degradation; off by default.
    pub degrade: DegradeConfig,
}

impl EngineConfig {
    /// The paper's 2-GPU testbed with given occupancies, all defaults.
    pub fn two_gpu_default(artifacts: impl AsRef<Path>, occ: &[f64]) -> Self {
        let devices = occ
            .iter()
            .enumerate()
            .map(|(i, &o)| DeviceConfig::new(format!("gpu{i}"), 1.0, o))
            .collect();
        EngineConfig {
            artifacts_dir: artifacts.as_ref().to_path_buf(),
            devices,
            stadi: StadiParams::default(),
            comm: CommConfig::default(),
            mode: ExecMode::Dataflow,
            replan: ReplanConfig::default(),
            halo: HaloMode::default(),
            batch: BatchConfig::default(),
            federation: FederationConfig::default(),
            degrade: DegradeConfig::default(),
        }
    }

    /// Validate ranges and cross-field constraints.
    pub fn validate(&self) -> Result<()> {
        if self.devices.is_empty() {
            return Err(Error::Config("no devices configured".into()));
        }
        for d in &self.devices {
            if d.capability <= 0.0 || d.capability > 1.0 + 1e-9 {
                return Err(Error::Config(format!(
                    "{}: capability {} outside (0, 1]",
                    d.name, d.capability
                )));
            }
            if !(0.0..=1.0).contains(&d.occupancy) {
                return Err(Error::Config(format!(
                    "{}: occupancy {} outside [0, 1]",
                    d.name, d.occupancy
                )));
            }
            if d.occupancy >= 1.0 {
                return Err(Error::Config(format!(
                    "{}: occupancy 1.0 leaves no compute",
                    d.name
                )));
            }
        }
        let s = &self.stadi;
        if !(0.0 < s.b && s.b < s.a && s.a < 1.0) {
            return Err(Error::Config(format!(
                "need 0 < b < a < 1 (got a={}, b={})",
                s.a, s.b
            )));
        }
        if s.m_warmup >= s.m_base {
            return Err(Error::Config(format!(
                "M_warmup {} must be < M_base {}",
                s.m_warmup, s.m_base
            )));
        }
        if (s.m_base - s.m_warmup) % 2 != 0 {
            return Err(Error::Config(format!(
                "M_base - M_warmup must be even for the 2:1 LCM \
                 quantization (got {} - {})",
                s.m_base, s.m_warmup
            )));
        }
        if self.comm.bandwidth_bytes_per_s <= 0.0 || self.comm.latency_s < 0.0 {
            return Err(Error::Config("bad comm cost model".into()));
        }
        if self.replan.every_k_syncs == 0 {
            return Err(Error::Config(
                "replan.every_k_syncs must be >= 1".into(),
            ));
        }
        if self.replan.drift_threshold < 0.0
            || self.replan.drift_threshold.is_nan()
        {
            return Err(Error::Config(
                "replan.drift_threshold must be >= 0".into(),
            ));
        }
        if self.halo.max_staleness() > 1024 {
            return Err(Error::Config(format!(
                "halo staleness budget {} is nonsense (max 1024)",
                self.halo.max_staleness()
            )));
        }
        if self.batch.max_batch == 0 {
            return Err(Error::Config(
                "batch.max_batch must be >= 1".into(),
            ));
        }
        if self.batch.max_batch > 64 {
            return Err(Error::Config(format!(
                "batch.max_batch {} is nonsense (max 64)",
                self.batch.max_batch
            )));
        }
        if self.batch.window_ms > 60_000 {
            return Err(Error::Config(format!(
                "batch.window_ms {} is nonsense (max 60000)",
                self.batch.window_ms
            )));
        }
        if self.federation.nodes == 0 {
            return Err(Error::Config(
                "federation.nodes must be >= 1".into(),
            ));
        }
        if self.federation.nodes > 64 {
            return Err(Error::Config(format!(
                "federation.nodes {} is nonsense (max 64)",
                self.federation.nodes
            )));
        }
        match self.federation.shard_policy.as_str() {
            "least-loaded" | "hash" => {}
            other => {
                return Err(Error::Config(format!(
                    "unknown federation.shard_policy {other:?} \
                     (want \"least-loaded\" or \"hash\")",
                )));
            }
        }
        let th = &self.degrade.pressure_thresholds;
        if th.is_empty() || th.len() > 8 {
            return Err(Error::Config(format!(
                "degrade.pressure_thresholds needs 1..=8 entries \
                 (got {})",
                th.len()
            )));
        }
        for w in th.windows(2) {
            if !(w[0] < w[1]) {
                return Err(Error::Config(format!(
                    "degrade.pressure_thresholds must be strictly \
                     increasing (got {} then {})",
                    w[0], w[1]
                )));
            }
        }
        for &t in th {
            if !t.is_finite() || t <= 0.0 {
                return Err(Error::Config(format!(
                    "degrade.pressure_thresholds entries must be \
                     finite and > 0 (got {t})"
                )));
            }
        }
        Ok(())
    }

    /// Load from a JSON config file (see `examples/cluster.json` shape
    /// in README).
    pub fn from_json_file(path: &Path) -> Result<Self> {
        let v = json::from_file(path)?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let artifacts_dir = PathBuf::from(
            v.get_opt("artifacts_dir")
                .map(|x| x.as_str())
                .transpose()?
                .unwrap_or("artifacts"),
        );
        let mut devices = Vec::new();
        for (i, d) in v.get("devices")?.as_arr()?.iter().enumerate() {
            devices.push(DeviceConfig {
                name: d
                    .get_opt("name")
                    .map(|x| x.as_str().map(String::from))
                    .transpose()?
                    .unwrap_or_else(|| format!("gpu{i}")),
                capability: d
                    .get_opt("capability")
                    .map(|x| x.as_f64())
                    .transpose()?
                    .unwrap_or(1.0),
                occupancy: d
                    .get_opt("occupancy")
                    .map(|x| x.as_f64())
                    .transpose()?
                    .unwrap_or(0.0),
            });
        }
        let mut stadi = StadiParams::default();
        if let Some(s) = v.get_opt("stadi") {
            if let Some(x) = s.get_opt("m_base") {
                stadi.m_base = x.as_usize()?;
            }
            if let Some(x) = s.get_opt("m_warmup") {
                stadi.m_warmup = x.as_usize()?;
            }
            if let Some(x) = s.get_opt("a") {
                stadi.a = x.as_f64()?;
            }
            if let Some(x) = s.get_opt("b") {
                stadi.b = x.as_f64()?;
            }
            if let Some(x) = s.get_opt("temporal") {
                stadi.temporal = x.as_bool()?;
            }
            if let Some(x) = s.get_opt("spatial") {
                stadi.spatial = x.as_bool()?;
            }
            if let Some(x) = s.get_opt("cost_aware") {
                stadi.cost_aware = x.as_bool()?;
            }
        }
        let mut comm = CommConfig::default();
        if let Some(c) = v.get_opt("comm") {
            if let Some(x) = c.get_opt("latency_s") {
                comm.latency_s = x.as_f64()?;
            }
            if let Some(x) = c.get_opt("bandwidth_bytes_per_s") {
                comm.bandwidth_bytes_per_s = x.as_f64()?;
            }
            if let Some(x) = c.get_opt("uneven_strategy") {
                comm.uneven_strategy = UnevenStrategy::parse(x.as_str()?)?;
            }
        }
        let mode = match v.get_opt("mode").map(|x| x.as_str()).transpose()? {
            Some("threaded") => ExecMode::Threaded,
            _ => ExecMode::Dataflow,
        };
        let mut replan = ReplanConfig::default();
        if let Some(r) = v.get_opt("replan") {
            if let Some(x) = r.get_opt("enabled") {
                replan.enabled = x.as_bool()?;
            }
            if let Some(x) = r.get_opt("every_k_syncs") {
                replan.every_k_syncs = x.as_usize()?;
            }
            if let Some(x) = r.get_opt("drift_threshold") {
                replan.drift_threshold = x.as_f64()?;
            }
        }
        let halo = match v.get_opt("halo").map(|x| x.as_str()).transpose()? {
            Some(s) => HaloMode::parse(s)?,
            None => HaloMode::default(),
        };
        let mut batch = BatchConfig::default();
        if let Some(b) = v.get_opt("batch") {
            if let Some(x) = b.get_opt("enabled") {
                batch.enabled = x.as_bool()?;
            }
            if let Some(x) = b.get_opt("window_ms") {
                batch.window_ms = x.as_usize()? as u64;
            }
            if let Some(x) = b.get_opt("max_batch") {
                batch.max_batch = x.as_usize()?;
            }
        }
        let mut federation = FederationConfig::default();
        if let Some(f) = v.get_opt("federation") {
            if let Some(x) = f.get_opt("nodes") {
                federation.nodes = x.as_usize()?;
            }
            if let Some(x) = f.get_opt("shard_policy") {
                federation.shard_policy = x.as_str()?.to_string();
            }
            if let Some(x) = f.get_opt("migrate") {
                federation.migrate = x.as_bool()?;
            }
        }
        let mut degrade = DegradeConfig::default();
        if let Some(d) = v.get_opt("degrade") {
            if let Some(x) = d.get_opt("enabled") {
                degrade.enabled = x.as_bool()?;
            }
            if let Some(x) = d.get_opt("pressure_thresholds") {
                degrade.pressure_thresholds = x
                    .as_arr()?
                    .iter()
                    .map(|t| t.as_f64())
                    .collect::<Result<Vec<f64>>>()?;
            }
            if let Some(x) = d.get_opt("floor") {
                degrade.floor = crate::spec::Quality::parse(x.as_str()?)?;
            }
        }
        let cfg = EngineConfig {
            artifacts_dir,
            devices,
            stadi,
            comm,
            mode,
            replan,
            halo,
            batch,
            federation,
            degrade,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_two_gpu_validates() {
        let cfg = EngineConfig::two_gpu_default("artifacts", &[0.0, 0.4]);
        cfg.validate().unwrap();
        assert_eq!(cfg.devices.len(), 2);
        assert!((cfg.devices[1].effective_speed() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_thresholds() {
        let mut cfg = EngineConfig::two_gpu_default("artifacts", &[0.0]);
        cfg.stadi.a = 0.2;
        cfg.stadi.b = 0.5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn for_steps_rebases_and_stays_valid() {
        let base = StadiParams::default(); // m_base 100, warmup 4
        for steps in [2usize, 3, 5, 7, 8, 50, 101, 150] {
            let p = base.for_steps(steps);
            assert_eq!(p.m_base, steps);
            let mut cfg = EngineConfig::two_gpu_default("a", &[0.0]);
            cfg.stadi = p;
            cfg.validate().unwrap_or_else(|e| {
                panic!("for_steps({steps}) produced invalid params: {e}")
            });
        }
        // The default budget is untouched.
        let p = base.for_steps(100);
        assert_eq!((p.m_base, p.m_warmup), (100, 4));
    }

    #[test]
    fn rejects_odd_step_gap() {
        let mut cfg = EngineConfig::two_gpu_default("artifacts", &[0.0]);
        cfg.stadi.m_base = 101;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_full_occupancy() {
        let cfg = EngineConfig::two_gpu_default("artifacts", &[0.0, 1.0]);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn parses_json_config() {
        let text = r#"{
            "artifacts_dir": "artifacts",
            "devices": [
                {"name": "fast", "capability": 1.0, "occupancy": 0.0},
                {"capability": 0.8, "occupancy": 0.5}
            ],
            "stadi": {"m_base": 50, "m_warmup": 4, "a": 0.8, "b": 0.2},
            "comm": {"latency_s": 1e-05, "uneven_strategy": "broadcast"},
            "mode": "threaded"
        }"#;
        let cfg = EngineConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.devices[0].name, "fast");
        assert_eq!(cfg.devices[1].name, "gpu1");
        assert_eq!(cfg.stadi.m_base, 50);
        assert_eq!(cfg.comm.uneven_strategy, UnevenStrategy::MultiBroadcast);
        assert_eq!(cfg.mode, ExecMode::Threaded);
        assert!((cfg.comm.latency_s - 1e-5).abs() < 1e-18);
    }

    #[test]
    fn json_missing_devices_errors() {
        assert!(EngineConfig::from_json(&json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn replan_defaults_off_and_parses_from_json() {
        let cfg = EngineConfig::two_gpu_default("artifacts", &[0.0]);
        assert!(!cfg.replan.enabled, "replan must default off (PR-4 path)");
        let text = r#"{
            "devices": [{"name": "g0"}],
            "replan": {"enabled": true, "every_k_syncs": 2,
                       "drift_threshold": 0.1}
        }"#;
        let cfg = EngineConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert!(cfg.replan.enabled);
        assert_eq!(cfg.replan.every_k_syncs, 2);
        assert!((cfg.replan.drift_threshold - 0.1).abs() < 1e-12);
        // Invalid cadence / threshold are typed config errors.
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.replan.every_k_syncs = 0;
        assert!(bad.validate().is_err());
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.replan.drift_threshold = -0.5;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn batch_defaults_off_and_parses_from_json() {
        let cfg = EngineConfig::two_gpu_default("artifacts", &[0.0]);
        assert!(!cfg.batch.enabled, "batching must default off");
        // A config that never mentions "batch" is the pre-batching
        // config exactly.
        let text = r#"{"devices": [{"name": "g0"}]}"#;
        let cfg = EngineConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert!(!cfg.batch.enabled);
        assert_eq!(cfg.batch.max_batch, BatchConfig::default().max_batch);
        let text = r#"{
            "devices": [{"name": "g0"}],
            "batch": {"enabled": true, "window_ms": 12, "max_batch": 3}
        }"#;
        let cfg = EngineConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert!(cfg.batch.enabled);
        assert_eq!(cfg.batch.window_ms, 12);
        assert_eq!(cfg.batch.max_batch, 3);
        // Invalid knobs are typed config errors.
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.batch.max_batch = 0;
        assert!(bad.validate().is_err());
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.batch.max_batch = 1000;
        assert!(bad.validate().is_err());
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.batch.window_ms = 600_000;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn federation_defaults_off_and_parses_from_json() {
        let cfg = EngineConfig::two_gpu_default("artifacts", &[0.0]);
        assert_eq!(cfg.federation.nodes, 1, "federation must default off");
        assert!(!cfg.federation.migrate);
        // A config that never mentions "federation" is the
        // pre-federation config exactly.
        let text = r#"{"devices": [{"name": "g0"}]}"#;
        let cfg = EngineConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.federation.nodes, 1);
        assert_eq!(cfg.federation.shard_policy, "least-loaded");
        assert!(!cfg.federation.migrate);
        let text = r#"{
            "devices": [{"name": "g0"}],
            "federation": {
                "nodes": 3, "shard_policy": "hash", "migrate": true
            }
        }"#;
        let cfg = EngineConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.federation.nodes, 3);
        assert_eq!(cfg.federation.shard_policy, "hash");
        assert!(cfg.federation.migrate);
        // Invalid knobs are typed config errors.
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.federation.nodes = 0;
        assert!(bad.validate().is_err());
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.federation.nodes = 1000;
        assert!(bad.validate().is_err());
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.federation.shard_policy = "round-robin".into();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn degrade_defaults_off_and_parses_from_json() {
        let cfg = EngineConfig::two_gpu_default("artifacts", &[0.0]);
        assert!(!cfg.degrade.enabled, "degradation must default off");
        // A config that never mentions "degrade" is the
        // pre-degradation config exactly.
        let text = r#"{"devices": [{"name": "g0"}]}"#;
        let cfg = EngineConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.degrade, DegradeConfig::default());
        let text = r#"{
            "devices": [{"name": "g0"}],
            "degrade": {
                "enabled": true,
                "pressure_thresholds": [0.5, 1.5, 3.0],
                "floor": "standard"
            }
        }"#;
        let cfg = EngineConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert!(cfg.degrade.enabled);
        assert_eq!(cfg.degrade.pressure_thresholds, vec![0.5, 1.5, 3.0]);
        assert_eq!(cfg.degrade.floor, crate::spec::Quality::Standard);
        // Invalid knobs are typed config errors.
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.degrade.pressure_thresholds = vec![];
        assert!(bad.validate().is_err());
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.degrade.pressure_thresholds = vec![2.0, 1.0];
        assert!(bad.validate().is_err());
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.degrade.pressure_thresholds = vec![0.0];
        assert!(bad.validate().is_err());
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.degrade.pressure_thresholds = vec![f64::NAN];
        assert!(bad.validate().is_err());
        // An unknown floor tier is a parse error.
        let text = r#"{
            "devices": [{"name": "g0"}],
            "degrade": {"floor": "potato"}
        }"#;
        assert!(EngineConfig::from_json(&json::parse(text).unwrap()).is_err());
    }

    #[test]
    fn halo_mode_parses_round_trips_and_defaults_sync() {
        let cfg = EngineConfig::two_gpu_default("artifacts", &[0.0]);
        assert_eq!(cfg.halo, HaloMode::Sync, "halo must default to sync");
        assert_eq!(HaloMode::parse("sync").unwrap(), HaloMode::Sync);
        assert_eq!(
            HaloMode::parse("displaced").unwrap(),
            HaloMode::Displaced { max_staleness: 1 }
        );
        assert_eq!(
            HaloMode::parse("displaced:3").unwrap(),
            HaloMode::Displaced { max_staleness: 3 }
        );
        for m in [
            HaloMode::Sync,
            HaloMode::Displaced { max_staleness: 0 },
            HaloMode::Displaced { max_staleness: 7 },
        ] {
            assert_eq!(HaloMode::parse(&m.as_string()).unwrap(), m);
        }
        assert!(HaloMode::parse("async").is_err());
        assert!(HaloMode::parse("displaced:-1").is_err());
        assert!(HaloMode::parse("displaced:x").is_err());
        // JSON plumbing: `"halo"` is a string field of the config.
        let text = r#"{
            "devices": [{"name": "g0"}],
            "halo": "displaced:2"
        }"#;
        let cfg = EngineConfig::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.halo, HaloMode::Displaced { max_staleness: 2 });
        assert_eq!(cfg.halo.max_staleness(), 2);
        assert!(cfg.halo.is_displaced());
        // An absurd budget is a typed config error.
        let mut bad = EngineConfig::two_gpu_default("a", &[0.0]);
        bad.halo = HaloMode::Displaced { max_staleness: 4096 };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn io_mode_parses_round_trips_and_defaults_events() {
        assert_eq!(IoMode::default(), IoMode::Events);
        for m in [IoMode::Events, IoMode::Threads] {
            assert_eq!(IoMode::parse(m.as_str()).unwrap(), m);
        }
        assert_eq!(IoMode::parse(" events ").unwrap(), IoMode::Events);
        assert!(IoMode::parse("epoll").is_err());
        assert!(IoMode::parse("").is_err());
    }
}
