//! Crate-wide error type.
//!
//! The offline registry carries no `thiserror`/`anyhow` usable here, so
//! this is a plain hand-rolled enum. Every layer converts into it via
//! `From` so `?` composes across the runtime / scheduler / serving
//! boundaries.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All the ways the STADI stack can fail.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA failures (compile, execute, literal conversion).
    #[cfg(feature = "xla-backend")]
    Xla(xla::Error),
    /// Filesystem / socket errors.
    Io(std::io::Error),
    /// JSON parse errors from `util::json` (offset + message).
    Json { offset: usize, msg: String },
    /// Artifact manifest problems (missing file, shape mismatch...).
    Artifact(String),
    /// Configuration validation failures.
    Config(String),
    /// Scheduling infeasibility (e.g. all devices excluded by Eq. 4).
    Sched(String),
    /// Communication layer failures (peer gone, size mismatch).
    Comm(String),
    /// Serving protocol violations.
    Protocol(String),
    /// Admission control: the router queue is full. Carries the queue
    /// depth observed at rejection so the wire protocol can report it
    /// as a structured field rather than leaking it into the message.
    Busy { queue_depth: usize },
    /// Anything else.
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            #[cfg(feature = "xla-backend")]
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Sched(m) => write!(f, "sched: {m}"),
            Error::Comm(m) => write!(f, "comm: {m}"),
            Error::Protocol(m) => write!(f, "protocol: {m}"),
            Error::Busy { queue_depth } => {
                write!(f, "busy: queue full (depth {queue_depth})")
            }
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {}

#[cfg(feature = "xla-backend")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Convenience constructor for ad-hoc errors.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Other(m.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Sched("no eligible devices".into());
        assert_eq!(e.to_string(), "sched: no eligible devices");
        let e = Error::Json { offset: 12, msg: "bad token".into() };
        assert!(e.to_string().contains("byte 12"));
    }

    #[test]
    fn busy_carries_depth() {
        let e = Error::Busy { queue_depth: 7 };
        assert!(e.to_string().contains("depth 7"));
        assert!(matches!(e, Error::Busy { queue_depth: 7 }));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
