//! Crate-wide error type.
//!
//! The offline registry carries no `thiserror`/`anyhow` usable here, so
//! this is a plain hand-rolled enum. Every layer converts into it via
//! `From` so `?` composes across the runtime / scheduler / serving
//! boundaries.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All the ways the STADI stack can fail.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA failures (compile, execute, literal conversion).
    #[cfg(feature = "xla-backend")]
    Xla(xla::Error),
    /// Filesystem / socket errors.
    Io(std::io::Error),
    /// JSON parse errors from `util::json` (offset + message).
    Json { offset: usize, msg: String },
    /// Artifact manifest problems (missing file, shape mismatch...).
    Artifact(String),
    /// Configuration validation failures.
    Config(String),
    /// Scheduling infeasibility (e.g. all devices excluded by Eq. 4).
    Sched(String),
    /// Communication layer failures (peer gone, size mismatch).
    Comm(String),
    /// Serving protocol violations (malformed request lines, missing
    /// fields — wire code `bad_request`).
    Protocol(String),
    /// Invalid per-request [`GenerationSpec`](crate::spec::GenerationSpec)
    /// — out-of-range fields, negative seeds, non-executable
    /// resolutions (wire code `bad_spec`).
    Spec(String),
    /// The request's deadline passed before service started; the
    /// router sheds it on dequeue (wire code `deadline`). Carries the
    /// requested budget and how late dequeue was, as structured fields.
    DeadlineExceeded { deadline_s: f64, late_by_s: f64 },
    /// The server is shutting down / the router is closed (wire code
    /// `shutdown`).
    Shutdown,
    /// Admission control: the router queue is full. Carries the queue
    /// depth observed at rejection so the wire protocol can report it
    /// as a structured field rather than leaking it into the message.
    Busy { queue_depth: usize },
    /// Anything else.
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            #[cfg(feature = "xla-backend")]
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Sched(m) => write!(f, "sched: {m}"),
            Error::Comm(m) => write!(f, "comm: {m}"),
            Error::Protocol(m) => write!(f, "protocol: {m}"),
            Error::Spec(m) => write!(f, "spec: {m}"),
            Error::DeadlineExceeded { deadline_s, late_by_s } => write!(
                f,
                "deadline exceeded: {deadline_s}s budget missed by \
                 {late_by_s:.3}s before service started"
            ),
            Error::Shutdown => write!(f, "server shutting down"),
            Error::Busy { queue_depth } => {
                write!(f, "busy: queue full (depth {queue_depth})")
            }
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {}

#[cfg(feature = "xla-backend")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Convenience constructor for ad-hoc errors.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Other(m.into())
    }

    /// Stable machine-readable wire code for error response lines.
    /// Clients dispatch on this, never on the message text.
    pub fn wire_code(&self) -> &'static str {
        match self {
            Error::Busy { .. } => "busy",
            Error::Spec(_) => "bad_spec",
            Error::DeadlineExceeded { .. } => "deadline",
            Error::Shutdown => "shutdown",
            Error::Json { .. } | Error::Protocol(_) => "bad_request",
            _ => "error",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::Sched("no eligible devices".into());
        assert_eq!(e.to_string(), "sched: no eligible devices");
        let e = Error::Json { offset: 12, msg: "bad token".into() };
        assert!(e.to_string().contains("byte 12"));
    }

    #[test]
    fn busy_carries_depth() {
        let e = Error::Busy { queue_depth: 7 };
        assert!(e.to_string().contains("depth 7"));
        assert!(matches!(e, Error::Busy { queue_depth: 7 }));
    }

    #[test]
    fn wire_codes_are_stable() {
        assert_eq!(Error::Busy { queue_depth: 1 }.wire_code(), "busy");
        assert_eq!(Error::Spec("x".into()).wire_code(), "bad_spec");
        assert_eq!(
            Error::DeadlineExceeded { deadline_s: 1.0, late_by_s: 0.1 }
                .wire_code(),
            "deadline"
        );
        assert_eq!(Error::Shutdown.wire_code(), "shutdown");
        assert_eq!(
            Error::Json { offset: 0, msg: "x".into() }.wire_code(),
            "bad_request"
        );
        assert_eq!(Error::Protocol("x".into()).wire_code(), "bad_request");
        assert_eq!(Error::Sched("x".into()).wire_code(), "error");
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
