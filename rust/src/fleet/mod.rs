//! Fleet allocation: which GPUs does each request run on?
//!
//! PR 1 made the serve stack concurrent, but every session still
//! planned over the *whole* cluster, so N in-flight requests contended
//! for the same simulated GPUs and throughput could not scale with
//! load. This subsystem partitions the fleet instead:
//!
//! * [`FleetManager`] — grants disjoint RAII [`GpuLease`]s over device
//!   subsets. A lease releases its devices on `Drop`, which makes the
//!   worker pool's `catch_unwind` path automatically lease-safe: a
//!   panicking job unwinds through the lease and frees its GPUs.
//! * [`GangPolicy`] — the admission-control brain: given the free
//!   devices, current load, per-device effective speeds, and
//!   (optionally) a latency predictor, choose the gang for the next
//!   request. Baselines [`AllGpus`] and [`FixedGang`]; the
//!   [`Adaptive`] policy picks the min-predicted-latency gang at low
//!   load and shards the fleet into small heterogeneity-balanced
//!   gangs under queueing pressure (the granularity shift DistriFusion
//!   and hybrid data/pipeline-parallel serving systems observe).
//! * [`EngineCore::session_on`](crate::coordinator::EngineCore::session_on)
//!   — opens a session whose Eq. 4 / Eq. 5 plan is restricted to the
//!   leased subset, so gangs execute truly concurrently.
//!
//! The STADI allocators (paper §III-B/C) are subset-agnostic — Eq. 4
//! normalizes speeds to the gang's own v_max and Eq. 5 mends patches
//! over whatever devices it is given — which is exactly what makes
//! gang partitioning viable on heterogeneous clusters.
//!
//! See rust/DESIGN_SERVE.md §"Fleet allocation" for the lease
//! lifecycle and lock-ordering rules.

pub mod manager;
pub mod policy;

pub use manager::{FleetManager, GpuLease, SlotJoin};
pub use policy::{
    parse_policy, Adaptive, AllGpus, BatchAware, Deadline, FixedGang,
    GangPolicy, PolicyCtx,
};
