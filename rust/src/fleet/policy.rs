//! Gang-size policies: how much of the fleet does one request get?
//!
//! The tradeoff is classic: a bigger gang finishes one request sooner
//! (until sync overhead wins), while many small gangs serve more
//! requests at once. The right granularity shifts with load — the
//! observation behind DistriFusion-style patch parallelism and hybrid
//! data/pipeline parallel serving — so the policy sees the live queue
//! depth and, optionally, a latency predictor (the scheduler's own
//! `simulate_latency` timeline) and decides per request.

use crate::error::{Error, Result};
use crate::spec::Priority;

/// Everything a policy may consult when choosing a gang.
pub struct PolicyCtx<'a> {
    /// Per-device effective speeds, indexed by *global* device id
    /// (the profiler's normalized estimates).
    pub speeds: &'a [f64],
    /// Requests waiting behind the one being placed.
    pub queue_depth: usize,
    /// Leases currently outstanding.
    pub in_flight: usize,
    /// Predicted end-to-end latency of running one request on a
    /// candidate gang (global device ids); `None` entries mean the
    /// subset is unplannable. Policies must tolerate a missing
    /// predictor (offline / degraded mode). The serving path binds
    /// this per request (it closes over the request's
    /// [`GenerationSpec`](crate::spec::GenerationSpec)), so the
    /// prediction prices the request's own steps and rows.
    pub predict: Option<&'a dyn Fn(&[usize]) -> Option<f64>>,
    /// Priority tier of the request being placed.
    pub priority: Priority,
    /// Seconds left until the request's deadline (`None` = no SLO;
    /// may be ≤ 0 if it expired while waiting for a lease).
    pub deadline_s: Option<f64>,
}

impl PolicyCtx<'_> {
    fn predict_gang(&self, gang: &[usize]) -> Option<f64> {
        self.predict.and_then(|p| p(gang))
    }
}

/// Chooses the device gang for the next request.
///
/// Contract: `choose` is a pure function of `(free, ctx)`; it must
/// return a duplicate-free subset of `free` (the manager validates and
/// errors otherwise), or `None` to wait for the next lease release.
/// It must never block and never assume it will be called again with
/// the same snapshot.
pub trait GangPolicy: Send + Sync {
    /// Display name ("all", "fixed:2", "adaptive").
    fn name(&self) -> String;

    /// Pick a gang from `free`, or `None` to wait.
    fn choose(&self, free: &[usize], ctx: &PolicyCtx) -> Option<Vec<usize>>;
}

/// Baseline: every request takes the whole cluster (PR 1 behavior).
/// Minimizes single-request latency; serializes the fleet.
pub struct AllGpus;

impl GangPolicy for AllGpus {
    fn name(&self) -> String {
        "all".into()
    }

    fn choose(&self, free: &[usize], ctx: &PolicyCtx) -> Option<Vec<usize>> {
        if free.len() == ctx.speeds.len() {
            Some(free.to_vec())
        } else {
            None
        }
    }
}

/// Baseline: every request gets the `k` fastest free devices.
pub struct FixedGang(pub usize);

impl GangPolicy for FixedGang {
    fn name(&self) -> String {
        format!("fixed:{}", self.0)
    }

    fn choose(&self, free: &[usize], ctx: &PolicyCtx) -> Option<Vec<usize>> {
        let k = self.0.max(1);
        if free.len() < k {
            return None;
        }
        let sorted = by_speed_desc(free, ctx.speeds);
        Some(sorted[..k].to_vec())
    }
}

/// Adaptive gang sizing: min-predicted-latency gangs when the queue is
/// empty, many small heterogeneity-balanced gangs under load.
///
/// * Low load (`queue_depth < load_threshold`): evaluate the latency
///   predictor on every fastest-first prefix of the free set and take
///   the cheapest — adding a straggler to a gang is only worth it
///   while Eq. 4/5 can absorb it, and the predictor (the scheduler's
///   own simulated timeline) knows exactly where that stops. Without a
///   predictor it falls back to the whole free set.
/// * High load: split the free devices across the waiting demand
///   (`queue_depth + 1` requests), picking gang members fast/slow
///   alternately so each gang gets a balanced speed mix instead of one
///   all-fast and one all-straggler gang.
pub struct Adaptive {
    /// Queue depth at which the policy switches from min-latency to
    /// fleet-sharding mode.
    pub load_threshold: usize,
}

impl Default for Adaptive {
    fn default() -> Self {
        Adaptive { load_threshold: 1 }
    }
}

impl GangPolicy for Adaptive {
    fn name(&self) -> String {
        "adaptive".into()
    }

    fn choose(&self, free: &[usize], ctx: &PolicyCtx) -> Option<Vec<usize>> {
        if free.is_empty() {
            return None;
        }
        let sorted = by_speed_desc(free, ctx.speeds);
        if ctx.queue_depth < self.load_threshold {
            return Some(min_latency_prefix(&sorted, ctx));
        }
        // Shard mode: give this request ceil(free / demand) devices so
        // the waiting requests behind it can gang up on the rest.
        let demand = ctx.queue_depth + 1;
        let k = sorted.len().div_ceil(demand).max(1);
        Some(balanced_pick(&sorted, k))
    }
}

/// SLO-driven gang sizing: give each request the *fewest* GPUs that
/// still meet its deadline, and only fall back to latency-optimal
/// gangs when no SLO is attached.
///
/// * With a deadline and a predictor: take the smallest fastest-first
///   prefix whose predicted latency (scaled by `slack`) fits the
///   remaining budget — a small/urgent request (tight deadline but a
///   cheap spec) lands on one or two GPUs and leaves the rest free
///   for concurrent requests. If nothing fits (deadline already blown
///   or the request is simply too big), fall back to the
///   min-predicted-latency prefix: best effort beats giving up.
/// * Without a deadline: high-priority requests get the min-latency
///   prefix; everything else defers to [`Adaptive`] (shard under
///   load).
pub struct Deadline {
    /// Multiplicative safety margin on predicted latency (prediction
    /// is a model, not a measurement).
    pub slack: f64,
    fallback: Adaptive,
}

impl Default for Deadline {
    fn default() -> Self {
        Deadline { slack: 1.2, fallback: Adaptive::default() }
    }
}

impl GangPolicy for Deadline {
    fn name(&self) -> String {
        "deadline".into()
    }

    fn choose(&self, free: &[usize], ctx: &PolicyCtx) -> Option<Vec<usize>> {
        if free.is_empty() {
            return None;
        }
        let sorted = by_speed_desc(free, ctx.speeds);
        if let Some(budget) = ctx.deadline_s {
            for k in 1..=sorted.len() {
                if let Some(t) = ctx.predict_gang(&sorted[..k]) {
                    if t * self.slack <= budget {
                        return Some(sorted[..k].to_vec());
                    }
                }
            }
            return Some(min_latency_prefix(&sorted, ctx));
        }
        if ctx.priority == Priority::High {
            return Some(min_latency_prefix(&sorted, ctx));
        }
        self.fallback.choose(free, ctx)
    }
}

/// Batch-slot-aware gang sizing: like [`Adaptive`], but the shard
/// divisor assumes each gang can serve up to `max_batch` queued
/// requests as one fused session — so under backlog the policy hands
/// out *fewer, larger* gangs than demand-per-gang sharding would, and
/// the batching layer fills the slots. Demand that cannot batch
/// (incompatible shapes) still drains: a gang is never smaller than
/// the plain adaptive shard would make the *batched* demand.
///
/// Low load behaves exactly like [`Adaptive`] — there is nothing to
/// fuse, so min-predicted-latency gangs win.
pub struct BatchAware {
    /// Largest fused session the serve layer will assemble; the
    /// divisor that converts queued requests into expected gangs.
    pub max_batch: usize,
    inner: Adaptive,
}

impl BatchAware {
    pub fn new(max_batch: usize) -> Self {
        BatchAware { max_batch: max_batch.max(1), inner: Adaptive::default() }
    }
}

impl GangPolicy for BatchAware {
    fn name(&self) -> String {
        format!("batched:{}", self.max_batch)
    }

    fn choose(&self, free: &[usize], ctx: &PolicyCtx) -> Option<Vec<usize>> {
        if free.is_empty() {
            return None;
        }
        if ctx.queue_depth < self.inner.load_threshold {
            return self.inner.choose(free, ctx);
        }
        // Fused demand: `queue_depth + 1` requests collapse into
        // ceil(demand / max_batch) expected sessions; shard the free
        // set across those instead of across raw requests.
        let sessions =
            (ctx.queue_depth + 1).div_ceil(self.max_batch).max(1);
        let sorted = by_speed_desc(free, ctx.speeds);
        let k = sorted.len().div_ceil(sessions).max(1);
        Some(balanced_pick(&sorted, k))
    }
}

/// Min-predicted-latency fastest-first prefix (fastest-first prefixes
/// are the natural candidates: a slower device only ever joins after
/// every faster one). Whole free set when no prefix can be priced.
fn min_latency_prefix(sorted_desc: &[usize], ctx: &PolicyCtx) -> Vec<usize> {
    let mut best: Option<(f64, usize)> = None;
    for k in 1..=sorted_desc.len() {
        if let Some(t) = ctx.predict_gang(&sorted_desc[..k]) {
            let better = match best {
                None => true,
                Some((bt, _)) => t < bt,
            };
            if better {
                best = Some((t, k));
            }
        }
    }
    let k = match best {
        Some((_, k)) => k,
        None => sorted_desc.len(), // no predictor: take everything
    };
    sorted_desc[..k].to_vec()
}

/// Free devices sorted fastest-first (stable: ties keep id order).
fn by_speed_desc(free: &[usize], speeds: &[f64]) -> Vec<usize> {
    let mut v = free.to_vec();
    v.sort_by(|&a, &b| {
        speeds[b]
            .partial_cmp(&speeds[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    v
}

/// Take `k` devices from a fastest-first list, alternating ends, so
/// the gang's speed mix mirrors the fleet's (heterogeneity-balanced):
/// the leftovers are equally balanced for the next gang.
fn balanced_pick(sorted_desc: &[usize], k: usize) -> Vec<usize> {
    let mut gang = Vec::with_capacity(k);
    let (mut lo, mut hi) = (0usize, sorted_desc.len());
    while gang.len() < k && lo < hi {
        gang.push(sorted_desc[lo]);
        lo += 1;
        if gang.len() < k && lo < hi {
            hi -= 1;
            gang.push(sorted_desc[hi]);
        }
    }
    gang
}

/// Parse a `--gang-policy` spec: `all`, `fixed:K`, `adaptive`,
/// `deadline`, or `batched:K`.
pub fn parse_policy(spec: &str) -> Result<Box<dyn GangPolicy>> {
    if spec == "all" {
        return Ok(Box::new(AllGpus));
    }
    if spec == "adaptive" {
        return Ok(Box::new(Adaptive::default()));
    }
    if spec == "deadline" {
        return Ok(Box::new(Deadline::default()));
    }
    if let Some(k) = spec.strip_prefix("fixed:") {
        let k: usize = k.parse().map_err(|_| {
            Error::Config(format!("bad gang size in {spec:?}"))
        })?;
        if k == 0 {
            return Err(Error::Config("fixed gang size must be >= 1".into()));
        }
        return Ok(Box::new(FixedGang(k)));
    }
    if let Some(k) = spec.strip_prefix("batched:") {
        let k: usize = k.parse().map_err(|_| {
            Error::Config(format!("bad batch size in {spec:?}"))
        })?;
        if k == 0 {
            return Err(Error::Config("batch size must be >= 1".into()));
        }
        return Ok(Box::new(BatchAware::new(k)));
    }
    Err(Error::Config(format!(
        "unknown gang policy {spec:?} (expected all | fixed:K | adaptive \
         | deadline | batched:K)"
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(
        speeds: &'a [f64],
        queue_depth: usize,
        predict: Option<&'a dyn Fn(&[usize]) -> Option<f64>>,
    ) -> PolicyCtx<'a> {
        PolicyCtx {
            speeds,
            queue_depth,
            in_flight: 0,
            predict,
            priority: Priority::Normal,
            deadline_s: None,
        }
    }

    #[test]
    fn all_gpus_waits_unless_fleet_is_whole() {
        let speeds = [1.0, 0.8, 0.6];
        assert_eq!(
            AllGpus.choose(&[0, 1, 2], &ctx(&speeds, 0, None)),
            Some(vec![0, 1, 2])
        );
        assert_eq!(AllGpus.choose(&[0, 2], &ctx(&speeds, 5, None)), None);
    }

    #[test]
    fn fixed_gang_takes_fastest_free() {
        let speeds = [0.5, 1.0, 0.9, 0.2];
        let got = FixedGang(2)
            .choose(&[0, 1, 2, 3], &ctx(&speeds, 0, None))
            .unwrap();
        assert_eq!(got, vec![1, 2]);
        // Not enough free devices -> wait.
        assert_eq!(FixedGang(3).choose(&[0, 3], &ctx(&speeds, 0, None)), None);
    }

    #[test]
    fn adaptive_low_load_minimizes_predicted_latency() {
        let speeds = [1.0, 0.9, 0.3];
        // Predictor: the straggler (device 2) makes any gang slower.
        let predict = |gang: &[usize]| -> Option<f64> {
            Some(if gang.contains(&2) {
                1.0
            } else {
                0.5 / gang.len() as f64
            })
        };
        let got = Adaptive::default()
            .choose(&[0, 1, 2], &ctx(&speeds, 0, Some(&predict)))
            .unwrap();
        assert_eq!(got, vec![0, 1], "should stop before the straggler");
        // No predictor -> whole free set.
        let got = Adaptive::default()
            .choose(&[0, 1, 2], &ctx(&speeds, 0, None))
            .unwrap();
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn adaptive_high_load_shards_balanced() {
        let speeds = [1.0, 0.9, 0.8, 0.5];
        // One request waiting behind us: split 4 free devices 2+2,
        // pairing fastest with slowest.
        let got = Adaptive::default()
            .choose(&[0, 1, 2, 3], &ctx(&speeds, 1, None))
            .unwrap();
        assert_eq!(got, vec![0, 3]);
        // Three waiting: 4/4 -> singleton gangs, fastest first.
        let got = Adaptive::default()
            .choose(&[0, 1, 2, 3], &ctx(&speeds, 3, None))
            .unwrap();
        assert_eq!(got, vec![0]);
    }

    #[test]
    fn balanced_pick_alternates_ends() {
        assert_eq!(balanced_pick(&[10, 11, 12, 13], 2), vec![10, 13]);
        assert_eq!(balanced_pick(&[10, 11, 12, 13], 3), vec![10, 13, 11]);
        assert_eq!(balanced_pick(&[10], 3), vec![10]);
    }

    /// Toy predictor: gang latency = 1 / total speed (bigger = faster,
    /// diminishing returns).
    fn pooled_predict(speeds: &'static [f64]) -> impl Fn(&[usize]) -> Option<f64>
    {
        move |gang: &[usize]| {
            let cap: f64 = gang.iter().map(|&d| speeds[d]).sum();
            if cap <= 0.0 {
                None
            } else {
                Some(1.0 / cap)
            }
        }
    }

    #[test]
    fn deadline_policy_takes_fewest_gpus_meeting_the_slo() {
        static SPEEDS: &[f64] = &[1.0, 0.9, 0.8, 0.5];
        let predict = pooled_predict(SPEEDS);
        let p = Deadline::default(); // slack 1.2
        // One GPU predicts 1.0s; budget 2s fits with slack -> 1 GPU.
        let mut c = ctx(SPEEDS, 0, Some(&predict));
        c.deadline_s = Some(2.0);
        assert_eq!(p.choose(&[0, 1, 2, 3], &c).unwrap(), vec![0]);
        // Tighter budget: 1 GPU (1.2 > 0.7) fails, 2 GPUs predict
        // 1/1.9 = 0.53, *1.2 = 0.63 <= 0.7 -> exactly 2.
        c.deadline_s = Some(0.7);
        assert_eq!(p.choose(&[0, 1, 2, 3], &c).unwrap(), vec![0, 1]);
        // Impossible budget: best effort = min-latency prefix (all 4
        // under this monotone toy predictor).
        c.deadline_s = Some(0.01);
        assert_eq!(p.choose(&[0, 1, 2, 3], &c).unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn deadline_policy_without_slo_uses_priority_and_fallback() {
        static SPEEDS: &[f64] = &[1.0, 0.9, 0.8, 0.5];
        let predict = pooled_predict(SPEEDS);
        let p = Deadline::default();
        // High priority, no deadline -> latency-optimal prefix.
        let mut c = ctx(SPEEDS, 0, Some(&predict));
        c.priority = Priority::High;
        assert_eq!(p.choose(&[0, 1, 2, 3], &c).unwrap(), vec![0, 1, 2, 3]);
        // Normal priority under load -> the adaptive shard fallback
        // (2 waiting + this one over 4 free = 2-device gangs).
        let c2 = ctx(SPEEDS, 2, None);
        let got = p.choose(&[0, 1, 2, 3], &c2).unwrap();
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn batch_aware_shards_by_fused_demand() {
        let speeds = [1.0, 0.9, 0.8, 0.5];
        // 3 waiting + this one = 4 requests; max_batch 4 fuses them
        // into 1 expected session -> the whole free set, where plain
        // adaptive sharding would hand out singletons.
        let got = BatchAware::new(4)
            .choose(&[0, 1, 2, 3], &ctx(&speeds, 3, None))
            .unwrap();
        assert_eq!(got.len(), 4);
        let adaptive = Adaptive::default()
            .choose(&[0, 1, 2, 3], &ctx(&speeds, 3, None))
            .unwrap();
        assert_eq!(adaptive, vec![0]);
        // 7 waiting + 1 = 8 over batches of 4 -> 2 sessions -> 2-device
        // balanced gangs.
        let got = BatchAware::new(4)
            .choose(&[0, 1, 2, 3], &ctx(&speeds, 7, None))
            .unwrap();
        assert_eq!(got, vec![0, 3]);
        // max_batch 1 degenerates to adaptive sharding exactly.
        let got = BatchAware::new(1)
            .choose(&[0, 1, 2, 3], &ctx(&speeds, 3, None))
            .unwrap();
        assert_eq!(got, adaptive);
        // Low load: identical to adaptive (min-latency prefix path).
        let got = BatchAware::new(4)
            .choose(&[0, 1, 2, 3], &ctx(&speeds, 0, None))
            .unwrap();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(parse_policy("all").unwrap().name(), "all");
        assert_eq!(parse_policy("fixed:3").unwrap().name(), "fixed:3");
        assert_eq!(parse_policy("adaptive").unwrap().name(), "adaptive");
        assert_eq!(parse_policy("deadline").unwrap().name(), "deadline");
        assert_eq!(parse_policy("batched:4").unwrap().name(), "batched:4");
        assert!(parse_policy("fixed:0").is_err());
        assert!(parse_policy("fixed:x").is_err());
        assert!(parse_policy("batched:0").is_err());
        assert!(parse_policy("batched:x").is_err());
        assert!(parse_policy("bogus").is_err());
    }
}
