//! The fleet ledger: disjoint RAII GPU leases, with opt-in batch slots.
//!
//! `FleetManager` tracks per-device slot occupancy. A grant hands back
//! a [`GpuLease`] whose `Drop` returns the devices and wakes blocked
//! acquirers — so release is tied to scope, not to a code path: early
//! returns, `?` propagation, and panics unwinding through the serve
//! worker's `catch_unwind` all release correctly. Leases are granted
//! *exclusive*; a fused-batch host opts into sharing via
//! [`GpuLease::open_slots`], after which compatible requests attach
//! through [`FleetManager::try_join`] (RAII [`SlotJoin`]) instead of
//! waiting for a free gang.
//!
//! Locking: one `Mutex<Ledger>` guarding the in-use bitmap plus a
//! `Condvar` signalled on every release. The mutex is held only for
//! bookkeeping — never across policy evaluation, latency prediction,
//! planning, or execution: `acquire` snapshots the free set, runs the
//! policy (and its planner-backed predictor) *unlocked*, then
//! revalidates against fresh state before granting, retrying if a
//! concurrent grant/release changed the ledger in between (detected
//! via a generation counter, so no wakeup can be missed). All ledger
//! accesses recover from poisoning — the ledger is consistent at
//! every lock boundary, and the waiter count is restored by an RAII
//! guard, so even a panicking policy cannot brick the fleet.

use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

use crate::error::{Error, Result};
use crate::fleet::policy::{GangPolicy, PolicyCtx};
use crate::spec::Priority;

#[derive(Debug)]
struct Ledger {
    /// `used[d]` = batch slots of device `d` currently occupied. 0 =
    /// free; 1 = exclusively leased (the only state the pre-batching
    /// ledger had); >1 = a shared lease plus joined batch members.
    used: Vec<u32>,
    /// `share_cap[d]` = slot capacity the *owning lease* opened on
    /// device `d` via [`GpuLease::open_slots`]. 0 (the grant default)
    /// means exclusive — joins refused — so every pre-batching code
    /// path behaves bit-identically.
    share_cap: Vec<u32>,
    /// Acquirers currently blocked in [`FleetManager::acquire`] — the
    /// admission layer's natural queue-depth signal.
    waiters: usize,
    /// Leases currently outstanding.
    active: usize,
    /// Monotone grant counter (lease ids).
    granted: u64,
    /// Bumped on every grant and release; lets `acquire` detect state
    /// changes that happened while the policy ran unlocked.
    generation: u64,
}

#[derive(Debug)]
struct Inner {
    n: usize,
    ledger: Mutex<Ledger>,
    /// Signalled whenever devices return to the pool.
    freed: Condvar,
}

impl Inner {
    /// Lock the ledger, recovering from poisoning: every mutation
    /// keeps the ledger consistent at lock boundaries, so a panic on
    /// some other thread (e.g. in a policy's predictor) must not turn
    /// every later fleet operation into a panic of its own.
    fn ledger(&self) -> MutexGuard<'_, Ledger> {
        self.ledger.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Keeps `Ledger::waiters` honest across every exit path of
/// [`FleetManager::acquire`] — early errors, grants, and panics in the
/// (unlocked) policy evaluation all decrement on drop.
struct WaiterGuard<'a> {
    inner: &'a Inner,
}

impl<'a> WaiterGuard<'a> {
    fn new(inner: &'a Inner) -> Self {
        inner.ledger().waiters += 1;
        WaiterGuard { inner }
    }
}

impl Drop for WaiterGuard<'_> {
    fn drop(&mut self) {
        self.inner.ledger().waiters -= 1;
    }
}

/// Grants disjoint device leases; cheap to clone and share.
#[derive(Clone, Debug)]
pub struct FleetManager {
    inner: Arc<Inner>,
}

/// RAII lease over a device subset. Devices return to the pool on
/// `Drop` — including when a panicking job unwinds through it.
#[derive(Debug)]
pub struct GpuLease {
    inner: Arc<Inner>,
    devices: Vec<usize>,
    id: u64,
}

impl FleetManager {
    pub fn new(n_devices: usize) -> Self {
        assert!(n_devices > 0, "fleet needs at least one device");
        FleetManager {
            inner: Arc::new(Inner {
                n: n_devices,
                ledger: Mutex::new(Ledger {
                    used: vec![0; n_devices],
                    share_cap: vec![0; n_devices],
                    waiters: 0,
                    active: 0,
                    granted: 0,
                    generation: 0,
                }),
                freed: Condvar::new(),
            }),
        }
    }

    pub fn num_devices(&self) -> usize {
        self.inner.n
    }

    /// Devices not currently leased, ascending. A shared device with
    /// joiners still attached is NOT free — it returns to the pool
    /// only when its last slot (owner or joiner) drops.
    pub fn free_devices(&self) -> Vec<usize> {
        free_of(&self.inner.ledger().used)
    }

    /// Leases currently outstanding.
    pub fn in_flight(&self) -> usize {
        self.inner.ledger().active
    }

    /// Acquirers currently blocked in [`FleetManager::acquire`].
    pub fn waiters(&self) -> usize {
        self.inner.ledger().waiters
    }

    /// Total leases ever granted (monotone). Tests use it to assert a
    /// rejected request never touched the fleet.
    pub fn granted_total(&self) -> u64 {
        self.inner.ledger().granted
    }

    /// Validate a requested gang: non-empty, in range, no duplicates.
    fn validate(&self, devices: &[usize]) -> Result<()> {
        if devices.is_empty() {
            return Err(Error::Sched("empty gang requested".into()));
        }
        let mut seen = vec![false; self.inner.n];
        for &d in devices {
            if d >= self.inner.n {
                return Err(Error::Sched(format!(
                    "device {d} out of range (fleet has {})",
                    self.inner.n
                )));
            }
            if seen[d] {
                return Err(Error::Sched(format!(
                    "device {d} requested twice in one gang"
                )));
            }
            seen[d] = true;
        }
        Ok(())
    }

    /// Try to lease exactly `devices`. `Ok(None)` when any of them is
    /// already leased; `Err` on an invalid request (out of range,
    /// duplicate, empty). Never blocks.
    pub fn try_acquire(&self, devices: &[usize]) -> Result<Option<GpuLease>> {
        self.validate(devices)?;
        let mut g = self.inner.ledger();
        if devices.iter().any(|&d| g.used[d] > 0) {
            return Ok(None);
        }
        Ok(Some(self.grant(&mut g, devices)))
    }

    /// Try to join an in-flight shared lease on exactly `devices`:
    /// succeeds only when every device is currently leased by an owner
    /// that opened batch slots ([`GpuLease::open_slots`]) and has a
    /// slot spare. `Ok(None)` otherwise (exclusive lease, full, or
    /// free — a free device needs a real lease, not a join). The
    /// returned RAII guard occupies one slot per device until dropped;
    /// the devices stay un-free until owner *and* all joiners release.
    /// Never blocks.
    pub fn try_join(&self, devices: &[usize]) -> Result<Option<SlotJoin>> {
        self.validate(devices)?;
        let mut g = self.inner.ledger();
        let joinable = |d: usize| {
            g.used[d] >= 1 && g.share_cap[d] > 0 && g.used[d] < g.share_cap[d]
        };
        if !devices.iter().all(|&d| joinable(d)) {
            return Ok(None);
        }
        for &d in devices {
            g.used[d] += 1;
        }
        g.generation += 1;
        let mut sorted = devices.to_vec();
        sorted.sort_unstable();
        Ok(Some(SlotJoin { inner: Arc::clone(&self.inner), devices: sorted }))
    }

    /// Block until `policy` picks a grantable gang from the free set,
    /// then lease it. The policy sees the live load — queue depth =
    /// other blocked acquirers plus the caller-supplied `backlog`
    /// (e.g. the router's queued-job count) — and the in-flight lease
    /// count, so it can shift from min-latency gangs to many small
    /// gangs as load builds.
    ///
    /// The policy and its predictor run **without** the ledger lock
    /// (prediction is a full planner pass — holding the lock would
    /// serialize every admission and lease release behind it): the
    /// free set is snapshotted, the choice is made unlocked, then
    /// revalidated against fresh state before granting. A concurrent
    /// grant/release in between just retries on the new snapshot.
    ///
    /// A policy returning `None` (e.g. [`AllGpus`](crate::fleet::AllGpus)
    /// while any device is busy) waits for the next release. Progress
    /// is guaranteed as long as leases are eventually dropped — which
    /// RAII plus the worker's `catch_unwind` ensures.
    pub fn acquire(
        &self,
        policy: &dyn GangPolicy,
        speeds: &[f64],
        predict: Option<&dyn Fn(&[usize]) -> Option<f64>>,
        backlog: usize,
    ) -> Result<GpuLease> {
        self.acquire_for(
            policy,
            speeds,
            predict,
            backlog,
            Priority::Normal,
            None,
        )
    }

    /// [`Self::acquire`] with the request's shape attached: priority
    /// tier and remaining deadline budget flow into the
    /// [`PolicyCtx`], so SLO-aware policies (e.g.
    /// [`Deadline`](crate::fleet::Deadline)) can size the gang against
    /// *this* request rather than an average one. The deadline budget
    /// is re-measured against the wall clock on every retry of the
    /// snapshot loop — time spent blocked waiting for a lease counts
    /// against the SLO.
    pub fn acquire_for(
        &self,
        policy: &dyn GangPolicy,
        speeds: &[f64],
        predict: Option<&dyn Fn(&[usize]) -> Option<f64>>,
        backlog: usize,
        priority: Priority,
        deadline: Option<std::time::Instant>,
    ) -> Result<GpuLease> {
        if speeds.len() != self.inner.n {
            return Err(Error::Sched(format!(
                "speeds length {} != fleet size {}",
                speeds.len(),
                self.inner.n
            )));
        }
        // RAII waiter registration: early errors, grants, and panics
        // inside the (unlocked) policy all restore the count.
        let _waiter = WaiterGuard::new(&self.inner);
        loop {
            // Snapshot under the lock...
            let (free, queue_depth, in_flight, gen) = {
                let g = self.inner.ledger();
                (
                    free_of(&g.used),
                    // This acquirer is demand, not queue: depth counts
                    // the requests waiting *behind* it.
                    g.waiters - 1 + backlog,
                    g.active,
                    g.generation,
                )
            };
            // ...choose unlocked (this may run the full planner)...
            let decision = if free.is_empty() {
                None
            } else {
                let now = std::time::Instant::now();
                let ctx = PolicyCtx {
                    speeds,
                    queue_depth,
                    in_flight,
                    predict,
                    priority,
                    // Signed remaining budget: negative once blown, so
                    // the policy sees "already late" rather than a
                    // vanished SLO.
                    deadline_s: deadline.map(|d| {
                        if d >= now {
                            (d - now).as_secs_f64()
                        } else {
                            -((now - d).as_secs_f64())
                        }
                    }),
                };
                policy.choose(&free, &ctx)
            };
            // ...revalidate and grant against fresh state.
            let mut g = self.inner.ledger();
            match decision {
                Some(gang) => {
                    self.validate(&gang)?;
                    if let Some(&bad) =
                        gang.iter().find(|&&d| !free.contains(&d))
                    {
                        // Contract violation, not staleness: the
                        // device was never in the snapshot shown.
                        return Err(Error::Sched(format!(
                            "policy {} chose device {bad} outside the \
                             free set",
                            policy.name()
                        )));
                    }
                    if gang.iter().all(|&d| g.used[d] == 0) {
                        return Ok(self.grant(&mut g, &gang));
                    }
                    // A concurrent grant took one of our devices while
                    // the policy ran; retry on the new snapshot.
                }
                None => {
                    if free.len() == self.inner.n {
                        // The policy refused the *fully idle* fleet; a
                        // pure policy will refuse every (smaller) free
                        // set too, so waiting can only deadlock (e.g.
                        // FixedGang(k) with k > fleet size).
                        return Err(Error::Sched(format!(
                            "policy {} refused the fully idle fleet",
                            policy.name()
                        )));
                    }
                    // Sleep only if nothing changed since the
                    // snapshot; a grant/release that slipped in while
                    // the policy ran must trigger an immediate retry,
                    // not a missed wakeup.
                    if g.generation == gen {
                        drop(
                            self.inner
                                .freed
                                .wait(g)
                                .unwrap_or_else(PoisonError::into_inner),
                        );
                    }
                }
            }
        }
    }

    fn grant(
        &self,
        g: &mut MutexGuard<'_, Ledger>,
        devices: &[usize],
    ) -> GpuLease {
        for &d in devices {
            debug_assert!(g.used[d] == 0, "double-granting device {d}");
            g.used[d] = 1;
            g.share_cap[d] = 0;
        }
        g.active += 1;
        g.granted += 1;
        g.generation += 1;
        let mut sorted = devices.to_vec();
        sorted.sort_unstable();
        GpuLease {
            inner: Arc::clone(&self.inner),
            devices: sorted,
            id: g.granted,
        }
    }
}

fn free_of(used: &[u32]) -> Vec<usize> {
    used.iter()
        .enumerate()
        .filter(|(_, &u)| u == 0)
        .map(|(d, _)| d)
        .collect()
}

impl GpuLease {
    /// Leased device indices, ascending.
    pub fn devices(&self) -> &[usize] {
        &self.devices
    }

    /// Monotone grant id (diagnostics / trace correlation).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Open this lease's devices for batch-slot joins: up to `cap`
    /// total slots per device (owner included), so `cap - 1` compatible
    /// requests can attach via [`FleetManager::try_join`] while this
    /// lease is in flight. `cap <= 1` keeps/returns the lease to
    /// exclusive. Leases are granted exclusive — sharing is opt-in per
    /// lease, which is what keeps every non-batching caller's
    /// disjointness guarantees (and the property tests pinning them)
    /// intact.
    pub fn open_slots(&self, cap: u32) {
        let mut g = self.inner.ledger();
        for &d in &self.devices {
            g.share_cap[d] = cap.max(1);
        }
        g.generation += 1;
    }

    /// Close the join window early (the fused session's gate no longer
    /// accepts members): new joins are refused, already-joined slots
    /// drain on their own schedule. Idempotent; `Drop` does this too.
    pub fn close_slots(&self) {
        let mut g = self.inner.ledger();
        for &d in &self.devices {
            g.share_cap[d] = 0;
        }
        g.generation += 1;
    }
}

impl Drop for GpuLease {
    fn drop(&mut self) {
        // Inner::ledger recovers from poisoning: a panic here while
        // *this* thread unwinds through the worker's catch_unwind
        // would abort the process.
        let mut g = self.inner.ledger();
        for &d in &self.devices {
            debug_assert!(g.used[d] >= 1, "releasing an unleased device {d}");
            g.used[d] -= 1;
            // The owner is gone: no new joins, whatever joiner slots
            // remain keep the device un-free until they drop.
            g.share_cap[d] = 0;
        }
        g.active -= 1;
        g.generation += 1;
        // Releases can unblock several waiters (small-gang policies).
        self.inner.freed.notify_all();
    }
}

/// RAII batch-slot membership on an in-flight shared lease (see
/// [`FleetManager::try_join`]). Dropping releases the slots and wakes
/// blocked acquirers — the last slot out returns the devices to the
/// pool.
#[derive(Debug)]
pub struct SlotJoin {
    inner: Arc<Inner>,
    devices: Vec<usize>,
}

impl SlotJoin {
    /// Joined device indices, ascending.
    pub fn devices(&self) -> &[usize] {
        &self.devices
    }
}

impl Drop for SlotJoin {
    fn drop(&mut self) {
        let mut g = self.inner.ledger();
        for &d in &self.devices {
            debug_assert!(g.used[d] >= 1, "releasing an unjoined device {d}");
            g.used[d] -= 1;
        }
        g.generation += 1;
        self.inner.freed.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::policy::{AllGpus, FixedGang};
    use std::thread;

    #[test]
    fn try_acquire_grants_and_releases() {
        let m = FleetManager::new(4);
        let lease = m.try_acquire(&[1, 3]).unwrap().unwrap();
        assert_eq!(lease.devices(), &[1, 3]);
        assert_eq!(m.free_devices(), vec![0, 2]);
        assert_eq!(m.in_flight(), 1);
        // Overlap refused, disjoint remainder grantable.
        assert!(m.try_acquire(&[0, 1]).unwrap().is_none());
        let rest = m.try_acquire(&[0, 2]).unwrap().unwrap();
        assert!(m.free_devices().is_empty());
        drop(lease);
        assert_eq!(m.free_devices(), vec![1, 3]);
        drop(rest);
        assert_eq!(m.free_devices(), vec![0, 1, 2, 3]);
        assert_eq!(m.in_flight(), 0);
    }

    #[test]
    fn invalid_requests_error() {
        let m = FleetManager::new(2);
        assert!(m.try_acquire(&[]).is_err());
        assert!(m.try_acquire(&[2]).is_err());
        assert!(m.try_acquire(&[0, 0]).is_err());
        // Errors must not leak partial state.
        assert_eq!(m.free_devices(), vec![0, 1]);
    }

    #[test]
    fn release_on_panic_unwind() {
        let m = FleetManager::new(2);
        let m2 = m.clone();
        let r = std::panic::catch_unwind(move || {
            let _lease = m2.try_acquire(&[0, 1]).unwrap().unwrap();
            panic!("job died");
        });
        assert!(r.is_err());
        // The unwind dropped the lease: the fleet is whole again.
        assert_eq!(m.free_devices(), vec![0, 1]);
        assert_eq!(m.in_flight(), 0);
    }

    #[test]
    fn blocking_acquire_waits_for_release() {
        let m = FleetManager::new(2);
        let held = m.try_acquire(&[0]).unwrap().unwrap();
        let waiter = {
            let m = m.clone();
            thread::spawn(move || {
                // AllGpus needs both devices -> blocks until `held`
                // drops.
                m.acquire(&AllGpus, &[1.0, 1.0], None, 0).unwrap()
            })
        };
        // Let the waiter actually block (registered as a waiter).
        while m.waiters() == 0 {
            thread::yield_now();
        }
        drop(held);
        let lease = waiter.join().unwrap();
        assert_eq!(lease.devices(), &[0, 1]);
    }

    #[test]
    fn impossible_policy_errors_instead_of_deadlock() {
        // FixedGang(3) on a 2-device fleet can never be satisfied;
        // with nothing leased, acquire must error, not block forever.
        let m = FleetManager::new(2);
        assert!(m.acquire(&FixedGang(3), &[1.0, 1.0], None, 0).is_err());
        assert_eq!(m.waiters(), 0);
        assert_eq!(m.free_devices(), vec![0, 1]);
    }

    #[test]
    fn panicking_policy_does_not_brick_the_fleet() {
        // The policy runs unlocked, so its panic must not poison the
        // ledger, and the RAII waiter guard must restore the count —
        // otherwise one buggy policy turns every later acquire into a
        // panic (or inflates queue_depth forever).
        struct PanicPolicy;
        impl GangPolicy for PanicPolicy {
            fn name(&self) -> String {
                "panic".into()
            }
            fn choose(
                &self,
                _free: &[usize],
                _ctx: &PolicyCtx,
            ) -> Option<Vec<usize>> {
                panic!("policy bug")
            }
        }
        let m = FleetManager::new(2);
        let m2 = m.clone();
        let r = std::panic::catch_unwind(move || {
            let _ = m2.acquire(&PanicPolicy, &[1.0, 1.0], None, 0);
        });
        assert!(r.is_err());
        assert_eq!(m.waiters(), 0, "waiter count leaked");
        // The fleet still works: no poison, nothing marked in use.
        let lease = m.acquire(&FixedGang(1), &[1.0, 1.0], None, 0).unwrap();
        assert_eq!(lease.devices().len(), 1);
        drop(lease);
        assert_eq!(m.free_devices(), vec![0, 1]);
    }

    #[test]
    fn concurrent_acquirers_never_overlap() {
        // 3 devices, 6 threads each leasing 1-fastest gangs repeatedly:
        // the ledger must never double-grant (debug_asserts in
        // grant/drop) and counts must reconcile.
        let m = FleetManager::new(3);
        let speeds = [1.0, 0.9, 0.8];
        let threads: Vec<_> = (0..6)
            .map(|_| {
                let m = m.clone();
                thread::spawn(move || {
                    for _ in 0..50 {
                        let lease = m
                            .acquire(&FixedGang(1), &speeds, None, 0)
                            .unwrap();
                        assert_eq!(lease.devices().len(), 1);
                        thread::yield_now();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(m.free_devices(), vec![0, 1, 2]);
        assert_eq!(m.in_flight(), 0);
    }

    #[test]
    fn slot_joins_share_an_open_lease_and_respect_capacity() {
        let m = FleetManager::new(4);
        let lease = m.try_acquire(&[0, 1]).unwrap().unwrap();
        // Exclusive by default: joins refused everywhere.
        assert!(m.try_join(&[0, 1]).unwrap().is_none());
        // cap 3 = owner + 2 joiners.
        lease.open_slots(3);
        let j1 = m.try_join(&[0, 1]).unwrap().unwrap();
        assert_eq!(j1.devices(), &[0, 1]);
        let j2 = m.try_join(&[0, 1]).unwrap().unwrap();
        // Full: a third join is refused.
        assert!(m.try_join(&[0, 1]).unwrap().is_none());
        // A join must cover leased+open devices only — free devices
        // and partial overlaps are refused, not half-joined.
        assert!(m.try_join(&[2]).unwrap().is_none());
        assert!(m.try_join(&[1, 2]).unwrap().is_none());
        // Shared devices stay un-free and un-leasable for outsiders.
        assert_eq!(m.free_devices(), vec![2, 3]);
        assert!(m.try_acquire(&[0]).unwrap().is_none());
        // One joiner out -> a slot frees up again.
        drop(j1);
        let j3 = m.try_join(&[0, 1]).unwrap().unwrap();
        // Owner closes the window: no new joins, existing ones drain.
        lease.close_slots();
        assert!(m.try_join(&[0, 1]).unwrap().is_none());
        // Owner released while joiners remain: devices still un-free.
        drop(lease);
        assert_eq!(m.free_devices(), vec![2, 3]);
        assert_eq!(m.in_flight(), 0);
        drop(j2);
        drop(j3);
        // Last slot out returns the devices to the pool.
        assert_eq!(m.free_devices(), vec![0, 1, 2, 3]);
        let again = m.try_acquire(&[0, 1]).unwrap();
        assert!(again.is_some());
    }

    #[test]
    fn slot_release_wakes_blocked_acquirers() {
        let m = FleetManager::new(1);
        let lease = m.try_acquire(&[0]).unwrap().unwrap();
        lease.open_slots(2);
        let join = m.try_join(&[0]).unwrap().unwrap();
        drop(lease); // owner gone, joiner still holds the device
        let waiter = {
            let m = m.clone();
            thread::spawn(move || {
                m.acquire(&AllGpus, &[1.0], None, 0).unwrap()
            })
        };
        while m.waiters() == 0 {
            thread::yield_now();
        }
        drop(join); // last slot out must notify the waiter
        let lease = waiter.join().unwrap();
        assert_eq!(lease.devices(), &[0]);
    }

    #[test]
    fn slot_join_released_on_panic_unwind() {
        // A fused-session member that panics while holding a SlotJoin
        // must not leak its device slots — the batch-slot mirror of
        // release_on_panic_unwind above.
        let m = FleetManager::new(2);
        let lease = m.try_acquire(&[0, 1]).unwrap().unwrap();
        lease.open_slots(2); // owner + 1 joiner
        let m2 = m.clone();
        let r = std::panic::catch_unwind(move || {
            let _join = m2.try_join(&[0, 1]).unwrap().unwrap();
            panic!("fused member died");
        });
        assert!(r.is_err());
        // The unwind dropped the join: the slot is spare again, the
        // ledger did not poison, and counts stayed consistent.
        let j = m.try_join(&[0, 1]).unwrap().unwrap();
        assert_eq!(j.devices(), &[0, 1]);
        drop(j);
        drop(lease);
        // Owner and joiners all gone: the fleet is whole again.
        assert_eq!(m.free_devices(), vec![0, 1]);
        assert_eq!(m.in_flight(), 0);
        assert!(m.try_acquire(&[0, 1]).unwrap().is_some());
    }

    #[test]
    fn property_random_interleavings_stay_disjoint() {
        use crate::util::proptest::{ensure, forall};
        // Random acquire/release sequences against a shadow model: a
        // try_acquire must succeed iff its gang is disjoint from every
        // outstanding lease, and the free set must always equal the
        // shadow's complement.
        forall(
            23,
            150,
            |rng| {
                let n_ops = 4 + rng.below(40) as usize;
                (0..n_ops)
                    .map(|_| {
                        // op encoding: (kind, a, b) — kind 0 = acquire
                        // the gang {a..=b mod n}, kind 1 = release the
                        // (a mod live)-th outstanding lease.
                        vec![
                            rng.below(3) as usize, // acquire twice as often
                            rng.below(4) as usize,
                            rng.below(4) as usize,
                        ]
                    })
                    .collect::<Vec<Vec<usize>>>()
            },
            |ops| {
                let n = 4usize;
                let m = FleetManager::new(n);
                let mut live: Vec<GpuLease> = Vec::new();
                let mut shadow = vec![false; n];
                for op in ops {
                    if op.len() < 3 {
                        continue; // shrunk-away op
                    }
                    let (kind, a, b) = (op[0], op[1] % n, op[2] % n);
                    if kind < 2 {
                        let (lo, hi) = (a.min(b), a.max(b));
                        let gang: Vec<usize> = (lo..=hi).collect();
                        let want_free =
                            gang.iter().all(|&d| !shadow[d]);
                        match m.try_acquire(&gang) {
                            Err(e) => {
                                return Err(format!("acquire err: {e}"))
                            }
                            Ok(Some(lease)) => {
                                ensure(
                                    want_free,
                                    "granted an overlapping lease",
                                )?;
                                for &d in lease.devices() {
                                    shadow[d] = true;
                                }
                                live.push(lease);
                            }
                            Ok(None) => {
                                ensure(
                                    !want_free,
                                    "refused a disjoint lease",
                                )?;
                            }
                        }
                    } else if !live.is_empty() {
                        let i = a % live.len();
                        let lease = live.swap_remove(i);
                        for &d in lease.devices() {
                            shadow[d] = false;
                        }
                        drop(lease);
                    }
                    let want: Vec<usize> = (0..n)
                        .filter(|&d| !shadow[d])
                        .collect();
                    ensure(
                        m.free_devices() == want,
                        "free set diverged from shadow model",
                    )?;
                    ensure(
                        m.in_flight() == live.len(),
                        "active-lease count diverged",
                    )?;
                }
                Ok(())
            },
        );
    }
}
