//! The versioned barrier-checkpoint wire format.
//!
//! A request frozen at a sync barrier is fully determined by one
//! `(x, kv)` snapshot (every included device holds the identical
//! gathered latent and fully-published KV stack — the fully-fresh
//! invariant [`Session::execute_to_barrier`] restores), the remaining
//! fast-grid suffix, the STADI params the plan was built under, and
//! the virtual clock. [`MigrationEnvelope`] packages exactly that,
//! with an explicit `version` gate so a node running an older tier
//! rejects an envelope it cannot faithfully resume instead of
//! rendering a silently different image.

use crate::config::StadiParams;
use crate::coordinator::{BarrierCheckpoint, Session};
use crate::error::{Error, Result};
use crate::runtime::Tensor;
use crate::sched::replan::fast_suffix_of;
use crate::util::json::{Object, Value};

/// Current envelope schema version. Bump on any field change; decoders
/// reject other versions (see DESIGN_SERVE.md "Federation & migration").
pub const ENVELOPE_VERSION: usize = 1;

/// A serialized barrier checkpoint: everything a destination node
/// needs to resume the request — on any device count — plus the clock
/// to resume under. Produced by [`MigrationEnvelope::capture`],
/// consumed by [`resume_envelope_on`](crate::federation::resume_envelope_on).
#[derive(Debug, Clone)]
pub struct MigrationEnvelope {
    /// Schema version ([`ENVELOPE_VERSION`]).
    pub version: usize,
    /// The request's seed (conditioning is re-derived from it).
    pub seed: u64,
    /// Sync points of the source plan completed at the checkpoint.
    pub synced: usize,
    /// Source virtual clock at the handoff.
    pub elapsed_s: f64,
    /// Portion of `elapsed_s` that was blocking communication.
    pub comm_s: f64,
    /// Remaining fast-grid timesteps (the Full-class reference grid).
    pub fast_suffix: Vec<usize>,
    /// STADI params the source plan was built under (the destination
    /// re-plans the suffix under the same Eq. 4/5 knobs).
    pub params: StadiParams,
    /// Latent rows the request spans (Eq. 5 re-splits these).
    pub total_rows: usize,
    /// Gathered full latent at the barrier.
    pub x: Tensor,
    /// Fully-published KV stack at the barrier.
    pub kv: Tensor,
}

impl MigrationEnvelope {
    /// Seal a [`BarrierCheckpoint`] of `session` into an envelope.
    /// Returns `Ok(None)` when the barrier leaves nothing migratable
    /// (at most the final step remains) — finish locally instead.
    pub fn capture(
        session: &Session,
        ckpt: &BarrierCheckpoint,
        seed: u64,
    ) -> Result<Option<MigrationEnvelope>> {
        let plan = session.plan();
        let fast_suffix = match fast_suffix_of(plan, ckpt.synced)? {
            Some(fs) => fs,
            None => return Ok(None),
        };
        // Fully fresh means any included device's buffers will do.
        let d = plan.included_devices().next().ok_or_else(|| {
            Error::Sched("checkpointed plan has no included device".into())
        })?;
        let bufs = &ckpt.exec.bufs[d.device];
        Ok(Some(MigrationEnvelope {
            version: ENVELOPE_VERSION,
            seed,
            synced: ckpt.synced,
            elapsed_s: ckpt.sim.now,
            comm_s: ckpt.sim.comm_s,
            fast_suffix,
            params: plan.params.clone(),
            total_rows: plan.total_rows(),
            x: bufs.x.clone(),
            kv: bufs.kv.clone(),
        }))
    }

    /// Bytes a cross-node transfer of this envelope's state moves (the
    /// latent and KV payloads; scalar header ignored). This is what
    /// the destination charges via
    /// [`SimState::charge_migration`](crate::coordinator::timeline::SimState::charge_migration).
    pub fn payload_bytes(&self) -> u64 {
        (self.x.byte_len() + self.kv.byte_len()) as u64
    }

    pub fn to_json(&self) -> Value {
        let mut o = Object::new();
        o.insert("version", Value::Num(self.version as f64));
        o.insert("seed", Value::Num(self.seed as f64));
        o.insert("synced", Value::Num(self.synced as f64));
        o.insert("elapsed_s", Value::Num(self.elapsed_s));
        o.insert("comm_s", Value::Num(self.comm_s));
        o.insert("fast_suffix", Value::from_usize_slice(&self.fast_suffix));
        let mut p = Object::new();
        p.insert("m_base", Value::Num(self.params.m_base as f64));
        p.insert("m_warmup", Value::Num(self.params.m_warmup as f64));
        p.insert("a", Value::Num(self.params.a));
        p.insert("b", Value::Num(self.params.b));
        p.insert("temporal", Value::Bool(self.params.temporal));
        p.insert("spatial", Value::Bool(self.params.spatial));
        p.insert("cost_aware", Value::Bool(self.params.cost_aware));
        o.insert("params", Value::Obj(p));
        o.insert("total_rows", Value::Num(self.total_rows as f64));
        o.insert("x", tensor_json(&self.x));
        o.insert("kv", tensor_json(&self.kv));
        Value::Obj(o)
    }

    /// Decode an envelope, rejecting unknown schema versions with a
    /// typed error — a node must never guess at fields it does not
    /// understand and resume a subtly different request.
    pub fn from_json(v: &Value) -> Result<MigrationEnvelope> {
        let version = v.get("version")?.as_usize()?;
        if version != ENVELOPE_VERSION {
            return Err(Error::Protocol(format!(
                "migration envelope version {version} unsupported \
                 (this node speaks {ENVELOPE_VERSION})"
            )));
        }
        let p = v.get("params")?;
        let params = StadiParams {
            m_base: p.get("m_base")?.as_usize()?,
            m_warmup: p.get("m_warmup")?.as_usize()?,
            a: p.get("a")?.as_f64()?,
            b: p.get("b")?.as_f64()?,
            temporal: p.get("temporal")?.as_bool()?,
            spatial: p.get("spatial")?.as_bool()?,
            cost_aware: p.get("cost_aware")?.as_bool()?,
        };
        Ok(MigrationEnvelope {
            version,
            seed: v.get("seed")?.as_f64()? as u64,
            synced: v.get("synced")?.as_usize()?,
            elapsed_s: v.get("elapsed_s")?.as_f64()?,
            comm_s: v.get("comm_s")?.as_f64()?,
            fast_suffix: v.get("fast_suffix")?.usizes()?,
            params,
            total_rows: v.get("total_rows")?.as_usize()?,
            x: tensor_from_json(v.get("x")?)?,
            kv: tensor_from_json(v.get("kv")?)?,
        })
    }
}

fn tensor_json(t: &Tensor) -> Value {
    let mut o = Object::new();
    o.insert("shape", Value::from_usize_slice(&t.shape));
    o.insert("data", Value::from_f32_slice(&t.data));
    Value::Obj(o)
}

fn tensor_from_json(v: &Value) -> Result<Tensor> {
    Tensor::new(v.get("shape")?.usizes()?, v.get("data")?.f32s()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn fixture() -> MigrationEnvelope {
        MigrationEnvelope {
            version: ENVELOPE_VERSION,
            seed: 42,
            synced: 3,
            elapsed_s: 1.25,
            comm_s: 0.125,
            fast_suffix: vec![6, 4, 2, 0],
            params: StadiParams {
                m_base: 8,
                m_warmup: 2,
                ..StadiParams::default()
            },
            total_rows: 32,
            x: Tensor::new(vec![2, 2], vec![1.0, -2.0, 0.5, 4.0]).unwrap(),
            kv: Tensor::new(vec![1, 3], vec![0.0, 7.0, -1.5]).unwrap(),
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        let env = fixture();
        let text = json::to_string(&env.to_json());
        let back =
            MigrationEnvelope::from_json(&json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(back.version, env.version);
        assert_eq!(back.seed, env.seed);
        assert_eq!(back.synced, env.synced);
        assert_eq!(back.elapsed_s, env.elapsed_s);
        assert_eq!(back.comm_s, env.comm_s);
        assert_eq!(back.fast_suffix, env.fast_suffix);
        assert_eq!(back.params.m_base, env.params.m_base);
        assert_eq!(back.params.m_warmup, env.params.m_warmup);
        assert_eq!(back.params.a, env.params.a);
        assert_eq!(back.params.b, env.params.b);
        assert_eq!(back.total_rows, env.total_rows);
        assert_eq!(back.x, env.x);
        assert_eq!(back.kv, env.kv);
        assert_eq!(
            back.payload_bytes(),
            env.payload_bytes(),
            "payload accounting must survive the wire"
        );
    }

    #[test]
    fn unknown_version_is_rejected() {
        let env = fixture();
        let mut v = env.to_json();
        if let Value::Obj(o) = &mut v {
            o.insert("version", Value::Num((ENVELOPE_VERSION + 1) as f64));
        }
        let e = MigrationEnvelope::from_json(&v).unwrap_err();
        assert!(matches!(e, Error::Protocol(_)), "{e}");
    }

    #[test]
    fn payload_counts_latent_and_kv_bytes() {
        let env = fixture();
        assert_eq!(env.payload_bytes(), (4 + 3) * 4);
    }
}
