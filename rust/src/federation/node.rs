//! One coordinator node: an engine core plus its fleet slice.

use std::sync::Arc;

use crate::coordinator::EngineCore;
use crate::error::Result;
use crate::federation::shard::NodeView;
use crate::fleet::{FleetManager, GpuLease};
use crate::spec::GenerationSpec;

/// A federation member: its own [`EngineCore`] (artifacts, profiler,
/// plan cache, virtual cluster) and its own [`FleetManager`] ledger.
/// The tier never reaches into a sibling's core — state crosses nodes
/// only through a serialized
/// [`MigrationEnvelope`](crate::federation::MigrationEnvelope).
pub struct CoordinatorNode {
    id: usize,
    core: Arc<EngineCore>,
    fleet: FleetManager,
}

impl CoordinatorNode {
    pub fn new(id: usize, core: Arc<EngineCore>) -> Self {
        let fleet = core.fleet();
        CoordinatorNode { id, core, fleet }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn core(&self) -> &Arc<EngineCore> {
        &self.core
    }

    pub fn fleet(&self) -> &FleetManager {
        &self.fleet
    }

    /// Every device of this node's cluster, ascending.
    pub fn all_devices(&self) -> Vec<usize> {
        (0..self.fleet.num_devices()).collect()
    }

    /// Non-blocking whole-node admission: lease the full cluster, or
    /// answer busy (`Ok(None)`) **without** touching the grant ledger —
    /// the property spill-over admission is pinned on
    /// (`FleetManager::granted_total` stays put on a busy answer).
    pub fn try_admit(&self) -> Result<Option<GpuLease>> {
        self.fleet.try_acquire(&self.all_devices())
    }

    /// This node's load snapshot for the shard policy: fleet backlog
    /// and occupancy plus the node's own planner-backed latency
    /// prediction for `spec`.
    pub fn view(&self, spec: &GenerationSpec) -> NodeView {
        NodeView {
            id: self.id,
            backlog: self.fleet.waiters(),
            in_flight: self.fleet.in_flight(),
            free_devices: self.fleet.free_devices().len(),
            predicted_latency_s: self
                .core
                .predict_latency_for(spec, &self.all_devices())
                .ok(),
        }
    }
}
