//! The front tier: routing, spill-over admission, barrier migration.

use std::sync::Arc;

use crate::config::{EngineConfig, FederationConfig};
use crate::coordinator::{EngineCore, Generation, ResumePoint, Session};
use crate::error::{Error, Result};
use crate::federation::envelope::MigrationEnvelope;
use crate::federation::node::CoordinatorNode;
use crate::federation::shard::{
    parse_shard_policy, spill_order, NodeView, ShardPolicy,
};
use crate::fleet::{AllGpus, GpuLease};
use crate::sched::replan::plan_suffix_on;
use crate::spec::GenerationSpec;

/// The multi-node serving front: N [`CoordinatorNode`]s behind one
/// admission surface. Requests are routed to a home node by the
/// [`ShardPolicy`], spill to the best-ranked sibling when the home
/// answers busy, and — with `federation.migrate` on — may move to a
/// sibling at a sync barrier mid-flight via a [`MigrationEnvelope`].
pub struct FrontTier {
    nodes: Vec<CoordinatorNode>,
    policy: Box<dyn ShardPolicy>,
    migrate: bool,
}

impl FrontTier {
    /// Federate pre-built cores (heterogeneous tiers, tests).
    pub fn new(
        cores: Vec<Arc<EngineCore>>,
        policy: Box<dyn ShardPolicy>,
        migrate: bool,
    ) -> Result<FrontTier> {
        if cores.is_empty() {
            return Err(Error::Config("front tier needs >= 1 node".into()));
        }
        let nodes = cores
            .into_iter()
            .enumerate()
            .map(|(id, core)| CoordinatorNode::new(id, core))
            .collect();
        Ok(FrontTier { nodes, policy, migrate })
    }

    /// Build `cfg.federation.nodes` identical nodes from one config
    /// (each node gets its own core, profiler, plan cache and fleet;
    /// the per-node config carries `federation` defaults so a node
    /// can never recursively federate).
    pub fn homogeneous(cfg: &EngineConfig) -> Result<FrontTier> {
        let fed = cfg.federation.clone();
        let policy = parse_shard_policy(&fed.shard_policy)?;
        let mut node_cfg = cfg.clone();
        node_cfg.federation = FederationConfig::default();
        let mut cores = Vec::with_capacity(fed.nodes);
        for _ in 0..fed.nodes {
            cores.push(EngineCore::new(node_cfg.clone())?);
        }
        Self::new(cores, policy, fed.migrate)
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn nodes(&self) -> &[CoordinatorNode] {
        &self.nodes
    }

    pub fn node(&self, id: usize) -> &CoordinatorNode {
        &self.nodes[id]
    }

    pub fn migrate_enabled(&self) -> bool {
        self.migrate
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Live load snapshots, indexed by node id.
    pub fn views(&self, spec: &GenerationSpec) -> Vec<NodeView> {
        self.nodes.iter().map(|n| n.view(spec)).collect()
    }

    /// The policy's home node for `spec` under current load.
    pub fn route(&self, spec: &GenerationSpec) -> usize {
        self.policy.choose(spec, &self.views(spec))
    }

    /// Spill-over admission: try the home node, then every sibling in
    /// [`spill_order`]. `Ok(None)` = every node busy (the caller may
    /// block on the home fleet or shed). A busy node's grant ledger is
    /// untouched — `try_admit` answers busy without granting.
    pub fn admit(
        &self,
        spec: &GenerationSpec,
    ) -> Result<Option<(usize, GpuLease)>> {
        let views = self.views(spec);
        let home = self.policy.choose(spec, &views);
        for id in spill_order(home, &views) {
            if let Some(lease) = self.nodes[id].try_admit()? {
                return Ok(Some((id, lease)));
            }
        }
        Ok(None)
    }

    /// Admit (spilling, then blocking on the home fleet if every node
    /// is busy) and execute one request; returns the serving node id.
    pub fn generate(
        &self,
        spec: &GenerationSpec,
    ) -> Result<(usize, Generation)> {
        let (id, lease) = self.admit_blocking(spec, 0)?;
        let g = self.nodes[id]
            .core()
            .session_for_on(spec, &lease)?
            .execute(spec)?;
        Ok((id, g))
    }

    /// One request through the full federated path — what the serve
    /// runner calls per job. Admission spills across nodes; when
    /// migration is enabled and the serving node is saturated (fleet
    /// waiters queued behind this request) while a sibling sits idle,
    /// the request executes to the mid-plan sync barrier, ships a
    /// [`MigrationEnvelope`], and finishes on the sibling.
    pub fn serve_one(
        &self,
        spec: &GenerationSpec,
        backlog: usize,
    ) -> Result<Generation> {
        let (id, lease) = self.admit_blocking(spec, backlog)?;
        let node = &self.nodes[id];
        let session = node.core().session_for_on(spec, &lease)?;
        if self.migrate {
            if let Some(g) = self.migrate_mid_run(spec, id, &session)? {
                return Ok(g);
            }
        }
        session.execute(spec)
    }

    fn admit_blocking(
        &self,
        spec: &GenerationSpec,
        backlog: usize,
    ) -> Result<(usize, GpuLease)> {
        if let Some(granted) = self.admit(spec)? {
            return Ok(granted);
        }
        // Every node busy: block on the home node's fleet (the
        // policy's pick under current load) until a lease frees up.
        let home = self.route(spec);
        let node = &self.nodes[home];
        let lease = node.fleet().acquire(
            &AllGpus,
            &node.core().effective_speeds(),
            None,
            backlog,
        )?;
        Ok((home, lease))
    }

    /// The saturation-triggered migration attempt. `Ok(None)` = no
    /// migration happened (no pressure, no idle sibling, nothing
    /// migratable at the barrier) — the caller finishes locally.
    fn migrate_mid_run(
        &self,
        spec: &GenerationSpec,
        src: usize,
        session: &Session,
    ) -> Result<Option<Generation>> {
        if self.nodes[src].fleet().waiters() == 0 {
            return Ok(None); // no one queued behind us: stay put
        }
        let dest = match self.nodes.iter().position(|n| {
            n.id() != src
                && n.fleet().in_flight() == 0
                && n.fleet().waiters() == 0
        }) {
            Some(d) => d,
            None => return Ok(None), // no idle sibling to absorb us
        };
        let total = session.plan().sync_points.len();
        if total < 2 {
            return Ok(None);
        }
        // Reserve the destination before doing any work there; a race
        // that snatched it away just cancels the migration.
        let dest_lease = match self.nodes[dest].try_admit()? {
            Some(l) => l,
            None => return Ok(None),
        };
        let ckpt = session.execute_to_barrier(spec.seed, total / 2)?;
        let env =
            match MigrationEnvelope::capture(session, &ckpt, spec.seed)? {
                Some(e) => e,
                // Nothing migratable (only the final step remains):
                // the caller re-executes locally from scratch —
                // wasteful, but deterministic and correct.
                None => return Ok(None),
            };
        let dest_core = self.nodes[dest].core();
        let g = resume_envelope_on(
            dest_core,
            &env,
            &dest_core.effective_speeds(),
        )?;
        drop(dest_lease);
        match g {
            Some(g) => Ok(Some(g)),
            // Parity deferral on the destination: resume locally from
            // the same envelope rather than re-running the prefix.
            None => {
                let src_core = self.nodes[src].core();
                resume_envelope_on(
                    src_core,
                    &env,
                    &src_core.effective_speeds(),
                )
            }
        }
    }

    /// Deterministic migration driver (tests, offline replay): run
    /// `spec` on `src` to its plan's `n_syncs`-th barrier, seal the
    /// envelope, resume on `dest`. Errors if migration is disabled or
    /// the barrier leaves nothing migratable.
    pub fn generate_migrated(
        &self,
        spec: &GenerationSpec,
        n_syncs: usize,
        src: usize,
        dest: usize,
    ) -> Result<Generation> {
        if !self.migrate {
            return Err(Error::Config(
                "federation.migrate is disabled".into(),
            ));
        }
        let session = self.nodes[src].core().session_for(spec)?;
        let ckpt = session.execute_to_barrier(spec.seed, n_syncs)?;
        let env = MigrationEnvelope::capture(&session, &ckpt, spec.seed)?
            .ok_or_else(|| {
                Error::Sched(format!(
                    "barrier {n_syncs} leaves no migratable suffix"
                ))
            })?;
        let core = self.nodes[dest].core();
        resume_envelope_on(core, &env, &core.effective_speeds())?
            .ok_or_else(|| {
                Error::Sched(
                    "suffix parity defers migration at this barrier"
                        .into(),
                )
            })
    }

    /// Resume a decoded envelope on node `dest` at its live speeds.
    /// `Ok(None)` = parity deferral (hand off at the next barrier).
    pub fn resume_on(
        &self,
        dest: usize,
        env: &MigrationEnvelope,
    ) -> Result<Option<Generation>> {
        if !self.migrate {
            return Err(Error::Config(
                "federation.migrate is disabled".into(),
            ));
        }
        let core = self.nodes[dest].core();
        resume_envelope_on(core, env, &core.effective_speeds())
    }
}

/// Resume a [`MigrationEnvelope`] on `core` with explicit per-device
/// `speeds` — the shared receiving half of cross-node migration *and*
/// intra-node device re-admission. The suffix is re-planned over
/// `speeds` by [`plan_suffix_on`] (every device starts from the
/// envelope's fully-fresh buffers, so a recovered device whose live
/// speed clears Eq. 4 is included — unlike the stock mid-flight
/// re-planner, which pins excluded devices out), the envelope payload
/// is charged on the resumed clock, and the returned timeline spans
/// the whole request. `Ok(None)` = parity deferral: a Half-class
/// continuation needs an odd suffix — hand off at the next barrier.
pub fn resume_envelope_on(
    core: &EngineCore,
    env: &MigrationEnvelope,
    speeds: &[f64],
) -> Result<Option<Generation>> {
    let names: Vec<String> = core
        .config()
        .devices
        .iter()
        .map(|d| d.name.clone())
        .collect();
    if speeds.len() != names.len() {
        return Err(Error::Sched(format!(
            "resume speeds for {} devices, node has {}",
            speeds.len(),
            names.len()
        )));
    }
    let cluster = core.cluster();
    let cost = if env.params.cost_aware {
        Some(&cluster[0].cost)
    } else {
        None
    };
    let granularity = core.exec().manifest().model.row_granularity;
    let plan = match plan_suffix_on(
        core.schedule(),
        &env.fast_suffix,
        &env.params,
        speeds,
        &names,
        cost,
        env.total_rows,
        granularity,
    )? {
        Some(p) => p,
        None => return Ok(None),
    };
    let session = core.session_with_plan(plan);
    session
        .resume_seeded(
            env.seed,
            &ResumePoint {
                x: &env.x,
                kv: &env.kv,
                elapsed_s: env.elapsed_s,
                comm_s: env.comm_s,
                transfer_bytes: env.payload_bytes(),
            },
        )
        .map(Some)
}
