//! Shard policies: which node a request calls home.
//!
//! A policy ranks live [`NodeView`]s — fleet backlog, in-flight
//! leases, free devices, and the node's own planner-backed latency
//! prediction — and names the home node. Admission then tries the
//! home first and spills to the best-ranked sibling when it answers
//! busy ([`spill_order`]). Policies are pure over their inputs, so
//! routing is deterministic and testable without a cluster.

use crate::error::{Error, Result};
use crate::spec::GenerationSpec;

/// One node's load snapshot, as the policy sees it.
#[derive(Debug, Clone, Copy)]
pub struct NodeView {
    /// Node id (index in the tier).
    pub id: usize,
    /// Acquirers blocked on the node's fleet (queue-depth signal,
    /// analogous to `Router::backlog()` on the serve side).
    pub backlog: usize,
    /// Leases currently outstanding on the node's fleet.
    pub in_flight: usize,
    /// Devices currently free on the node.
    pub free_devices: usize,
    /// The node's own predicted end-to-end latency for this spec on
    /// its full cluster (`EngineCore::predict_latency_for`); `None`
    /// when prediction failed (unplannable shape on that node).
    pub predicted_latency_s: Option<f64>,
}

impl NodeView {
    /// Load rank: fewer queued + in-flight requests first, then the
    /// faster predicted service, then the lower id (total order).
    fn load_key(&self) -> (usize, f64, usize) {
        (
            self.backlog + self.in_flight,
            self.predicted_latency_s.unwrap_or(f64::INFINITY),
            self.id,
        )
    }
}

fn lighter(a: &NodeView, b: &NodeView) -> bool {
    let (la, pa, ia) = a.load_key();
    let (lb, pb, ib) = b.load_key();
    if la != lb {
        return la < lb;
    }
    if pa != pb {
        return pa < pb;
    }
    ia < ib
}

/// Routes a spec to its home node. Implementations must be pure
/// functions of `(spec, views)` so routing decisions are reproducible.
pub trait ShardPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// The home node for `spec`. `views` is non-empty and indexed by
    /// node id.
    fn choose(&self, spec: &GenerationSpec, views: &[NodeView]) -> usize;
}

/// Least-loaded routing: fewest queued + in-flight requests, ties
/// broken by the node's own latency prediction, then by id.
#[derive(Debug, Default, Clone, Copy)]
pub struct LeastLoaded;

impl ShardPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn choose(&self, _spec: &GenerationSpec, views: &[NodeView]) -> usize {
        debug_assert!(!views.is_empty());
        let mut best = &views[0];
        for v in &views[1..] {
            if lighter(v, best) {
                best = v;
            }
        }
        best.id
    }
}

/// Consistent-hash affinity: equal request *shapes* (steps, size,
/// quality — everything that keys a
/// [`PlanKey`](crate::sched::plan::PlanKey), deliberately not the
/// seed) hash to the same node, so a shape's plan is built once and
/// every repeat hits that node's warm
/// [`PlanCache`](crate::sched::plan::PlanCache). A small virtual-node
/// ring keeps the mapping stable under node-count changes: adding a
/// node remaps only the shapes whose ring successor it becomes.
#[derive(Debug, Default, Clone, Copy)]
pub struct ConsistentHash;

/// Virtual points per node on the hash ring.
const RING_REPLICAS: u64 = 16;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The shape signature consistent hashing keys on: every spec field
/// that shapes the plan, and not the seed (seeds vary per request;
/// affinity is about plan-cache warmth, not stickiness per client).
fn shape_sig(spec: &GenerationSpec) -> String {
    format!(
        "steps={:?};h={:?};w={:?};q={};p={}",
        spec.steps,
        spec.height_px,
        spec.width_px,
        spec.quality.as_str(),
        spec.priority.rank(),
    )
}

impl ShardPolicy for ConsistentHash {
    fn name(&self) -> &'static str {
        "hash"
    }

    fn choose(&self, spec: &GenerationSpec, views: &[NodeView]) -> usize {
        debug_assert!(!views.is_empty());
        let key = fnv1a(shape_sig(spec).as_bytes());
        // Successor of `key` on the ring of node replica points.
        let mut best: Option<(u64, usize)> = None; // (distance, id)
        for v in views {
            for r in 0..RING_REPLICAS {
                let point = fnv1a(
                    format!("node={};replica={r}", v.id).as_bytes(),
                );
                let dist = point.wrapping_sub(key);
                if best.map(|(d, _)| dist < d).unwrap_or(true) {
                    best = Some((dist, v.id));
                }
            }
        }
        best.map(|(_, id)| id).unwrap_or(0)
    }
}

/// Parse a `federation.shard_policy` config string.
pub fn parse_shard_policy(s: &str) -> Result<Box<dyn ShardPolicy>> {
    match s {
        "least-loaded" => Ok(Box::new(LeastLoaded)),
        "hash" => Ok(Box::new(ConsistentHash)),
        other => Err(Error::Config(format!(
            "unknown shard policy {other:?} (want \"least-loaded\" or \
             \"hash\")"
        ))),
    }
}

/// Admission order when the home node answers busy: home first, then
/// every sibling by ascending load rank. The caller walks this list
/// with `try_admit` — the first grant wins.
pub fn spill_order(home: usize, views: &[NodeView]) -> Vec<usize> {
    let mut rest: Vec<&NodeView> =
        views.iter().filter(|v| v.id != home).collect();
    rest.sort_by(|a, b| {
        let (la, pa, ia) = a.load_key();
        let (lb, pb, ib) = b.load_key();
        la.cmp(&lb)
            .then(pa.partial_cmp(&pb).unwrap_or(std::cmp::Ordering::Equal))
            .then(ia.cmp(&ib))
    });
    let mut order = Vec::with_capacity(views.len());
    if views.iter().any(|v| v.id == home) {
        order.push(home);
    }
    order.extend(rest.iter().map(|v| v.id));
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(id: usize, backlog: usize, in_flight: usize) -> NodeView {
        NodeView {
            id,
            backlog,
            in_flight,
            free_devices: 2,
            predicted_latency_s: Some(1.0),
        }
    }

    #[test]
    fn least_loaded_prefers_idle_then_prediction_then_id() {
        let spec = GenerationSpec::new();
        let views =
            vec![view(0, 2, 1), view(1, 0, 0), view(2, 0, 1)];
        assert_eq!(LeastLoaded.choose(&spec, &views), 1);
        // Equal load: the faster-predicted node wins.
        let mut views = vec![view(0, 0, 0), view(1, 0, 0)];
        views[1].predicted_latency_s = Some(0.5);
        assert_eq!(LeastLoaded.choose(&spec, &views), 1);
        // Fully symmetric: lowest id.
        let views = vec![view(0, 1, 1), view(1, 1, 1)];
        assert_eq!(LeastLoaded.choose(&spec, &views), 0);
        // A node that cannot predict ranks behind one that can.
        let mut views = vec![view(0, 0, 0), view(1, 0, 0)];
        views[0].predicted_latency_s = None;
        assert_eq!(LeastLoaded.choose(&spec, &views), 1);
    }

    #[test]
    fn hash_is_deterministic_and_seed_blind() {
        let views = vec![view(0, 0, 0), view(1, 5, 5), view(2, 0, 0)];
        let a = GenerationSpec::new().seed(1).steps(6);
        let b = GenerationSpec::new().seed(999).steps(6);
        let h = ConsistentHash;
        // Same shape, different seed: same home (plan-cache affinity);
        // load plays no part in the hash choice.
        assert_eq!(h.choose(&a, &views), h.choose(&b, &views));
        // Repeated calls are stable.
        assert_eq!(h.choose(&a, &views), h.choose(&a, &views));
        // Shapes spread: over a family of step budgets at 3 nodes, at
        // least two distinct homes appear.
        let homes: std::collections::BTreeSet<usize> = (2..40)
            .map(|s| {
                h.choose(&GenerationSpec::new().steps(2 * s), &views)
            })
            .collect();
        assert!(homes.len() >= 2, "ring degenerated to one node");
    }

    #[test]
    fn ring_is_mostly_stable_when_a_node_joins() {
        let h = ConsistentHash;
        let three = vec![view(0, 0, 0), view(1, 0, 0), view(2, 0, 0)];
        let four = vec![
            view(0, 0, 0),
            view(1, 0, 0),
            view(2, 0, 0),
            view(3, 0, 0),
        ];
        let shapes: Vec<GenerationSpec> =
            (1..=60).map(|s| GenerationSpec::new().steps(2 * s)).collect();
        let moved = shapes
            .iter()
            .filter(|s| {
                let before = h.choose(s, &three);
                let after = h.choose(s, &four);
                after != before && after != 3
            })
            .count();
        // Consistent hashing: shapes either stay put or move to the
        // new node — none shuffle between surviving nodes.
        assert_eq!(moved, 0, "{moved} shapes shuffled between old nodes");
    }

    #[test]
    fn parse_matches_config_contract() {
        assert_eq!(parse_shard_policy("least-loaded").unwrap().name(),
            "least-loaded");
        assert_eq!(parse_shard_policy("hash").unwrap().name(), "hash");
        assert!(parse_shard_policy("round-robin").is_err());
    }

    #[test]
    fn spill_order_puts_home_first_then_lightest() {
        let views =
            vec![view(0, 3, 1), view(1, 0, 0), view(2, 1, 0)];
        assert_eq!(spill_order(0, &views), vec![0, 1, 2]);
        assert_eq!(spill_order(1, &views), vec![1, 2, 0]);
        assert_eq!(spill_order(2, &views), vec![2, 1, 0]);
    }
}
