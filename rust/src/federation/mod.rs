//! Federated serving (EXTENSION): a multi-node coordinator tier.
//!
//! The paper's coordinator owns one heterogeneous cluster. A serving
//! deployment shards traffic across several such clusters ("nodes"),
//! each with its own [`EngineCore`](crate::coordinator::EngineCore),
//! plan cache, profiler and fleet ledger. This module adds the tier
//! that federates them:
//!
//! * [`CoordinatorNode`] — one engine core plus its fleet slice;
//! * [`ShardPolicy`] ([`LeastLoaded`], [`ConsistentHash`]) — routes a
//!   [`GenerationSpec`](crate::spec::GenerationSpec) to a home node:
//!   least-loaded by backlog and predicted latency, or consistent-hash
//!   affinity so repeated request shapes land on a warm
//!   [`PlanCache`](crate::sched::plan::PlanCache);
//! * spill-over admission — when the home node answers busy, the
//!   request spills to the best-ranked sibling instead of queueing
//!   ([`FrontTier::admit`]);
//! * barrier-checkpoint migration — an in-flight request can move to
//!   a sibling node at a sync barrier: the fully-fresh `(x, kv)`
//!   snapshot plus the remaining fast-grid suffix are serialized into
//!   a versioned [`MigrationEnvelope`], the suffix is re-planned on
//!   the destination
//!   ([`plan_suffix_on`](crate::sched::replan::plan_suffix_on)), the
//!   transfer is charged on the virtual clock
//!   ([`charge_migration`](crate::coordinator::timeline::SimState::charge_migration)),
//!   and — when speeds match — the rendered latent is byte-identical
//!   to the unmigrated run (the zero-drift re-plan invariant).
//!
//! The same envelope seam re-admits a *recovered device* on its own
//! node: the stock mid-flight re-planner never re-admits excluded
//! devices (their buffers are stale), but a barrier handoff transfers
//! fresh state to everyone, so [`resume_envelope_on`] may include any
//! device whose live speed clears Eq. 4.
//!
//! Everything defaults off: `federation.nodes = 1` is the pre-tier
//! single-node engine, bit-exact (pinned by
//! `tests/integration_federation.rs`).

pub mod envelope;
pub mod node;
pub mod shard;
pub mod tier;

pub use envelope::{MigrationEnvelope, ENVELOPE_VERSION};
pub use node::CoordinatorNode;
pub use shard::{
    parse_shard_policy, spill_order, ConsistentHash, LeastLoaded, NodeView,
    ShardPolicy,
};
pub use tier::{resume_envelope_on, FrontTier};
